"""AOT pipeline: manifest consistency + HLO text validity."""

import json
import os

import numpy as np
import pytest

from compile.aot import (
    build_artifacts,
    compile_spec,
    config_digest,
    fragment_points,
    lower_fragment,
)
from compile.model import build_models, load_config

CONFIG = load_config()
MODELS = build_models(CONFIG)


def test_fragment_points_include_bounds():
    for m in CONFIG["models"]:
        pts = fragment_points(m)
        assert pts[0] == 0 and pts[-1] == m["layers"]
        assert pts == sorted(set(pts))


def test_compile_spec_covers_all_pairs():
    spec = compile_spec(CONFIG, ["vgg"], [1, 4])
    pts = fragment_points(next(m for m in CONFIG["models"]
                               if m["name"] == "vgg"))
    npairs = len(pts) * (len(pts) - 1) // 2
    assert len(spec) == npairs * 2
    assert all(s < e for (_, s, e, _) in spec)


def test_lowered_hlo_is_text_with_entry():
    text = lower_fragment(MODELS["vgg"], 4, 6, 2)
    assert "ENTRY" in text and "HloModule" in text
    # parameters of the ENTRY computation: x + 2 layers * (w, b)
    entry = text[text.index("ENTRY"):]
    entry = entry[: entry.index("\n}")]
    assert entry.count("parameter(") == 1 + 2 * 2


def test_build_artifacts_manifest(tmp_path):
    out = str(tmp_path / "artifacts")
    manifest = build_artifacts(out, ["vgg"], [1, 2], CONFIG, verbose=False)
    assert manifest["config_digest"] == config_digest(CONFIG)
    disk = json.load(open(os.path.join(out, "manifest.json")))
    assert disk["entries"] == manifest["entries"]
    for e in manifest["entries"]:
        path = os.path.join(out, e["path"])
        assert os.path.exists(path), e["path"]
        assert e["input_shape"][0] == e["batch"]
        dims = manifest["models"][e["model"]]["dims"]
        assert e["input_shape"][1] == dims[e["start"]]
        assert e["output_shape"][1] == dims[e["end"]]
    # one weight blob with the full parameter set
    wpath = os.path.join(out, "weights_vgg.bin")
    m = MODELS["vgg"]
    assert os.path.getsize(wpath) == len(m.weights_blob())


def test_weight_blob_roundtrip_matches_params(tmp_path):
    out = str(tmp_path / "artifacts")
    build_artifacts(out, ["vgg"], [1], CONFIG, verbose=False)
    m = MODELS["vgg"]
    blob = np.fromfile(os.path.join(out, "weights_vgg.bin"), dtype="<f4")
    off = 0
    for i in range(m.layers):
        wlen = m.dims[i] * m.dims[i + 1]
        w = blob[off:off + wlen].reshape(m.dims[i], m.dims[i + 1])
        off += wlen
        b = blob[off:off + m.dims[i + 1]]
        off += m.dims[i + 1]
        np.testing.assert_array_equal(w, m.params[i][0])
        np.testing.assert_array_equal(b, m.params[i][1])
    assert off == blob.size
