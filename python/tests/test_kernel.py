"""L1 kernel vs pure-jnp oracle — the core correctness signal.

Hypothesis sweeps shapes (tile-divisible and ragged batch), dtypes and
activations; every case asserts allclose against ``ref.py``.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    linear_block,
    linear_block_ref,
    mxu_utilisation,
    vmem_bytes,
)

DIMS = st.sampled_from([64, 128, 192, 256])
BATCH = st.integers(min_value=1, max_value=17)
ACT = st.sampled_from(["none", "relu", "gelu"])


def _rand(shape, seed, dtype=np.float32):
    return jnp.asarray(
        np.random.default_rng(seed).normal(size=shape).astype(dtype)
    )


@settings(max_examples=40, deadline=None)
@given(m=BATCH, k=DIMS, n=DIMS, act=ACT, seed=st.integers(0, 2**31 - 1))
def test_kernel_matches_ref(m, k, n, act, seed):
    x = _rand((m, k), seed)
    w = _rand((k, n), seed + 1) * np.float32(np.sqrt(1.0 / k))
    b = _rand((n,), seed + 2)
    got = np.asarray(linear_block(x, w, b, act=act))
    want = np.asarray(linear_block_ref(x, w, b, act=act))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(m=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
def test_kernel_bfloat16(m, seed):
    k = n = 128
    x = _rand((m, k), seed).astype(jnp.bfloat16)
    w = (_rand((k, n), seed + 1) * np.float32(0.1)).astype(jnp.bfloat16)
    b = _rand((n,), seed + 2).astype(jnp.bfloat16)
    got = np.asarray(linear_block(x, w, b, act="relu").astype(jnp.float32))
    want = np.asarray(
        linear_block_ref(x, w, b, act="relu").astype(jnp.float32)
    )
    np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("bm,bn,bk", [(8, 64, 64), (16, 64, 128)])
def test_tile_size_variants(bm, bn, bk):
    x, w, b = _rand((5, 128), 0), _rand((128, 128), 1), _rand((128,), 2)
    got = np.asarray(linear_block(x, w, b, act="relu", bm=bm, bn=bn, bk=bk))
    want = np.asarray(linear_block_ref(x, w, b, act="relu"))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_rejects_bad_shapes():
    x, w, b = _rand((4, 128), 0), _rand((64, 128), 1), _rand((128,), 2)
    with pytest.raises(ValueError, match="shape mismatch"):
        linear_block(x, w, b)


def test_rejects_non_dividing_tiles():
    # defaults clamp tiles to the full matrix, so force small tiles
    x, w, b = _rand((4, 100), 0), _rand((100, 128), 1), _rand((128,), 2)
    with pytest.raises(ValueError, match="must divide"):
        linear_block(x, w, b, bk=64)


def test_rejects_unknown_activation():
    x, w, b = _rand((4, 64), 0), _rand((64, 64), 1), _rand((64,), 2)
    with pytest.raises(ValueError, match="unknown activation"):
        linear_block(x, w, b, act="swish")


def test_vmem_estimate_within_budget():
    # default tiles must fit VMEM (16 MiB) with huge headroom
    assert vmem_bytes(16, 64, 64) < 64 * 1024
    assert 0.0 < mxu_utilisation(16, 64, 64) <= 1.0
    assert mxu_utilisation(128, 128, 128) == 1.0
