"""Stand-in model invariants: shapes, determinism, fragment composition."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.model import StandInModel, build_models, load_config, model_seed

CONFIG = load_config()
MODELS = build_models(CONFIG)
NAMES = sorted(MODELS)


@pytest.mark.parametrize("name", NAMES)
def test_layer_counts_match_config(name):
    cfg = next(m for m in CONFIG["models"] if m["name"] == name)
    assert MODELS[name].layers == cfg["layers"]
    assert len(cfg["rel_cost"]) == cfg["layers"]
    assert len(cfg["act_kb"]) == cfg["layers"]


@pytest.mark.parametrize("name", NAMES)
def test_weights_deterministic(name):
    a = StandInModel(name, MODELS[name].dims, model_seed(name))
    b = StandInModel(name, MODELS[name].dims, model_seed(name))
    for (wa, ba), (wb, bb) in zip(a.params, b.params):
        np.testing.assert_array_equal(wa, wb)
        np.testing.assert_array_equal(ba, bb)


def test_weights_blob_layout():
    m = MODELS["vgg"]
    blob = m.weights_blob()
    expect = sum(
        m.dims[i] * m.dims[i + 1] + m.dims[i + 1] for i in range(m.layers)
    )
    assert len(blob) == 4 * expect
    # first weight round-trips
    w0 = np.frombuffer(
        blob[: 4 * m.dims[0] * m.dims[1]], dtype="<f4"
    ).reshape(m.dims[0], m.dims[1])
    np.testing.assert_array_equal(w0, m.params[0][0])


@settings(max_examples=15, deadline=None)
@given(
    name=st.sampled_from(NAMES),
    data=st.data(),
)
def test_fragment_composition(name, data):
    """frag(mid,end) o frag(start,mid) == frag(start,end)."""
    m = MODELS[name]
    start = data.draw(st.integers(0, m.layers - 2))
    mid = data.draw(st.integers(start + 1, m.layers - 1))
    end = data.draw(st.integers(mid + 1, m.layers))
    x = jnp.asarray(
        np.random.default_rng(7).normal(size=(2, m.dims[start]))
        .astype(np.float32)
    )
    whole = m.fragment_ref_fn(start, end)(x)
    composed = m.fragment_ref_fn(mid, end)(m.fragment_ref_fn(start, mid)(x))
    np.testing.assert_allclose(whole, composed, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("name", NAMES)
def test_pallas_fragment_matches_ref(name):
    m = MODELS[name]
    start, end = 0, min(3, m.layers)
    x = jnp.asarray(
        np.random.default_rng(11).normal(size=(4, m.dims[start]))
        .astype(np.float32)
    )
    got = jax.jit(m.fragment_fn(start, end))(
        x, *m.flat_fragment_params(start, end)
    )[0]
    want = m.fragment_ref_fn(start, end)(x)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_final_layer_has_no_activation():
    m = MODELS["vgg"]
    acts = m.acts(m.layers - 2, m.layers)
    assert acts == ["relu", "none"]
    # the head can go negative (no relu clamp)
    x = jnp.asarray(
        -np.abs(np.random.default_rng(3).normal(size=(8, m.dims[0])))
        .astype(np.float32)
    )
    y = np.asarray(m.fragment_ref_fn(0, m.layers)(x))
    assert (y < 0).any()


def test_bad_fragment_ranges_rejected():
    m = MODELS["inc"]
    for start, end in [(-1, 3), (3, 3), (5, 2), (0, m.layers + 1)]:
        with pytest.raises(ValueError):
            m.fragment_params(start, end)


def test_activation_magnitudes_stable():
    """He-init keeps activations O(1) through the deepest model."""
    m = MODELS["mob"]
    x = jnp.asarray(
        np.random.default_rng(5).normal(size=(4, m.dims[0]))
        .astype(np.float32)
    )
    y = np.asarray(m.fragment_ref_fn(0, m.layers)(x))
    rms = float(np.sqrt((y ** 2).mean()))
    assert 1e-3 < rms < 1e3, rms
