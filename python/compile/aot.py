"""AOT pipeline: lower model fragments to HLO text + weight blobs.

``make artifacts`` runs this once; afterwards Python never touches the
request path.  For every fragment ``(model, start, end)`` in the compile
spec and every bucketed batch size we emit
``artifacts/<model>_s<start>_e<end>_b<batch>.hlo.txt`` plus one
``artifacts/weights_<model>.bin`` per model and a ``manifest.json`` the
Rust runtime indexes by ``(model, start, end, batch)``.

Interchange format is **HLO text** (not ``.serialize()``): jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension
0.5.1 (the version the ``xla`` 0.1.6 crate links) rejects; the text
parser reassigns ids and round-trips cleanly.

The compile spec covers every fragment the executor-backed scheduler can
pick: for each model, the candidate point set is
``{0} | common_starts | {L}``; artifacts exist for all ordered pairs
drawn from it.  The simulation experiments (most paper figures) use the
analytical profiler and need no artifacts at all.
"""

from __future__ import annotations

import argparse
import hashlib
import itertools
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from .model import build_models, load_config

DEFAULT_MODELS = ["inc", "res", "vgg", "mob", "vit"]
DEFAULT_BATCHES = [1, 2, 4, 8]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def fragment_points(model_cfg: dict) -> list[int]:
    """Candidate (re-)partition points: {0} | common_starts | {L}."""
    pts = {0, model_cfg["layers"], *model_cfg["common_starts"]}
    return sorted(pts)


def compile_spec(config: dict, model_names: list[str],
                 batches: list[int]) -> list[tuple[str, int, int, int]]:
    """All (model, start, end, batch) tuples to lower."""
    spec = []
    by_name = {m["name"]: m for m in config["models"]}
    for name in model_names:
        pts = fragment_points(by_name[name])
        for start, end in itertools.combinations(pts, 2):
            for b in batches:
                spec.append((name, start, end, b))
    return spec


def lower_fragment(model, start: int, end: int, batch: int) -> str:
    fn = model.fragment_fn(start, end)
    specs = model.fragment_arg_specs(start, end, batch)
    return to_hlo_text(jax.jit(fn).lower(*specs))


def config_digest(config: dict) -> str:
    return hashlib.sha256(
        json.dumps(config, sort_keys=True).encode()
    ).hexdigest()[:16]


def build_artifacts(out_dir: str, model_names: list[str],
                    batches: list[int], config: dict | None = None,
                    verbose: bool = True) -> dict:
    """Lower the full compile spec into ``out_dir``; returns the manifest."""
    config = config or load_config()
    models = build_models(config)
    by_name = {m["name"]: m for m in config["models"]}
    os.makedirs(out_dir, exist_ok=True)

    entries = []
    for name in model_names:
        model = models[name]
        wpath = f"weights_{name}.bin"
        with open(os.path.join(out_dir, wpath), "wb") as f:
            f.write(model.weights_blob())
        if verbose:
            print(f"[aot] {name}: wrote {wpath} "
                  f"({len(model.weights_blob()) // 1024} KiB)")

    spec = compile_spec(config, model_names, batches)
    for i, (name, start, end, batch) in enumerate(spec):
        model = models[name]
        fname = f"{name}_s{start}_e{end}_b{batch}.hlo.txt"
        text = lower_fragment(model, start, end, batch)
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entries.append({
            "model": name,
            "start": start,
            "end": end,
            "batch": batch,
            "path": fname,
            "weights": f"weights_{name}.bin",
            "input_shape": [batch, model.dims[start]],
            "output_shape": [batch, model.dims[end]],
            # layer j (1-indexed, in [start+1, end]) contributes params
            # (w:[dims[j-1],dims[j]], b:[dims[j]]) in order after x.
            "param_layers": list(range(start + 1, end + 1)),
        })
        if verbose and (i % 20 == 0 or i == len(spec) - 1):
            print(f"[aot] lowered {i + 1}/{len(spec)}: {fname}")

    manifest = {
        "config_digest": config_digest(config),
        "models": {
            name: {"dims": by_name[name]["dims"],
                   "points": fragment_points(by_name[name])}
            for name in model_names
        },
        "batches": batches,
        "entries": entries,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if verbose:
        print(f"[aot] manifest: {len(entries)} artifacts in {out_dir}")
    return manifest


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default=",".join(DEFAULT_MODELS),
                    help="comma-separated model names (or 'all')")
    ap.add_argument("--batches", default=",".join(map(str, DEFAULT_BATCHES)))
    args = ap.parse_args(argv)

    config = load_config()
    names = ([m["name"] for m in config["models"]]
             if args.models == "all" else args.models.split(","))
    batches = [int(b) for b in args.batches.split(",")]
    build_artifacts(args.out, names, batches, config)


if __name__ == "__main__":
    sys.exit(main())
