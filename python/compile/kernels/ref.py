"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth the kernels and the AOT-compiled fragments are
validated against in ``python/tests/``.  They use only ``jax.numpy`` so any
numerical divergence is attributable to the kernel implementation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_ACTIVATIONS = {
    "none": lambda x: x,
    "relu": lambda x: jnp.maximum(x, 0.0),
    "gelu": jax.nn.gelu,
}


def linear_block_ref(
    x: jax.Array, w: jax.Array, b: jax.Array, *, act: str = "relu"
) -> jax.Array:
    """Reference ``act(x @ w + b)``."""
    return _ACTIVATIONS[act](jnp.dot(x, w) + b[None, :])


def fragment_ref(x: jax.Array, layer_params, acts) -> jax.Array:
    """Reference forward through a list of ``(w, b)`` layers.

    ``acts`` is the per-layer activation name list (same length as
    ``layer_params``).
    """
    for (w, b), act in zip(layer_params, acts):
        x = linear_block_ref(x, w, b, act=act)
    return x
