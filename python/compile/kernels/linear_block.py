"""L1: tiled linear-block Pallas kernel — the per-layer compute hot-spot.

Every layer of the five stand-in DNNs is a ``linear_block``:
``y = act(x @ w + b)`` over ``x:[M,K] w:[K,N] b:[N]``.  The paper's hot
spot is CUDA kernels executed under MPS shares; the TPU re-think (see
DESIGN.md §3) is an MXU-tiled matmul with VMEM-resident blocks:

* the grid is ``(M/bm, N/bn, K/bk)`` — the K axis is the innermost,
  sequential, accumulation axis (double-buffered HBM->VMEM streaming is
  expressed by the BlockSpec index maps, the analogue of the paper's
  threadblock tiling);
* each grid step multiplies a ``(bm,bk)`` x ``(bk,bn)`` tile pair on the
  MXU and accumulates into the ``(bm,bn)`` output tile kept in VMEM;
* bias add + activation are fused into the last K step, so the block is
  a single kernel (the paper's fused conv+bias+relu analogue).

``interpret=True`` is mandatory: this repo executes on CPU PJRT, and a
real-TPU lowering would emit a Mosaic custom-call the CPU plugin cannot
run.  Real-TPU performance is *estimated* from the VMEM footprint / MXU
utilisation of the chosen block shapes (EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes.  The stand-in widths are <= 512, so a whole layer's
# working set fits VMEM comfortably:
#   x-tile bm*bk + w-tile bk*bn + o-tile bm*bn  (f32)
# at bm=32, bk=bn=512: (32*512 + 512*512 + 32*512) * 4B = 1.13 MiB << 16 MiB.
# We therefore default to whole-matrix tiles (grid collapses to the batch
# axis): one MXU pass per layer instead of (N/64)*(K/64) sequential grid
# steps.  This matters doubly here because interpret-mode lowering turns
# every grid step into an XLA while-loop iteration with dynamic slices —
# the 64x64 default cost ~20-60 ms per fragment on the CPU PJRT hot path
# vs ~1 ms with whole-matrix tiles (EXPERIMENTS.md §Perf, L1 iteration 1).
# For layers wider than VMEM allows, pass explicit bn/bk (the kernel keeps
# full tiling support; tests sweep small tiles).
DEFAULT_BN = 512
DEFAULT_BK = 512

_ACTIVATIONS = {
    "none": lambda x: x,
    "relu": lambda x: jnp.maximum(x, 0.0),
    "gelu": jax.nn.gelu,
}


def _kernel(x_ref, w_ref, b_ref, o_ref, *, nk: int, act: str):
    """One grid step: accumulate x-tile @ w-tile; finalise on last K step."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=o_ref.dtype
    )

    @pl.when(k == nk - 1)
    def _finalise():
        o_ref[...] = _ACTIVATIONS[act](o_ref[...] + b_ref[...][None, :])


@functools.partial(
    jax.jit, static_argnames=("act", "bm", "bn", "bk", "interpret")
)
def linear_block(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    act: str = "relu",
    bm: int | None = None,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool = True,
) -> jax.Array:
    """Fused ``act(x @ w + b)`` as a tiled Pallas kernel.

    Args:
      x: ``[M, K]`` activations.
      w: ``[K, N]`` weights.
      b: ``[N]`` bias.
      act: one of ``none|relu|gelu``.
      bm/bn/bk: tile sizes; must divide (padded) M/N/K.
      interpret: keep True for CPU PJRT (see module docstring).

    Returns:
      ``[M, N]`` output, same dtype as ``x``.
    """
    if x.ndim != 2 or w.ndim != 2 or b.ndim != 1:
        raise ValueError(
            f"linear_block expects x:[M,K] w:[K,N] b:[N], got "
            f"{x.shape}/{w.shape}/{b.shape}"
        )
    m, k = x.shape
    k2, n = w.shape
    if k != k2 or b.shape[0] != n:
        raise ValueError(
            f"shape mismatch: x:{x.shape} w:{w.shape} b:{b.shape}"
        )
    if act not in _ACTIVATIONS:
        raise ValueError(f"unknown activation {act!r}")

    bn = min(bn, n)
    bk = min(bk, k)
    if n % bn or k % bk:
        raise ValueError(f"tile sizes bn={bn},bk={bk} must divide N={n},K={k}")

    # The batch axis is small in serving (<=32); pad it to the tile size so
    # the grid stays rectangular (bucketed batching pads on the Rust side
    # too, so the padding here is usually a no-op).
    bm = bm or min(16, m)
    pad_m = (-m) % bm
    if pad_m:
        x = jnp.pad(x, ((0, pad_m), (0, 0)))
    mp = m + pad_m

    nk = k // bk
    grid = (mp // bm, n // bn, nk)

    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk, act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, n), x.dtype),
        interpret=interpret,
    )(x, w, b)
    return out[:m] if pad_m else out


def vmem_bytes(bm: int, bn: int, bk: int, dtype_bytes: int = 4) -> int:
    """VMEM working-set estimate for one grid step (perf model input)."""
    return (bm * bk + bk * bn + bn + bm * bn) * dtype_bytes


def mxu_utilisation(bm: int, bn: int, bk: int, mxu: int = 128) -> float:
    """Fraction of MXU lanes busy for a (bm,bk)x(bk,bn) tile matmul."""
    return min(1.0, bm / mxu) * min(1.0, bn / mxu) * min(1.0, bk / mxu)
