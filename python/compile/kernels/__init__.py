"""L1 Pallas kernels (build-time only) + pure-jnp oracles."""

from .linear_block import linear_block, mxu_utilisation, vmem_bytes
from .ref import fragment_ref, linear_block_ref

__all__ = [
    "linear_block",
    "linear_block_ref",
    "fragment_ref",
    "vmem_bytes",
    "mxu_utilisation",
]
