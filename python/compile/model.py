"""L2: the five stand-in DNNs as JAX layer graphs (build-time only).

Each of the paper's TorchVision models (Inception-v3, ResNet-101, VGG11,
DeepLabV3-MobileNetV3-L, ViT-B16) is represented by a stand-in network
with the same layer count (Table 2) whose per-layer widths come from
``configs/models.json`` — the single source of truth shared with the Rust
profiler.  Layer ``i`` (1-indexed) maps ``dims[i-1] -> dims[i]`` through
the fused :func:`~compile.kernels.linear_block` Pallas kernel; the final
layer uses no activation (classification/regression head).

A *fragment* ``(start, end)`` is the sub-network of layers
``start+1 .. end``; hybrid DL runs fragment ``(0, p)`` on the mobile
device and ``(p, L)`` on the server, and Graft's re-alignment additionally
creates alignment-stage fragments ``(p_i, p')`` plus one shared fragment
``(p', L)``.

Weights are deterministic (He-init from a per-model seed) so the Rust
runtime and the Python oracle agree bit-for-bit on the same weight file.
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass, field

import numpy as np
import jax
import jax.numpy as jnp

from .kernels import fragment_ref, linear_block

_CONFIG_PATH = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "..", "configs",
    "models.json",
)


def load_config(path: str | None = None) -> dict:
    """Load configs/models.json (canonical model tables)."""
    with open(path or _CONFIG_PATH) as f:
        return json.load(f)


@dataclass
class StandInModel:
    """A stand-in DNN: widths, deterministic weights, fragment forwards."""

    name: str
    dims: list[int]
    seed: int
    params: list[tuple[np.ndarray, np.ndarray]] = field(default_factory=list)

    @property
    def layers(self) -> int:
        return len(self.dims) - 1

    def __post_init__(self):
        if not self.params:
            self.params = self._init_params()

    def _init_params(self) -> list[tuple[np.ndarray, np.ndarray]]:
        """He-init weights from the per-model seed (deterministic)."""
        rng = np.random.default_rng(self.seed)
        params = []
        for i in range(self.layers):
            fan_in, fan_out = self.dims[i], self.dims[i + 1]
            w = rng.normal(0.0, np.sqrt(2.0 / fan_in), (fan_in, fan_out))
            b = rng.normal(0.0, 0.01, (fan_out,))
            params.append((w.astype(np.float32), b.astype(np.float32)))
        return params

    def acts(self, start: int, end: int) -> list[str]:
        """Per-layer activation names for fragment (start, end)."""
        return [
            "none" if i == self.layers else "relu"
            for i in range(start + 1, end + 1)
        ]

    def fragment_params(self, start: int, end: int):
        """The (w, b) pairs of layers start+1..end."""
        self._check_range(start, end)
        return self.params[start:end]

    def fragment_fn(self, start: int, end: int):
        """A jittable ``f(x, *flat_params) -> (y,)`` for the fragment.

        Weights are *parameters* (not baked constants) to keep the HLO
        text small; the Rust runtime feeds them from the weight file.
        Returns a 1-tuple to match the ``return_tuple=True`` lowering.
        """
        self._check_range(start, end)
        acts = self.acts(start, end)

        def fn(x, *flat):
            assert len(flat) == 2 * len(acts)
            for j, act in enumerate(acts):
                x = linear_block(x, flat[2 * j], flat[2 * j + 1], act=act)
            return (x,)

        return fn

    def fragment_ref_fn(self, start: int, end: int):
        """Pure-jnp oracle for the same fragment (same weights)."""
        self._check_range(start, end)
        params = [(jnp.asarray(w), jnp.asarray(b))
                  for w, b in self.fragment_params(start, end)]
        acts = self.acts(start, end)
        return lambda x: fragment_ref(x, params, acts)

    def fragment_arg_specs(self, start: int, end: int, batch: int):
        """ShapeDtypeStructs for ``fragment_fn``'s arguments."""
        self._check_range(start, end)
        specs = [jax.ShapeDtypeStruct((batch, self.dims[start]), jnp.float32)]
        for w, b in self.fragment_params(start, end):
            specs.append(jax.ShapeDtypeStruct(w.shape, jnp.float32))
            specs.append(jax.ShapeDtypeStruct(b.shape, jnp.float32))
        return specs

    def flat_fragment_params(self, start: int, end: int):
        """Weights flattened in ``fragment_fn`` argument order."""
        flat = []
        for w, b in self.fragment_params(start, end):
            flat.extend((jnp.asarray(w), jnp.asarray(b)))
        return flat

    def weights_blob(self) -> bytes:
        """All layers' (w, b) as little-endian f32, layer-major.

        Layout (layer i = 1..L): w_i row-major [dims[i-1], dims[i]] then
        b_i [dims[i]].  Offsets are derivable from ``dims`` alone, which
        is how the Rust runtime indexes into the file.
        """
        chunks = []
        for w, b in self.params:
            chunks.append(w.astype("<f4").tobytes())
            chunks.append(b.astype("<f4").tobytes())
        return b"".join(chunks)

    def _check_range(self, start: int, end: int) -> None:
        if not (0 <= start < end <= self.layers):
            raise ValueError(
                f"bad fragment ({start},{end}) for {self.name} "
                f"with {self.layers} layers"
            )


_SEED_BASE = 0x67AF7  # "Graft"


def model_seed(name: str) -> int:
    return _SEED_BASE + sum(ord(c) * 31 ** i for i, c in enumerate(name))


def build_models(config: dict | None = None) -> dict[str, StandInModel]:
    """Instantiate all stand-in models from the canonical config."""
    config = config or load_config()
    return {
        m["name"]: StandInModel(m["name"], list(m["dims"]),
                                model_seed(m["name"]))
        for m in config["models"]
    }
