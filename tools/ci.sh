#!/usr/bin/env bash
# CI pipeline: build, test, style gates, and fast bench smoke runs:
# planner (n=200 on 2 planner shards, re-validates cached==uncached and
# sharded==sequential plan identity plus the replan scenario's
# warm<=cold, incremental-grouping, plan-quality and dirty-flag
# self-checks), serving
# (n=100, both executors), placement (n=200, integrated-vs-oracle GPU
# counts + cap checks), transition (n=200, live hot-swap: zero-drop
# + delta-vs-repack migration bounds), faults (n=200, single-GPU
# failure: zero silent losses + emergency replan avoids the dead GPU,
# plus the predictive-vs-reactive comparison: health-score-driven
# proactive migration strictly reduces degraded-window drops) and the
# observability round-trip (bench-serving schema v3 attribution +
# tracing-overhead verdict, obs-report /metrics endpoint scrape).
#
#   tools/ci.sh            full pipeline
#   tools/ci.sh --fast     build + test only
#   tools/ci.sh --stress   build + the #[ignore]d stress tests: serving
#                          (64 instances x 10k requests, pooled executor)
#                          and scheduler (lazy-vs-dense similarity table
#                          at n=2500, 100k-client sharded-vs-sequential
#                          plan identity)
#
# Concurrency tests carry in-test watchdogs that abort on deadlock; the
# `timeout` wrappers here are the outer belt-and-braces so a wedged
# build can never hang the CI job until the job-level limit.
set -euo pipefail
cd "$(dirname "$0")/.."

FAST=0
STRESS=0
[[ "${1:-}" == "--fast" ]] && FAST=1
[[ "${1:-}" == "--stress" ]] && STRESS=1

echo "== cargo build --release =="
cargo build --release

if [[ "$STRESS" == "1" ]]; then
    echo "== serving stress (64 instances x 10k requests, cap 900s) =="
    timeout 900 cargo test --release --test serving_stress -- \
        --ignored --nocapture
    echo "== scheduler stress (n=2500 grouping, n=100k sharded plan, cap 1800s) =="
    timeout 1800 cargo test --release --test scheduler_integration -- \
        --ignored --nocapture
    echo "ci: stress OK"
    exit 0
fi

echo "== cargo test -q (cap 1800s) =="
timeout 1800 cargo test -q

echo "== serving concurrency suite (release, cap 600s) =="
timeout 600 cargo test --release -q \
    --test serving_integration --test transition_integration \
    --test fault_integration --test proptests

if [[ "$FAST" == "1" ]]; then
    echo "ci: fast mode, skipping style gates and bench smoke"
    exit 0
fi

if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    cargo fmt --check
else
    echo "ci: rustfmt unavailable, skipping fmt check"
fi

if cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy -D warnings =="
    cargo clippy --workspace --all-targets -- -D warnings
else
    echo "ci: clippy unavailable, skipping lint"
fi

echo "== bench smoke (n=200, incl. trigger-to-trigger replan scenario) =="
# the replan scenario self-checks warm replan <= cold plan time,
# incremental grouping <= scratch grouping time at small perturbations,
# replanned-plan quality (coverage/SLO-safety/share slack vs a fresh
# cold plan) and clean context re-saves being skipped (dirty flag)
# inside the bench (it bails hard); --planner-threads 2 routes the
# plans through the sharded lane, whose byte-identity to the
# sequential oracle is also a hard bail; the greps assert the
# sections, the grouping counters and the per-row grouping_ok /
# shards_ok flags actually landed in the JSON
timeout 600 cargo run --release -p graft -- bench-scheduler \
    --sizes 200 --reps 1 --planner-threads 2 --shard-sizes 200 \
    --out target/BENCH_scheduler_smoke.json
test -s target/BENCH_scheduler_smoke.json
grep -q '"replan"' target/BENCH_scheduler_smoke.json
grep -q '"groups_replayed"' target/BENCH_scheduler_smoke.json
grep -q '"fragments_regrouped"' target/BENCH_scheduler_smoke.json
grep -q '"grouping_ok":true' target/BENCH_scheduler_smoke.json
grep -q '"planner_shards"' target/BENCH_scheduler_smoke.json
grep -q '"shards_ok":true' target/BENCH_scheduler_smoke.json

echo "== serving bench smoke (n=100, both executors) =="
# schema v3 self-checks inside the bench: zero lost responses and the
# tracing-overhead bail (sampled tracing must not inflate pool p99 by
# >5% + 0.5 ms at the largest size); the greps assert the
# registry-snapshot counter dump, the SLO-budget attribution (with a
# dominant-component flag per model) and the overhead verdict landed
timeout 600 cargo run --release -p graft -- bench-serving \
    --sizes 100 --requests 2000 --out target/BENCH_serving_smoke.json
test -s target/BENCH_serving_smoke.json
grep -q '"counters"' target/BENCH_serving_smoke.json
grep -q '"graft_serving_served_total"' target/BENCH_serving_smoke.json
grep -q '"attribution"' target/BENCH_serving_smoke.json
grep -q '"dominant"' target/BENCH_serving_smoke.json
grep -q '"trace_overhead_ok":true' target/BENCH_serving_smoke.json

echo "== metrics exposition smoke (obs-report endpoint) =="
# drive a synthetic traced run, serve its registry snapshot over HTTP,
# and scrape it: the exposition must carry at least one counter and
# one histogram bucket line
OBS_PORT="${OBS_PORT:-9464}"
timeout 120 cargo run --release -p graft -- obs-report \
    --clients 32 --requests 800 \
    --out target/obs_report_smoke.prom \
    --metrics-addr "127.0.0.1:${OBS_PORT}" --serve-for 10 &
OBS_PID=$!
SCRAPED=0
for _ in $(seq 1 100); do
    if curl -sf "http://127.0.0.1:${OBS_PORT}/metrics" \
        -o target/obs_scrape_smoke.prom 2>/dev/null; then
        SCRAPED=1
        break
    fi
    sleep 0.25
done
wait "$OBS_PID"
[[ "$SCRAPED" == "1" ]] || { echo "ci: metrics endpoint never came up"; exit 1; }
grep -q '_total ' target/obs_scrape_smoke.prom
grep -q '_bucket{.*le="' target/obs_scrape_smoke.prom
# the --out exposition is the same snapshot written to disk
grep -q '_total ' target/obs_report_smoke.prom
grep -q '_bucket{.*le="' target/obs_report_smoke.prom

echo "== placement bench smoke (n=200, integrated vs post-hoc FFD) =="
timeout 600 cargo run --release -p graft -- bench-placement \
    --sizes 200 --out target/BENCH_placement_smoke.json
test -s target/BENCH_placement_smoke.json

echo "== transition bench smoke (n=200, live hot-swap, zero-drop) =="
# self-checking inside the bench: every request answered exactly once
# across the swap (dropped == rejected == 0), delta re-placement
# migrates <= / packs onto <= GPUs than the full-repack oracle per k
# and strictly fewer migrations summed over k in {1,5,20}%; the grep
# asserts the transition section actually landed in the JSON
timeout 600 cargo run --release -p graft -- bench-transition \
    --sizes 200 --requests 3000 --out target/BENCH_transition_smoke.json
test -s target/BENCH_transition_smoke.json
grep -q '"transition"' target/BENCH_transition_smoke.json

echo "== fault bench smoke (n=200, single-GPU failure + emergency replan) =="
# self-checking inside the bench: the GPU failure fires the emergency
# replan trigger, every request is answered exactly once across the
# failure + hot swap (zero silent losses), and the replacement plan
# places zero instances on the failed GPU (it bails hard otherwise);
# schema v2 also runs the predictive-vs-reactive comparison and bails
# unless the predictive leg vacated the victim before death and
# strictly reduced degraded-window drops; the greps assert the faults
# + predictive sections and the self-check verdict landed in the JSON
timeout 600 cargo run --release -p graft -- bench-faults \
    --sizes 200 --requests 400 --out target/BENCH_faults_smoke.json
test -s target/BENCH_faults_smoke.json
grep -q '"faults"' target/BENCH_faults_smoke.json
grep -q '"predictive"' target/BENCH_faults_smoke.json
grep -q '"degraded_window_drops"' target/BENCH_faults_smoke.json
grep -q '"predictive_ok":true' target/BENCH_faults_smoke.json

echo "ci: OK"
