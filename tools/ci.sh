#!/usr/bin/env bash
# CI pipeline: build, test, style gates, and a fast planner-bench smoke
# run (n=200) that also re-validates cached==uncached plan identity.
#
#   tools/ci.sh           full pipeline
#   tools/ci.sh --fast    build + test only
set -euo pipefail
cd "$(dirname "$0")/.."

FAST=0
[[ "${1:-}" == "--fast" ]] && FAST=1

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

if [[ "$FAST" == "1" ]]; then
    echo "ci: fast mode, skipping style gates and bench smoke"
    exit 0
fi

if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    cargo fmt --check
else
    echo "ci: rustfmt unavailable, skipping fmt check"
fi

if cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy -D warnings =="
    cargo clippy --workspace --all-targets -- -D warnings
else
    echo "ci: clippy unavailable, skipping lint"
fi

echo "== bench smoke (n=200) =="
cargo run --release -p graft -- bench-scheduler \
    --sizes 200 --reps 1 --out target/BENCH_scheduler_smoke.json
test -s target/BENCH_scheduler_smoke.json

echo "ci: OK"
