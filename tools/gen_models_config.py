#!/usr/bin/env python3
"""Generate configs/models.json — the canonical per-model layer tables.

This file is the single source of truth shared by the Python compile path
(python/compile/model.py builds the stand-in fragment networks from `dims`)
and the Rust profiler (rust/src/profiler/ embeds the JSON via include_str!).

The five models are *stand-ins* for the paper's TorchVision models
(Inception-v3, ResNet-101, VGG11, DeepLabV3-MobileNetV3-L, ViT-B16): same
layer counts (Table 2), per-layer relative compute cost and activation
transfer sizes shaped like the real nets (e.g. Mob's 71.1% reduction at
layer 1, ViT's uniform transformer blocks, VGG's front-loaded convs), and
totals calibrated to Table 2 (mobile latency on Nano/TX2; server latency at
batch=1, GPU share=30).

Run: python tools/gen_models_config.py   (idempotent; configs/models.json)
"""
import json
import os

HERE = os.path.dirname(os.path.abspath(__file__))
OUT = os.path.join(HERE, "..", "configs", "models.json")

INPUT_KB = 588.0  # paper §5.1: DNN input size ~588KB


def norm(ws):
    s = float(sum(ws))
    return [w / s for w in ws]


def model(name, full_name, layers, rate_rps, mobile_nano, mobile_tx2,
          server_ms, rel_cost, act_kb, dims, params_mb, common_starts):
    assert len(rel_cost) == layers and len(act_kb) == layers
    assert len(dims) == layers + 1
    return {
        "name": name,
        "full_name": full_name,
        "layers": layers,
        "rate_rps": rate_rps,
        "input_kb": INPUT_KB,
        # Table 2 calibration targets
        "mobile_ms_nano": mobile_nano,
        "mobile_ms_tx2": mobile_tx2,
        "server_ms_ref": server_ms,   # batch=1, share=30, full model
        # per-layer relative compute cost (sums to 1); shared shape for
        # mobile and server execution
        "rel_cost": norm(rel_cost),
        # output activation transfer size (KB) after layer i (1-indexed
        # layer i -> act_kb[i-1]); act before layer 1 is input_kb
        "act_kb": act_kb,
        # stand-in network widths: layer i maps dims[i-1] -> dims[i]
        "dims": dims,
        "params_mb": params_mb,  # GPU memory per instance (weights)
        # partition points Neurosurgeon commonly picks (compile set)
        "common_starts": common_starts,
    }


MODELS = [
    # Inception-v3: 17 mixed blocks; cost roughly uniform with heavier
    # middle; activations decay steadily -> partition point tracks
    # bandwidth smoothly (Fig 2 / Fig 6 "spread" behaviour).
    model(
        "inc", "Inception-v3", 17, 30.0, 165.0, 94.0, 29.0,
        rel_cost=[4, 5, 6, 7, 7, 8, 8, 8, 7, 7, 6, 6, 5, 5, 4, 4, 3],
        act_kb=[480, 380, 300, 240, 190, 150, 120, 100, 85, 75, 65,
                55, 45, 40, 35, 30, 4],
        dims=[256, 320, 320, 320, 320, 384, 384, 384, 320, 320, 320, 320,
              256, 256, 256, 256, 192, 64],
        params_mb=104.0,
        common_starts=[1, 2, 3, 4, 5, 6],
    ),
    # ResNet-101: 16 block groups; activation drops sharply at stage
    # boundaries -> polarised partitioning (paper §5.1).
    model(
        "res", "ResNet-101", 16, 30.0, 226.0, 114.0, 30.0,
        rel_cost=[5, 6, 6, 6, 7, 7, 7, 7, 7, 7, 7, 7, 6, 6, 5, 4],
        act_kb=[555, 552, 549, 250, 248, 246, 244, 120, 118, 116, 114,
                60, 59, 58, 30, 4],
        dims=[256, 320, 320, 320, 320, 320, 320, 320, 320, 320, 320, 320,
              320, 320, 320, 256, 64],
        params_mb=170.0,
        common_starts=[4, 8, 12],
    ),
    # VGG11: 6 coarse layers; convs front-loaded, huge early activations.
    model(
        "vgg", "VGG11", 6, 30.0, 147.0, 77.0, 6.0,
        rel_cost=[3, 5, 8, 9, 8, 7],
        act_kb=[440, 280, 160, 90, 50, 4],
        dims=[256, 512, 512, 448, 384, 320, 64],  # all multiples of 64
        params_mb=507.0,
        common_starts=[1, 2, 3],
    ),
    # DeepLabV3 MobileNetV3-L: 18 layers; layer 1 reduces transmission by
    # 71.1% vs raw input (paper §5.1) -> polarised at layer 1.
    model(
        "mob", "DeepLabV3-MobileNetV3-L", 18, 30.0, 84.0, 67.0, 19.0,
        rel_cost=[6, 5, 5, 5, 5, 5, 5, 6, 6, 6, 6, 6, 6, 6, 6, 6, 5, 5],
        act_kb=[170, 164, 158, 152, 146, 140, 134, 128, 122, 116, 110,
                104, 98, 92, 86, 80, 74, 40],
        dims=[128, 128, 128, 192, 192, 192, 192, 192, 192, 192, 192, 192,
              192, 192, 192, 128, 128, 128, 64],
        params_mb=42.0,
        common_starts=[1, 2, 3],
    ),
    # ViT-B16: patchify + 12 uniform transformer blocks + pool + head;
    # tokens keep a near-constant (large) activation until the head ->
    # polarised partitioning; 1 RPS (mobile latency 816ms on Nano).
    model(
        "vit", "ViT-B16", 15, 1.0, 816.0, 603.0, 58.0,
        rel_cost=[3, 7, 7, 7, 7, 7, 7, 7, 7, 7, 7, 7, 7, 2, 1],
        act_kb=[300, 300, 300, 300, 300, 300, 300, 300, 300, 300, 300,
                300, 300, 3, 4],
        dims=[384, 384, 384, 384, 384, 384, 384, 384, 384, 384, 384, 384,
              384, 384, 256, 64],  # all multiples of 64
        params_mb=330.0,
        common_starts=[1, 2],
    ),
]

CONFIG = {
    "input_kb": INPUT_KB,
    # analytical MPS GPU model (see DESIGN.md §2): latency of a fragment
    # at batch b, share s:
    #   lat(b, s) = T_frag_ms * (alpha + (1 - alpha) * b) * (ref_share/s)^gamma
    # Shares are requested in 1% units (as in the paper) but only become
    # *effective* in share_unit=5% steps — the SM-granularity rounding a
    # real GPU applies to MPS thread percentages.  This quantisation is
    # what produces the paper's resource margins (Fig 4 discreteness).
    "gpu": {
        "ref_share": 30.0,
        "share_gamma": 0.9,
        "batch_alpha": 0.6,
        "max_batch": 32,
        # instances run AOT-compiled executables, which exist only for
        # bucketed batch sizes (python/compile/aot.py) — the allocation
        # search is restricted to the same buckets.
        "batch_buckets": [1, 2, 4, 8, 16, 32],
        "share_unit": 5,
        "max_share": 100,
        "gpu_mem_mb": 16000.0,
        "act_mem_scale_mb_per_kb": 0.004,
        # energy model (Fig 21): E = sum over instances of
        # (p_share_w_per_pct * share + p_base_w) * busy_time
        "p_share_w_per_pct": 2.0,
        "p_base_w": 25.0,
    },
    "slo_ratio_default": 0.95,
    "models": MODELS,
}


def main():
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(CONFIG, f, indent=1)
        f.write("\n")
    print(f"wrote {os.path.abspath(OUT)}")


if __name__ == "__main__":
    main()
