//! Integration: failure domains.  Injected worker kills, GPU failures,
//! and poisoned queue shards must all drain cleanly — every submitted
//! request gets exactly one response (served or an explicit drop
//! notice), the health ledger records the damage, and `drain()` never
//! hangs on a dead stage's backlog.  Everything runs over both executor
//! cores ([`ExecutorMode::Threads`] and [`ExecutorMode::Pool`]).

mod common;

use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::time::Duration;

use graft::profiler::CostModel;
use graft::serving::{
    ExecutorMode, FaultEvent, FaultKind, FaultPlan, FaultyExecutor, Request,
    Server, ServerOptions,
};

use common::{cm, mock_executor, plan_for, watchdog};

const MODES: [ExecutorMode; 2] = [ExecutorMode::Threads, ExecutorMode::Pool];

fn opts(mode: ExecutorMode) -> ServerOptions {
    ServerOptions {
        time_scale: 0.0,
        drop_on_slo: false,
        mode,
        ..Default::default()
    }
}

/// Submit `n` requests for `client` at partition `p` onto `tx`.
fn submit_n(
    server: &Server,
    cm: &CostModel,
    model: &str,
    client: u32,
    p: usize,
    n: u32,
    tx: &mpsc::Sender<graft::serving::Response>,
) {
    let mi = cm.model_index(model).unwrap();
    let dims = &cm.config().models[mi].dims;
    for seq in 0..n {
        server.submit(
            Request {
                client_id: client,
                model: mi as u16,
                p: p as u16,
                seq,
                t_capture_ms: 0.0,
                upstream_ms: 0.0,
                budget_ms: 1e9,
                payload: vec![0.5; dims[p]],
            },
            tx.clone(),
        );
    }
}

/// A worker killed mid-batch (injected [`FaultKind::WorkerKill`] on the
/// first executed batch): the doomed batch gets drop notices, the
/// instance retires into the health ledger, and the drain still
/// accounts for every request — zero silent losses.
#[test]
fn worker_kill_mid_batch_drains_with_notices() {
    let _wd = watchdog("worker_kill_mid_batch", Duration::from_secs(120));
    for mode in MODES {
        let cm = cm();
        let plan = plan_for(
            &cm,
            "inc",
            &[(0, 2, 150.0, 30.0), (1, 3, 150.0, 30.0), (2, 3, 150.0, 30.0)],
        );
        let faults = Arc::new(FaultPlan::new(
            0,
            vec![FaultEvent { at_tick: 1, kind: FaultKind::WorkerKill }],
        ));
        let server = Server::start(
            Arc::new(FaultyExecutor::new(mock_executor(&cm), faults.clone())),
            &cm,
            &plan,
            opts(mode),
        );
        let (tx, rx) = mpsc::channel();
        let per_client = 20u32;
        for c in 0..3u32 {
            let p = if c == 0 { 2 } else { 3 };
            submit_n(&server, &cm, "inc", c, p, per_client, &tx);
        }
        drop(tx);
        // the drain flushes whatever a dead stage stranded, so after it
        // returns every request has reached a final outcome
        server.drain();
        let responses: Vec<_> = rx.iter().collect();
        assert_eq!(responses.len(), 60, "{mode:?}: silent loss");
        let dropped = responses.iter().filter(|r| r.dropped).count();
        assert!(dropped >= 1, "{mode:?}: the killed batch must drop");
        assert!(
            server.counters.exec_panics.load(Ordering::Relaxed) >= 1,
            "{mode:?}"
        );
        assert_eq!(server.health().dead_instance_count(), 1, "{mode:?}");
        assert!(server.health().degraded(), "{mode:?}");
        assert_eq!(faults.injected().len(), 1, "{mode:?}");
    }
}

/// Total failure mid-stream: a backlog is queued, then every instance
/// dies at once (`fail_gpu` on the unplaced sentinel).  Requests
/// submitted before *and* after the failure all get explicit drop
/// notices — never a hang, never a silent loss.
#[test]
fn gpu_failure_mid_stream_yields_notices_not_hangs() {
    let _wd = watchdog("gpu_failure_mid_stream", Duration::from_secs(120));
    for mode in MODES {
        let cm = cm();
        let plan = plan_for(&cm, "vgg", &[(0, 2, 120.0, 30.0)]);
        let server =
            Server::start(mock_executor(&cm), &cm, &plan, opts(mode));
        let total_instances: usize = server.stage_instances().iter().sum();
        let (tx, rx) = mpsc::channel();
        submit_n(&server, &cm, "vgg", 0, 2, 15, &tx);
        // unplaced plans put every instance on the NO_GPU sentinel, so
        // failing it is the whole-cluster failure domain
        let killed = server.fail_gpu(u32::MAX);
        assert_eq!(killed, total_instances, "{mode:?}");
        // post-failure submits hit the dead-stage fast path
        let mi = cm.model_index("vgg").unwrap();
        let dims = &cm.config().models[mi].dims;
        for seq in 100..115u32 {
            server.submit(
                Request {
                    client_id: 0,
                    model: mi as u16,
                    p: 2,
                    seq,
                    t_capture_ms: 0.0,
                    upstream_ms: 0.0,
                    budget_ms: 1e9,
                    payload: vec![0.5; dims[2]],
                },
                tx.clone(),
            );
        }
        drop(tx);
        server.drain();
        let responses: Vec<_> = rx.iter().collect();
        assert_eq!(responses.len(), 30, "{mode:?}: silent loss");
        // pre-failure items may have been served before the kill landed;
        // everything after it must be an explicit notice
        assert!(
            responses.iter().filter(|r| r.dropped).count() >= 15,
            "{mode:?}"
        );
        let health = server.health();
        assert_eq!(health.dead_instance_count(), total_instances, "{mode:?}");
        assert_eq!(health.failed_gpus(), vec![u32::MAX], "{mode:?}");
    }
}

/// A queue shard poisoned mid-drain (the way a panicking consumer would
/// leave it): the next acquisition recovers the lock, counts it, and
/// serving continues — every request still served.
#[test]
fn poisoned_shard_recovers_mid_drain() {
    let _wd = watchdog("poisoned_shard", Duration::from_secs(120));
    for mode in MODES {
        let cm = cm();
        let plan = plan_for(
            &cm,
            "inc",
            &[(0, 2, 150.0, 30.0), (1, 3, 150.0, 30.0), (2, 3, 150.0, 30.0)],
        );
        let server =
            Server::start(mock_executor(&cm), &cm, &plan, opts(mode));
        let (tx, rx) = mpsc::channel();
        for c in 0..3u32 {
            let p = if c == 0 { 2 } else { 3 };
            submit_n(&server, &cm, "inc", c, p, 10, &tx);
        }
        // poison every stage's first shard while the backlog drains
        for stage in 0..server.stage_instances().len() {
            server.poison_stage_queue(stage, 0);
        }
        for c in 0..3u32 {
            let p = if c == 0 { 2 } else { 3 };
            let mi = cm.model_index("inc").unwrap();
            let dims = &cm.config().models[mi].dims;
            for seq in 50..60u32 {
                server.submit(
                    Request {
                        client_id: c,
                        model: mi as u16,
                        p: p as u16,
                        seq,
                        t_capture_ms: 0.0,
                        upstream_ms: 0.0,
                        budget_ms: 1e9,
                        payload: vec![0.5; dims[p]],
                    },
                    tx.clone(),
                );
            }
        }
        drop(tx);
        server.drain();
        let responses: Vec<_> = rx.iter().collect();
        assert_eq!(responses.len(), 60, "{mode:?}: silent loss");
        assert!(
            responses.iter().all(|r| !r.dropped),
            "{mode:?}: poisoning must not drop requests"
        );
        assert!(
            server.poison_recoveries() >= 1,
            "{mode:?}: no recovery counted"
        );
        assert!(server.health().degraded(), "{mode:?}");
    }
}

/// `kill_instance` is idempotent and the second call reports it.
#[test]
fn kill_instance_is_idempotent() {
    let _wd = watchdog("kill_idempotent", Duration::from_secs(60));
    for mode in MODES {
        let cm = cm();
        let plan = plan_for(&cm, "vgg", &[(0, 2, 120.0, 30.0)]);
        let server =
            Server::start(mock_executor(&cm), &cm, &plan, opts(mode));
        assert!(server.kill_instance(0, 0));
        assert!(!server.kill_instance(0, 0), "{mode:?}: double-kill");
        assert!(!server.kill_instance(0, 999), "{mode:?}: unknown instance");
        assert_eq!(server.health().dead_instance_count(), 1, "{mode:?}");
        server.drain();
    }
}

/// After an instance death the health ledger's failure epoch moves, and
/// `note_recovery` (what the replan controller calls after the swap)
/// moves the recovery epoch past it.
#[test]
fn health_epochs_order_failure_then_recovery() {
    let _wd = watchdog("health_epochs", Duration::from_secs(60));
    let cm = cm();
    let plan = plan_for(&cm, "vgg", &[(0, 2, 120.0, 30.0)]);
    let server = Server::start(
        mock_executor(&cm),
        &cm,
        &plan,
        opts(ExecutorMode::Pool),
    );
    let health = server.health();
    assert!(!health.degraded());
    server.fail_gpu(u32::MAX);
    // one epoch bump for the GPU plus one per instance death
    let fe = health.failure_epoch();
    assert!(fe > 1);
    assert!(health.degraded());
    for _ in 0..fe {
        health.note_recovery();
    }
    assert!(health.recovery_epoch() >= fe);
    assert!(!health.degraded());
    // the ledger keeps the failure before the recovery
    let events = health.events();
    let down = events
        .iter()
        .find(|e| e.kind == graft::serving::HealthEventKind::GpuDown)
        .expect("GpuDown recorded");
    let rec = events
        .iter()
        .find(|e| e.kind == graft::serving::HealthEventKind::Recovered)
        .expect("Recovered recorded");
    assert!(down.seq < rec.seq);
    server.drain();
}

/// A rejected push (closed queue — e.g. a submit racing shutdown) never
/// loses the request silently: the client still gets an explicit drop
/// notice and the rejection is counted.
#[test]
fn rejected_push_still_notices_client() {
    let _wd = watchdog("rejected_push_notice", Duration::from_secs(60));
    for mode in MODES {
        let cm = cm();
        let plan = plan_for(&cm, "vgg", &[(0, 2, 120.0, 30.0)]);
        let server =
            Server::start(mock_executor(&cm), &cm, &plan, opts(mode));
        // drain closes every stage queue but leaves the server callable
        server.drain();
        let (tx, rx) = mpsc::channel();
        submit_n(&server, &cm, "vgg", 0, 2, 5, &tx);
        drop(tx);
        let responses: Vec<_> = rx.iter().collect();
        assert_eq!(responses.len(), 5, "{mode:?}: silent loss");
        assert!(responses.iter().all(|r| r.dropped), "{mode:?}");
        assert!(
            server.counters.rejected.load(Ordering::Relaxed) >= 5,
            "{mode:?}"
        );
    }
}
