//! Integration: failure domains.  Injected worker kills, GPU failures,
//! and poisoned queue shards must all drain cleanly — every submitted
//! request gets exactly one response (served or an explicit drop
//! notice), the health ledger records the damage, and `drain()` never
//! hangs on a dead stage's backlog.  Everything runs over both executor
//! cores ([`ExecutorMode::Threads`] and [`ExecutorMode::Pool`]).

mod common;

use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::time::Duration;

use graft::coordinator::placement::{place, stamp};
use graft::coordinator::{
    ClientId, ControllerOptions, ExecutionPlan, FragmentSpec,
    ReplanController, Scheduler, SchedulerOptions, TickOutcome,
};
use graft::profiler::CostModel;
use graft::runtime::transition::LiveServer;
use graft::serving::{
    ExecutorMode, FailureDomain, FaultDomain, FaultEvent, FaultKind,
    FaultPlan, FaultyExecutor, Request, Server, ServerOptions,
};

use common::{cm, mock_executor, plan_for, watchdog};

const MODES: [ExecutorMode; 2] = [ExecutorMode::Threads, ExecutorMode::Pool];

fn opts(mode: ExecutorMode) -> ServerOptions {
    ServerOptions {
        time_scale: 0.0,
        drop_on_slo: false,
        mode,
        ..Default::default()
    }
}

/// Submit `n` requests for `client` at partition `p` onto `tx`.
fn submit_n(
    server: &Server,
    cm: &CostModel,
    model: &str,
    client: u32,
    p: usize,
    n: u32,
    tx: &mpsc::Sender<graft::serving::Response>,
) {
    let mi = cm.model_index(model).unwrap();
    let dims = &cm.config().models[mi].dims;
    for seq in 0..n {
        server.submit(
            Request {
                client_id: client,
                model: mi as u16,
                p: p as u16,
                seq,
                t_capture_ms: 0.0,
                upstream_ms: 0.0,
                budget_ms: 1e9,
                payload: vec![0.5; dims[p]],
            },
            tx.clone(),
        );
    }
}

/// A worker killed mid-batch (injected [`FaultKind::WorkerKill`] on the
/// first executed batch): the doomed batch gets drop notices, the
/// instance retires into the health ledger, and the drain still
/// accounts for every request — zero silent losses.
#[test]
fn worker_kill_mid_batch_drains_with_notices() {
    let _wd = watchdog("worker_kill_mid_batch", Duration::from_secs(120));
    for mode in MODES {
        let cm = cm();
        let plan = plan_for(
            &cm,
            "inc",
            &[(0, 2, 150.0, 30.0), (1, 3, 150.0, 30.0), (2, 3, 150.0, 30.0)],
        );
        let faults = Arc::new(FaultPlan::new(
            0,
            vec![FaultEvent { at_tick: 1, kind: FaultKind::WorkerKill }],
        ));
        let server = Server::start(
            Arc::new(FaultyExecutor::new(mock_executor(&cm), faults.clone())),
            &cm,
            &plan,
            opts(mode),
        );
        let (tx, rx) = mpsc::channel();
        let per_client = 20u32;
        for c in 0..3u32 {
            let p = if c == 0 { 2 } else { 3 };
            submit_n(&server, &cm, "inc", c, p, per_client, &tx);
        }
        drop(tx);
        // the drain flushes whatever a dead stage stranded, so after it
        // returns every request has reached a final outcome
        server.drain();
        let responses: Vec<_> = rx.iter().collect();
        assert_eq!(responses.len(), 60, "{mode:?}: silent loss");
        let dropped = responses.iter().filter(|r| r.dropped).count();
        assert!(dropped >= 1, "{mode:?}: the killed batch must drop");
        assert!(
            server.counters.exec_panics.load(Ordering::Relaxed) >= 1,
            "{mode:?}"
        );
        assert_eq!(server.health().dead_instance_count(), 1, "{mode:?}");
        assert!(server.health().degraded(), "{mode:?}");
        assert_eq!(faults.injected().len(), 1, "{mode:?}");
    }
}

/// Total failure mid-stream: a backlog is queued, then every instance
/// dies at once (`fail_gpu` on the unplaced sentinel).  Requests
/// submitted before *and* after the failure all get explicit drop
/// notices — never a hang, never a silent loss.
#[test]
fn gpu_failure_mid_stream_yields_notices_not_hangs() {
    let _wd = watchdog("gpu_failure_mid_stream", Duration::from_secs(120));
    for mode in MODES {
        let cm = cm();
        let plan = plan_for(&cm, "vgg", &[(0, 2, 120.0, 30.0)]);
        let server =
            Server::start(mock_executor(&cm), &cm, &plan, opts(mode));
        let total_instances: usize = server.stage_instances().iter().sum();
        let (tx, rx) = mpsc::channel();
        submit_n(&server, &cm, "vgg", 0, 2, 15, &tx);
        // unplaced plans put every instance on the NO_GPU sentinel, so
        // failing it is the whole-cluster failure domain
        let killed = server.fail_gpu(u32::MAX);
        assert_eq!(killed, total_instances, "{mode:?}");
        // post-failure submits hit the dead-stage fast path
        let mi = cm.model_index("vgg").unwrap();
        let dims = &cm.config().models[mi].dims;
        for seq in 100..115u32 {
            server.submit(
                Request {
                    client_id: 0,
                    model: mi as u16,
                    p: 2,
                    seq,
                    t_capture_ms: 0.0,
                    upstream_ms: 0.0,
                    budget_ms: 1e9,
                    payload: vec![0.5; dims[2]],
                },
                tx.clone(),
            );
        }
        drop(tx);
        server.drain();
        let responses: Vec<_> = rx.iter().collect();
        assert_eq!(responses.len(), 30, "{mode:?}: silent loss");
        // pre-failure items may have been served before the kill landed;
        // everything after it must be an explicit notice
        assert!(
            responses.iter().filter(|r| r.dropped).count() >= 15,
            "{mode:?}"
        );
        let health = server.health();
        assert_eq!(health.dead_instance_count(), total_instances, "{mode:?}");
        assert_eq!(health.failed_gpus(), vec![u32::MAX], "{mode:?}");
    }
}

/// A queue shard poisoned mid-drain (the way a panicking consumer would
/// leave it): the next acquisition recovers the lock, counts it, and
/// serving continues — every request still served.
#[test]
fn poisoned_shard_recovers_mid_drain() {
    let _wd = watchdog("poisoned_shard", Duration::from_secs(120));
    for mode in MODES {
        let cm = cm();
        let plan = plan_for(
            &cm,
            "inc",
            &[(0, 2, 150.0, 30.0), (1, 3, 150.0, 30.0), (2, 3, 150.0, 30.0)],
        );
        let server =
            Server::start(mock_executor(&cm), &cm, &plan, opts(mode));
        let (tx, rx) = mpsc::channel();
        for c in 0..3u32 {
            let p = if c == 0 { 2 } else { 3 };
            submit_n(&server, &cm, "inc", c, p, 10, &tx);
        }
        // poison every stage's first shard while the backlog drains
        for stage in 0..server.stage_instances().len() {
            server.poison_stage_queue(stage, 0);
        }
        for c in 0..3u32 {
            let p = if c == 0 { 2 } else { 3 };
            let mi = cm.model_index("inc").unwrap();
            let dims = &cm.config().models[mi].dims;
            for seq in 50..60u32 {
                server.submit(
                    Request {
                        client_id: c,
                        model: mi as u16,
                        p: p as u16,
                        seq,
                        t_capture_ms: 0.0,
                        upstream_ms: 0.0,
                        budget_ms: 1e9,
                        payload: vec![0.5; dims[p]],
                    },
                    tx.clone(),
                );
            }
        }
        drop(tx);
        server.drain();
        let responses: Vec<_> = rx.iter().collect();
        assert_eq!(responses.len(), 60, "{mode:?}: silent loss");
        assert!(
            responses.iter().all(|r| !r.dropped),
            "{mode:?}: poisoning must not drop requests"
        );
        assert!(
            server.poison_recoveries() >= 1,
            "{mode:?}: no recovery counted"
        );
        assert!(server.health().degraded(), "{mode:?}");
    }
}

/// `kill_instance` is idempotent and the second call reports it.
#[test]
fn kill_instance_is_idempotent() {
    let _wd = watchdog("kill_idempotent", Duration::from_secs(60));
    for mode in MODES {
        let cm = cm();
        let plan = plan_for(&cm, "vgg", &[(0, 2, 120.0, 30.0)]);
        let server =
            Server::start(mock_executor(&cm), &cm, &plan, opts(mode));
        assert!(server.kill_instance(0, 0));
        assert!(!server.kill_instance(0, 0), "{mode:?}: double-kill");
        assert!(!server.kill_instance(0, 999), "{mode:?}: unknown instance");
        assert_eq!(server.health().dead_instance_count(), 1, "{mode:?}");
        server.drain();
    }
}

/// After an instance death the health ledger's failure epoch moves, and
/// `note_recovery` (what the replan controller calls after the swap)
/// moves the recovery epoch past it.
#[test]
fn health_epochs_order_failure_then_recovery() {
    let _wd = watchdog("health_epochs", Duration::from_secs(60));
    let cm = cm();
    let plan = plan_for(&cm, "vgg", &[(0, 2, 120.0, 30.0)]);
    let server = Server::start(
        mock_executor(&cm),
        &cm,
        &plan,
        opts(ExecutorMode::Pool),
    );
    let health = server.health();
    assert!(!health.degraded());
    server.fail_gpu(u32::MAX);
    // one epoch bump for the GPU plus one per instance death
    let fe = health.failure_epoch();
    assert!(fe > 1);
    assert!(health.degraded());
    for _ in 0..fe {
        health.note_recovery();
    }
    assert!(health.recovery_epoch() >= fe);
    assert!(!health.degraded());
    // the ledger keeps the failure before the recovery
    let events = health.events();
    let down = events
        .iter()
        .find(|e| e.kind == graft::serving::HealthEventKind::GpuDown)
        .expect("GpuDown recorded");
    let rec = events
        .iter()
        .find(|e| e.kind == graft::serving::HealthEventKind::Recovered)
        .expect("Recovered recorded");
    assert!(down.seq < rec.seq);
    server.drain();
}

/// Correlated-failure domain under chaos: every stamped GPU shares one
/// failure domain, so when the seeded chaos plan fires a GPU failure
/// the *whole* rack dies at once mid-load.  Every submitted request —
/// before and after the domain death — still gets exactly one response
/// (multiset equality over (client, seq)), in both executor modes.
#[test]
fn correlated_domain_failure_never_silently_loses() {
    let _wd = watchdog("correlated_domain_chaos", Duration::from_secs(180));
    for mode in MODES {
        let cm = cm();
        let mut plan = plan_for(
            &cm,
            "inc",
            &[(0, 2, 150.0, 30.0), (1, 3, 150.0, 30.0), (2, 3, 150.0, 30.0)],
        );
        let placement = place(&cm, &plan, None).expect("placeable plan");
        stamp(&mut plan, &placement);
        let mut gpus: Vec<u32> =
            plan.stages().flat_map(|s| s.gpus.iter().copied()).collect();
        gpus.sort_unstable();
        gpus.dedup();
        assert!(!gpus.is_empty(), "{mode:?}: plan must be stamped");
        // one domain holding every stamped GPU: any GpuFail chaos event
        // takes the whole fleet down together
        let domains = vec![FailureDomain {
            name: "rack0".into(),
            gpus: gpus.clone(),
        }];
        let faults = Arc::new(FaultPlan::chaos_with_domains(
            7,
            40,
            &domains,
            &[],
            4,
        ));
        let server = Server::start(
            Arc::new(FaultyExecutor::new(mock_executor(&cm), faults.clone())),
            &cm,
            &plan,
            opts(mode),
        );
        let (tx, rx) = mpsc::channel();
        let mi = cm.model_index("inc").unwrap();
        let dims = &cm.config().models[mi].dims;
        let per_client = 40u32;
        for seq in 0..per_client {
            for c in 0..3u32 {
                let p = if c == 0 { 2usize } else { 3 };
                server.submit(
                    Request {
                        client_id: c,
                        model: mi as u16,
                        p: p as u16,
                        seq,
                        t_capture_ms: 0.0,
                        upstream_ms: 0.0,
                        budget_ms: 1e9,
                        payload: vec![0.5; dims[p]],
                    },
                    tx.clone(),
                );
                // control-domain chaos ticks once per submit; a GPU
                // failure event arrives as the complete domain
                for kind in faults.tick(FaultDomain::Control) {
                    if let FaultKind::GpuFail { gpu } = kind {
                        server.fail_gpu(gpu);
                    }
                }
            }
        }
        drop(tx);
        server.drain();
        let responses: Vec<_> = rx.iter().collect();
        assert_eq!(
            responses.len(),
            3 * per_client as usize,
            "{mode:?}: silent loss"
        );
        // multiset equality: every (client, seq) answered exactly once
        let mut want: Vec<(u32, u32)> = (0..3u32)
            .flat_map(|c| (0..per_client).map(move |s| (c, s)))
            .collect();
        let mut got: Vec<(u32, u32)> =
            responses.iter().map(|r| (r.client_id, r.seq)).collect();
        want.sort_unstable();
        got.sort_unstable();
        assert_eq!(got, want, "{mode:?}: response multiset mismatch");
        // the domain fired as a unit: every member GPU is down
        let failed = server.health().failed_gpus();
        assert_eq!(failed, gpus, "{mode:?}: partial domain failure");
    }
}

/// Counts how many instances `plan` stamps onto `gpu`.
fn instances_on(plan: &ExecutionPlan, gpu: u32) -> usize {
    plan.stages()
        .map(|s| s.gpus.iter().filter(|&&g| g == gpu).count())
        .sum()
}

/// Shared setup for the controller-path tests: a scheduler-planned
/// (and FFD-stamped) fleet behind a [`LiveServer`], with the drift
/// trigger disabled so only the failure paths can fire.
fn controlled_fleet(
    cm: &CostModel,
) -> (Arc<LiveServer>, ReplanController, u32) {
    let mi = cm.model_index("inc").unwrap();
    let specs: Vec<FragmentSpec> = (0..6)
        .map(|i| {
            FragmentSpec::single(ClientId(i), mi, 3, 130.0 + i as f64, 1.0)
        })
        .collect();
    let sched =
        Arc::new(Scheduler::new(cm.clone(), SchedulerOptions::default()));
    let (plan, _) = sched.plan(&specs);
    let live = Arc::new(LiveServer::start(
        mock_executor(cm),
        cm,
        &plan,
        opts(ExecutorMode::Pool),
    ));
    let ctrl = ReplanController::new(
        sched,
        live.clone(),
        specs,
        ControllerOptions {
            drift_threshold: 1e12,
            min_requests: u64::MAX,
            ..Default::default()
        },
    );
    let victim = live
        .plan()
        .stages()
        .flat_map(|s| s.gpus.iter().copied())
        .min()
        .expect("scheduler stamps its plans");
    (live, ctrl, victim)
}

/// Regression: `dead_gpus` used to only ever grow.  A GPU that fails
/// and later recovers must leave the controller's hard avoid-set, and
/// the recovery replan (a full repack) must actually place instances
/// back on the restored GPU.
#[test]
fn gpu_recovery_lifts_dead_set_and_replan_reuses_gpu() {
    let _wd = watchdog("gpu_recovery_replan", Duration::from_secs(180));
    let cm = cm();
    let (live, ctrl, victim) = controlled_fleet(&cm);
    assert!(instances_on(&live.plan(), victim) > 0);

    live.server().fail_gpu(victim);
    match ctrl.tick() {
        TickOutcome::EmergencyReplanned {
            failed_gpus,
            domain_excluded,
            ..
        } => {
            assert_eq!(failed_gpus, vec![victim]);
            assert!(domain_excluded.is_empty(), "no domains configured");
        }
        other => panic!("expected emergency replan, got {other:?}"),
    }
    assert_eq!(ctrl.dead_gpus(), vec![victim]);
    assert_eq!(
        instances_on(&live.plan(), victim),
        0,
        "emergency plan landed on the dead GPU"
    );

    // the GPU comes back; the controller drains the recovery, lifts
    // the hard avoid-set and repacks onto the restored capacity
    live.server().recover_gpu(victim);
    match ctrl.tick() {
        TickOutcome::RecoveryReplanned { recovered_gpus, .. } => {
            assert_eq!(recovered_gpus, vec![victim]);
        }
        other => panic!("expected recovery replan, got {other:?}"),
    }
    assert!(ctrl.dead_gpus().is_empty(), "dead set must shrink");
    assert!(
        instances_on(&live.plan(), victim) > 0,
        "recovery repack must reuse the restored GPU"
    );

    drop(ctrl);
    match Arc::try_unwrap(live) {
        Ok(l) => l.shutdown(),
        Err(l) => {
            l.server().drain();
        }
    }
}

/// Partial-GPU degradation: a full-share loss on a live GPU fires a
/// [`TickOutcome::DegradeRebalanced`] that folds the residual (zero)
/// capacity into placement — the degraded GPU is vacated, the fleet
/// keeps serving, and a recovery later restores it.
#[test]
fn partial_degradation_rebalances_to_residual_capacity() {
    let _wd = watchdog("partial_degradation", Duration::from_secs(180));
    let cm = cm();
    let (live, ctrl, victim) = controlled_fleet(&cm);
    assert!(instances_on(&live.plan(), victim) > 0);

    let full_share = cm.config().gpu.max_share;
    live.server().degrade_gpu(victim, full_share, 0.0);
    match ctrl.tick() {
        TickOutcome::DegradeRebalanced { degraded_gpus, .. } => {
            assert_eq!(degraded_gpus, vec![victim]);
        }
        other => panic!("expected degrade rebalance, got {other:?}"),
    }
    assert_eq!(
        ctrl.degraded_gpus(),
        vec![(
            victim,
            graft::serving::GpuDegradation {
                share_loss: full_share,
                mem_loss_mb: 0.0,
            }
        )]
    );
    assert_eq!(
        instances_on(&live.plan(), victim),
        0,
        "a zero-residual GPU must be vacated"
    );
    // the rebalanced fleet still serves
    let mi = cm.model_index("inc").unwrap();
    let dims = &cm.config().models[mi].dims;
    let (tx, rx) = mpsc::channel();
    for seq in 0..30u32 {
        for c in 0..6u32 {
            live.submit(
                Request {
                    client_id: c,
                    model: mi as u16,
                    p: 3,
                    seq,
                    t_capture_ms: 0.0,
                    upstream_ms: 0.0,
                    budget_ms: 1e9,
                    payload: vec![0.5; dims[3]],
                },
                tx.clone(),
            );
        }
    }
    drop(tx);
    let responses: Vec<_> = rx.iter().collect();
    assert_eq!(responses.len(), 180, "silent loss after rebalance");
    assert!(responses.iter().all(|r| !r.dropped));

    // recovery lifts the degradation and the repack may use it again
    live.server().recover_gpu(victim);
    match ctrl.tick() {
        TickOutcome::RecoveryReplanned { recovered_gpus, .. } => {
            assert_eq!(recovered_gpus, vec![victim]);
        }
        other => panic!("expected recovery replan, got {other:?}"),
    }
    assert!(ctrl.degraded_gpus().is_empty());
    assert!(instances_on(&live.plan(), victim) > 0);

    drop(ctrl);
    match Arc::try_unwrap(live) {
        Ok(l) => l.shutdown(),
        Err(l) => {
            l.server().drain();
        }
    }
}

/// A rejected push (closed queue — e.g. a submit racing shutdown) never
/// loses the request silently: the client still gets an explicit drop
/// notice and the rejection is counted.
#[test]
fn rejected_push_still_notices_client() {
    let _wd = watchdog("rejected_push_notice", Duration::from_secs(60));
    for mode in MODES {
        let cm = cm();
        let plan = plan_for(&cm, "vgg", &[(0, 2, 120.0, 30.0)]);
        let server =
            Server::start(mock_executor(&cm), &cm, &plan, opts(mode));
        // drain closes every stage queue but leaves the server callable
        server.drain();
        let (tx, rx) = mpsc::channel();
        submit_n(&server, &cm, "vgg", 0, 2, 5, &tx);
        drop(tx);
        let responses: Vec<_> = rx.iter().collect();
        assert_eq!(responses.len(), 5, "{mode:?}: silent loss");
        assert!(responses.iter().all(|r| r.dropped), "{mode:?}");
        assert!(
            server.counters.rejected.load(Ordering::Relaxed) >= 5,
            "{mode:?}"
        );
    }
}
