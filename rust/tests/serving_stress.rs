//! Stress: 64 instances × 10k requests through the pooled serving core.
//!
//! `#[ignore]`d by default (seconds of wall time, heavy contention);
//! run via `tools/ci.sh --stress` or
//! `cargo test --release --test serving_stress -- --ignored`.

mod common;

use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::time::Duration;

use graft::serving::{ExecutorMode, Request, Server, ServerOptions};

use common::{cm, mock_executor, plan_for, watchdog};

const CLIENTS: u32 = 32;
const PER_CLIENT: u32 = 313; // 32 × 313 = 10 016 requests
const INSTANCES: u32 = 64;

#[test]
#[ignore = "stress test: run via tools/ci.sh --stress"]
fn pooled_path_64_instances_10k_requests() {
    let _wd = watchdog("serving_stress", Duration::from_secs(300));
    let cm = cm();
    let specs: Vec<(u32, usize, f64, f64)> = (0..CLIENTS)
        .map(|c| (c, 2 + (c as usize % 2), 1e9, 30.0))
        .collect();
    let mut plan = plan_for(&cm, "inc", &specs);

    // widen the planned instance counts until the plan provisions
    // exactly INSTANCES slots (the planner sizes for modeled demand;
    // the stress test wants maximum slot-level concurrency instead)
    let mut n_stages = 0u32;
    for set in &mut plan.sets {
        set.shared.alloc.instances = 1;
        n_stages += 1;
        for a in plan_members(set) {
            a.instances = 1;
            n_stages += 1;
        }
    }
    assert!(
        (1..=INSTANCES).contains(&n_stages),
        "unexpected stage count {n_stages}"
    );
    let mut remaining = INSTANCES - n_stages;
    'grow: loop {
        for set in &mut plan.sets {
            if remaining == 0 {
                break 'grow;
            }
            set.shared.alloc.instances += 1;
            remaining -= 1;
            for a in plan_members(set) {
                if remaining == 0 {
                    break 'grow;
                }
                a.instances += 1;
                remaining -= 1;
            }
        }
    }
    let provisioned: u32 =
        plan.stages().map(|s| s.alloc.instances).sum();
    assert_eq!(provisioned, INSTANCES);

    let server = Server::start(
        mock_executor(&cm),
        &cm,
        &plan,
        ServerOptions {
            time_scale: 0.0,
            drop_on_slo: false,
            mode: ExecutorMode::Pool,
            ..Default::default()
        },
    );
    let cpus = std::thread::available_parallelism()
        .map(|c| c.get())
        .unwrap_or(4);
    assert!(
        server.thread_count() <= cpus.max(1).min(INSTANCES as usize),
        "pool spawned {} workers",
        server.thread_count()
    );

    let mi = cm.model_index("inc").unwrap();
    let dims = cm.config().models[mi].dims.clone();
    let total = (CLIENTS * PER_CLIENT) as usize;
    let (tx, rx) = mpsc::channel();
    let done = AtomicBool::new(false);

    let (seen, max_depth) = std::thread::scope(|scope| {
        let server_ref = &server;
        let done_ref = &done;
        let collector = scope.spawn(move || {
            let mut seen: HashSet<(u32, u32)> = HashSet::with_capacity(total);
            for _ in 0..total {
                let r = rx
                    .recv_timeout(Duration::from_secs(120))
                    .expect("response lost (queue wedged?)");
                assert!(!r.dropped, "unexpected drop {}/{}", r.client_id, r.seq);
                assert!(
                    seen.insert((r.client_id, r.seq)),
                    "duplicate response {}/{}",
                    r.client_id,
                    r.seq
                );
            }
            seen
        });
        let sampler = scope.spawn(move || {
            let mut max_depth = 0usize;
            while !done_ref.load(Ordering::SeqCst) {
                let d: usize = server_ref.queue_depths().iter().sum();
                max_depth = max_depth.max(d);
                std::thread::sleep(Duration::from_millis(2));
            }
            max_depth
        });
        for seq in 0..PER_CLIENT {
            for c in 0..CLIENTS {
                let p = 2 + (c as usize % 2);
                server_ref.submit(
                    Request {
                        client_id: c,
                        model: mi as u16,
                        p: p as u16,
                        seq,
                        t_capture_ms: 0.0,
                        upstream_ms: 0.0,
                        budget_ms: 1e9,
                        payload: vec![0.5; dims[p]],
                    },
                    tx.clone(),
                );
            }
        }
        drop(tx);
        let seen = collector.join().expect("collector");
        done.store(true, Ordering::SeqCst);
        let max_depth = sampler.join().expect("sampler");
        (seen, max_depth)
    });

    // zero lost, zero duplicated
    assert_eq!(seen.len(), total);
    // queue lengths stay bounded by the outstanding request count and
    // fully drain
    assert!(max_depth <= total, "queue depth {max_depth} > {total}");
    assert!(server.queue_depths().iter().all(|&d| d == 0));
    let served =
        server.counters.served.load(std::sync::atomic::Ordering::Relaxed);
    let rejected =
        server.counters.rejected.load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(served as usize, total);
    assert_eq!(rejected, 0);
    server.shutdown();
}

/// Mutable access to the align-stage allocs of a set (helper keeping the
/// instance-widening loops readable).
fn plan_members(
    set: &mut graft::coordinator::RealignedSet,
) -> Vec<&mut graft::profiler::Alloc> {
    set.members
        .iter_mut()
        .filter_map(|m| m.align.as_mut().map(|a| &mut a.alloc))
        .collect()
}
