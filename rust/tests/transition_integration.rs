//! Live-reconfiguration integration: hot-swap an execution plan under
//! load and prove the transition invariants — zero dropped requests,
//! exactly-once execution (the response multiset equals a no-swap
//! run's), graceful ordered drain (no closed-queue rejections), and
//! the replan controller driving the whole monitor → re-plan →
//! redeploy loop from observed arrival counters.

mod common;

use std::sync::{mpsc, Arc};
use std::time::Duration;

use graft::coordinator::scheduler::{Scheduler, SchedulerOptions};
use graft::coordinator::{ClientId, ControllerOptions, FragmentSpec, ReplanController, TickOutcome};
use graft::runtime::{diff_plans, LiveServer};
use graft::serving::{ExecutorMode, Request, RequestSink, ServerOptions};
use graft::util::Rng;

use common::{cm, mock_executor, plan_for, watchdog};

/// Deterministic payload for (client, seq): identical across runs, so
/// the mock executor's outputs are comparable bit-for-bit.
fn payload(c: u32, seq: u32, dim: usize) -> Vec<f32> {
    let mut rng = Rng::seed_from_u64(((c as u64) << 32) | seq as u64 | 1);
    (0..dim).map(|_| rng.normal() as f32).collect()
}

/// Drive 3 clients × 60 requests through a live server; when `swap` is
/// set, hot-swap to a re-planned (budget/rate-perturbed, same clients
/// and points) plan a third of the way in.  Returns the sorted
/// response multiset (client, seq, output bits).
fn run_workload(swap: bool, time_scale: f64) -> Vec<(u32, u32, Vec<u32>)> {
    let cm = cm();
    let plan_a = plan_for(
        &cm,
        "inc",
        &[(0, 2, 150.0, 30.0), (1, 3, 140.0, 30.0), (2, 3, 130.0, 30.0)],
    );
    // same clients at the same points (in-flight payload dims stay
    // valid), different budgets/rates → a genuinely different plan
    let plan_b = plan_for(
        &cm,
        "inc",
        &[(0, 2, 110.0, 45.0), (1, 3, 100.0, 45.0), (2, 3, 95.0, 45.0)],
    );
    let live = LiveServer::start(
        mock_executor(&cm),
        &cm,
        &plan_a,
        ServerOptions {
            time_scale,
            drop_on_slo: false,
            mode: ExecutorMode::Pool,
            ..Default::default()
        },
    );
    let mi = cm.model_index("inc").unwrap();
    let dims = &cm.config().models[mi].dims;
    let (tx, rx) = mpsc::channel();
    let total = 3 * 60;
    for seq in 0..60u32 {
        for c in 0..3u32 {
            let p = if c == 0 { 2 } else { 3 };
            live.submit(
                Request {
                    client_id: c,
                    model: mi as u16,
                    p: p as u16,
                    seq,
                    t_capture_ms: 0.0,
                    upstream_ms: 0.0,
                    budget_ms: 1e9,
                    payload: payload(c, seq, dims[p]),
                },
                tx.clone(),
            );
            if swap && seq == 20 && c == 2 {
                // mid-stream hot swap: drains the old core before
                // returning, with a third of the load already in flight
                let report = live.reconfigure(&plan_b);
                assert_eq!(report.old_rejected, 0, "drain lost items");
                assert_eq!(report.old_dropped, 0);
                assert!(report.transition.restarted_instances > 0);
            }
        }
    }
    drop(tx);
    let mut got = Vec::new();
    for resp in rx.iter() {
        assert!(!resp.dropped, "{resp:?}");
        got.push((
            resp.client_id,
            resp.seq,
            resp.output.iter().map(|x| x.to_bits()).collect::<Vec<u32>>(),
        ));
        if got.len() == total {
            break;
        }
    }
    assert_eq!(got.len(), total, "swap={swap} lost responses");
    let totals = live.totals();
    assert_eq!(totals.served, total as u64, "swap={swap}");
    assert_eq!(totals.dropped, 0, "swap={swap}");
    assert_eq!(totals.rejected, 0, "swap={swap}");
    if swap {
        assert_eq!(live.swap_count(), 1);
    }
    live.shutdown();
    got.sort();
    got
}

#[test]
fn hot_swap_preserves_the_response_multiset() {
    let _wd = watchdog("hot_swap_multiset", Duration::from_secs(120));
    // zero drops, exactly-once: the swapped run's response multiset
    // (including output tensors) equals the undisturbed run's
    assert_eq!(run_workload(false, 0.0), run_workload(true, 0.0));
}

#[test]
fn hot_swap_with_pacing_drains_the_wheel() {
    let _wd = watchdog("hot_swap_pacing", Duration::from_secs(120));
    // with pacing on, batches park in the deadline wheel during the
    // drain — the ordered drain must wait them out, not lose them
    assert_eq!(run_workload(false, 0.02), run_workload(true, 0.02));
}

#[test]
fn repeated_swaps_are_stable() {
    let _wd = watchdog("repeated_swaps", Duration::from_secs(120));
    let cm = cm();
    let mk = |t: f64| {
        plan_for(&cm, "vgg", &[(0, 1, t, 30.0), (1, 2, t - 10.0, 30.0)])
    };
    let plans = [mk(120.0), mk(100.0), mk(90.0)];
    let live = LiveServer::start(
        mock_executor(&cm),
        &cm,
        &plans[0],
        ServerOptions {
            time_scale: 0.0,
            drop_on_slo: false,
            mode: ExecutorMode::Pool,
            ..Default::default()
        },
    );
    let mi = cm.model_index("vgg").unwrap();
    let dims = &cm.config().models[mi].dims;
    let (tx, rx) = mpsc::channel();
    let mut sent = 0u32;
    for round in 0..3usize {
        for seq in 0..25u32 {
            for c in 0..2u32 {
                let p = (c + 1) as usize;
                live.submit(
                    Request {
                        client_id: c,
                        model: mi as u16,
                        p: p as u16,
                        seq: sent,
                        t_capture_ms: 0.0,
                        upstream_ms: 0.0,
                        budget_ms: 1e9,
                        payload: vec![0.5; dims[p]],
                    },
                    tx.clone(),
                );
                sent += 1;
            }
        }
        if round < 2 {
            let report = live.reconfigure(&plans[round + 1]);
            assert_eq!(report.old_rejected, 0, "round {round}");
        }
    }
    drop(tx);
    let got = rx.iter().take(sent as usize).count();
    assert_eq!(got, sent as usize);
    assert_eq!(live.swap_count(), 2);
    let totals = live.totals();
    assert_eq!(totals.served, sent as u64);
    assert_eq!(totals.rejected, 0);
    live.shutdown();
}

#[test]
fn controller_replans_on_observed_drift() {
    let _wd = watchdog("controller_drift", Duration::from_secs(180));
    let cm = cm();
    let mi = cm.model_index("inc").unwrap();
    // tiny planned rates: any real burst reads as massive drift no
    // matter how slow the test host is
    let specs: Vec<FragmentSpec> = (0..4)
        .map(|i| {
            FragmentSpec::single(ClientId(i), mi, 3, 130.0 + i as f64, 1.0)
        })
        .collect();
    let sched =
        Arc::new(Scheduler::new(cm.clone(), SchedulerOptions::default()));
    let (plan, _) = sched.plan(&specs);
    let live = Arc::new(LiveServer::start(
        mock_executor(&cm),
        &cm,
        &plan,
        ServerOptions {
            time_scale: 0.0,
            drop_on_slo: false,
            mode: ExecutorMode::Pool,
            ..Default::default()
        },
    ));
    let ctrl = ReplanController::new(
        sched,
        live.clone(),
        specs.clone(),
        ControllerOptions {
            drift_threshold: 0.5,
            min_requests: 10,
            rate_clamp: (0.2, 1e9),
            ..Default::default()
        },
    );
    // first tick records the baseline; an idle window is not trusted
    assert!(matches!(ctrl.tick(), TickOutcome::Baseline));
    assert!(matches!(ctrl.tick(), TickOutcome::TooFewRequests { .. }));

    // a burst far above the planned 4 rps total
    let dims = &cm.config().models[mi].dims;
    let (tx, rx) = mpsc::channel();
    let total = 4 * 300;
    for seq in 0..300u32 {
        for c in 0..4u32 {
            live.submit(
                Request {
                    client_id: c,
                    model: mi as u16,
                    p: 3,
                    seq,
                    t_capture_ms: 0.0,
                    upstream_ms: 0.0,
                    budget_ms: 1e9,
                    payload: vec![0.25; dims[3]],
                },
                tx.clone(),
            );
        }
    }
    drop(tx);
    assert_eq!(rx.iter().take(total).count(), total);

    match ctrl.tick() {
        TickOutcome::Replanned { max_drift, report, .. } => {
            assert!(max_drift >= 0.5, "drift {max_drift}");
            assert_eq!(report.old_rejected, 0);
            assert_eq!(report.old_dropped, 0);
            assert_eq!(live.swap_count(), 1);
            // the demand model followed the observation upward, and the
            // deployed plan changed with it
            let scaled = ctrl.demands();
            assert!(scaled.iter().all(|s| s.rate_rps > 1.0));
            let t = diff_plans(&plan, &live.plan());
            assert!(
                t.updated_sets + t.added_sets + t.removed_sets > 0,
                "deployed plan did not change"
            );
        }
        other => panic!("expected a replan, got {other:?}"),
    }
    drop(ctrl); // releases the controller's handle on the live server
    match Arc::try_unwrap(live) {
        Ok(l) => l.shutdown(),
        Err(_) => panic!("live server still shared"),
    }
}

#[test]
fn controller_replans_on_unplanned_model_surge() {
    let _wd = watchdog("controller_surge", Duration::from_secs(180));
    // the zero-planned-rate regression: a model whose demand specs are
    // all zero-rated has no meaningful relative drift, and the
    // controller used to skip it outright — real traffic on it could
    // never fire a replan.  Above `unplanned_rate_floor` it must now
    // walk the same surge to TickOutcome::Replanned.
    let cm = cm();
    let mi = cm.model_index("inc").unwrap();
    let mk = |rate: f64| -> Vec<FragmentSpec> {
        (0..4)
            .map(|i| {
                FragmentSpec::single(
                    ClientId(i),
                    mi,
                    3,
                    130.0 + i as f64,
                    rate,
                )
            })
            .collect()
    };
    let sched =
        Arc::new(Scheduler::new(cm.clone(), SchedulerOptions::default()));
    // deploy a real plan for these clients, but hand the controller a
    // demand model that expects *no* traffic on them
    let (plan, _) = sched.plan(&mk(1.0));
    let live = Arc::new(LiveServer::start(
        mock_executor(&cm),
        &cm,
        &plan,
        ServerOptions {
            time_scale: 0.0,
            drop_on_slo: false,
            mode: ExecutorMode::Pool,
            ..Default::default()
        },
    ));
    let ctrl = ReplanController::new(
        sched,
        live.clone(),
        mk(0.0),
        ControllerOptions {
            drift_threshold: 0.5,
            min_requests: 10,
            rate_clamp: (0.2, 1e9),
            unplanned_rate_floor: 0.5,
            ..Default::default()
        },
    );
    assert!(matches!(ctrl.tick(), TickOutcome::Baseline));
    assert!(matches!(ctrl.tick(), TickOutcome::TooFewRequests { .. }));

    // a burst on the supposedly-idle model
    let dims = &cm.config().models[mi].dims;
    let (tx, rx) = mpsc::channel();
    let total = 4 * 300;
    for seq in 0..300u32 {
        for c in 0..4u32 {
            live.submit(
                Request {
                    client_id: c,
                    model: mi as u16,
                    p: 3,
                    seq,
                    t_capture_ms: 0.0,
                    upstream_ms: 0.0,
                    budget_ms: 1e9,
                    payload: vec![0.25; dims[3]],
                },
                tx.clone(),
            );
        }
    }
    drop(tx);
    assert_eq!(rx.iter().take(total).count(), total);

    match ctrl.tick() {
        TickOutcome::Replanned { max_drift, scaled_models, report } => {
            // pseudo-drift o/floor is at least threshold-exceeding
            assert!(max_drift >= 0.5, "drift {max_drift}");
            assert_eq!(scaled_models, 1);
            assert_eq!(report.old_rejected, 0);
            assert_eq!(report.old_dropped, 0);
            assert_eq!(live.swap_count(), 1);
            // the observed rate was distributed across the zero-rated
            // specs, and the deployed plan moved with it
            let scaled = ctrl.demands();
            assert!(scaled.iter().all(|s| s.rate_rps > 0.0));
            let t = diff_plans(&plan, &live.plan());
            assert!(
                t.updated_sets + t.added_sets + t.removed_sets > 0,
                "deployed plan did not change"
            );
        }
        other => panic!("expected a surge replan, got {other:?}"),
    }
    drop(ctrl);
    match Arc::try_unwrap(live) {
        Ok(l) => l.shutdown(),
        Err(_) => panic!("live server still shared"),
    }
}

#[test]
fn adaptive_batch_window_serves_the_same_workload() {
    let _wd = watchdog("adaptive_window", Duration::from_secs(120));
    // adaptive windows are a pacing heuristic: with a live arrival-rate
    // estimate the stage must still serve everything (and the EWMA must
    // actually be populated)
    let cm = cm();
    let plan = plan_for(&cm, "vgg", &[(0, 2, 120.0, 30.0)]);
    let live = LiveServer::start(
        mock_executor(&cm),
        &cm,
        &plan,
        ServerOptions {
            time_scale: 0.02,
            drop_on_slo: false,
            mode: ExecutorMode::Pool,
            adaptive_window: true,
            ..Default::default()
        },
    );
    let mi = cm.model_index("vgg").unwrap();
    let dims = &cm.config().models[mi].dims;
    let (tx, rx) = mpsc::channel();
    let n = 60u32;
    for seq in 0..n {
        live.submit(
            Request {
                client_id: 0,
                model: mi as u16,
                p: 2,
                seq,
                t_capture_ms: 0.0,
                upstream_ms: 0.0,
                budget_ms: 1e9,
                payload: vec![0.5; dims[2]],
            },
            tx.clone(),
        );
        std::thread::sleep(Duration::from_micros(300));
    }
    drop(tx);
    let got = rx.iter().take(n as usize).count();
    assert_eq!(got, n as usize);
    let rates = live.server().stage_arrival_rates();
    assert!(
        rates.iter().any(|&r| r > 0.0),
        "arrival-rate EWMA never populated: {rates:?}"
    );
    live.shutdown();
}

/// The controller's second drift signal: observed e2e latency blowing
/// past the planned wall-clock envelope fires a replan even when the
/// arrival counters look perfectly on-plan.
#[test]
fn controller_replans_on_observed_latency_drift() {
    use graft::obs::{Span, SpanKind, Trace};
    use graft::serving::TraceOptions;

    let _wd = watchdog("controller_latency_drift", Duration::from_secs(180));
    let cm = cm();
    let mi = cm.model_index("inc").unwrap();
    let specs: Vec<FragmentSpec> = (0..4)
        .map(|i| {
            FragmentSpec::single(ClientId(i), mi, 3, 130.0 + i as f64, 1.0)
        })
        .collect();
    let sched =
        Arc::new(Scheduler::new(cm.clone(), SchedulerOptions::default()));
    let (plan, _) = sched.plan(&specs);
    let live = Arc::new(LiveServer::start(
        mock_executor(&cm),
        &cm,
        &plan,
        // pacing on: the modeled envelope has a wall-clock meaning,
        // which is the precondition for the latency-drift check
        ServerOptions {
            time_scale: 0.02,
            drop_on_slo: false,
            mode: ExecutorMode::Pool,
            trace: TraceOptions { sample_every: 1 },
            ..Default::default()
        },
    ));
    let ctrl = ReplanController::new(
        sched,
        live.clone(),
        specs,
        ControllerOptions {
            latency_drift_factor: Some(1.5),
            latency_min_samples: 20,
            rate_clamp: (0.2, 10.0),
            ..Default::default()
        },
    );
    // feed the observability sink traces whose e2e latency dwarfs any
    // plausible envelope — the arrival counters stay empty throughout
    let obs = live.server().obs();
    for seq in 0..60u32 {
        let base = 1_000 + seq as u64;
        obs.record(Trace {
            client_id: 0,
            seq,
            model: mi as u16,
            spans: vec![
                Span { kind: SpanKind::Enqueue, t_us: base },
                Span { kind: SpanKind::ShardPop, t_us: base + 50_000_000 },
                Span { kind: SpanKind::Deliver, t_us: base + 60_000_000 },
            ],
        });
    }
    match ctrl.tick() {
        TickOutcome::LatencyReplanned { model, e2e_p99_ms, envelope_ms, report } => {
            assert_eq!(model, "inc");
            assert!(
                e2e_p99_ms > envelope_ms * 1.5,
                "p99 {e2e_p99_ms} vs envelope {envelope_ms}"
            );
            assert_eq!(report.old_rejected, 0);
            assert_eq!(live.swap_count(), 1);
            // the latency signal argued for more capacity
            assert!(ctrl.demands().iter().all(|s| s.rate_rps > 1.0));
            let t = diff_plans(&plan, &live.plan());
            assert!(
                t.updated_sets + t.added_sets + t.removed_sets > 0,
                "deployed plan did not change"
            );
        }
        other => panic!("expected a latency replan, got {other:?}"),
    }
    // the swap installed a fresh core with empty histograms: the next
    // tick must fall through to the arrival path, not re-fire
    assert!(matches!(ctrl.tick(), TickOutcome::Baseline));
    drop(ctrl);
    match Arc::try_unwrap(live) {
        Ok(l) => l.shutdown(),
        Err(_) => panic!("live server still shared"),
    }
}
