//! Integration: the full hybrid-DL → scheduling pipeline over simulated
//! fleets, and cross-system dominance relations on real snapshots.

use graft::config::Config;
use graft::coordinator::baselines::{gslice, gslice_plus};
use graft::coordinator::grouping::{group_fragments, GroupOptions};
use graft::coordinator::repartition::{plan_covers_demand, plan_is_slo_safe};
use graft::coordinator::scheduler::{Scheduler, SchedulerOptions};
use graft::experiments::common::{
    fleet, random_fragments, random_mixed_fragments, snapshot, Scale,
};
use graft::experiments::scale::sharded_plan_scenario;
use graft::profiler::{AllocConstraints, CostModel};
use graft::sim::{plan_energy_j, simulate, SimClient, SimOptions};

fn cm() -> CostModel {
    CostModel::new(Config::embedded())
}

#[test]
fn full_pipeline_over_all_models_and_scales() {
    let cm = cm();
    for scale in [Scale::SmallHomo, Scale::SmallHeter, Scale::LargeHomo] {
        for (mi, m) in cm.config().models.iter().enumerate() {
            let clients = fleet(&cm, mi, scale, 0.95, 11);
            let specs = snapshot(&cm, &clients, 4.0);
            assert!(
                !specs.is_empty(),
                "{} at {:?}: no feasible client",
                m.name,
                scale
            );
            let sched =
                Scheduler::new(cm.clone(), SchedulerOptions::default());
            let (plan, stats) = sched.plan(&specs);
            assert!(plan.infeasible.is_empty(), "{}: {:?}", m.name, plan);
            assert!(plan_is_slo_safe(&plan), "{}", m.name);
            assert!(plan_covers_demand(&plan), "{}", m.name);
            assert!(stats.total_ms < 5_000.0, "{} too slow", m.name);
        }
    }
}

#[test]
fn graft_dominates_baselines_on_snapshots() {
    let cm = cm();
    let cons = AllocConstraints::default();
    for seed in [1u64, 2, 3] {
        for name in ["inc", "res", "vgg", "mob", "vit"] {
            let mi = cm.model_index(name).unwrap();
            let frags = random_fragments(&cm, mi, 12, seed);
            let sched =
                Scheduler::new(cm.clone(), SchedulerOptions::default());
            let (graft, _) = sched.plan(&frags);
            let g = gslice(&cm, &frags, &cons);
            let gp = gslice_plus(&cm, &frags, &cons);
            assert!(
                graft.total_share() <= gp.total_share(),
                "{name}/{seed}: graft {} > gslice+ {}",
                graft.total_share(),
                gp.total_share()
            );
            assert!(gp.total_share() <= g.total_share(), "{name}/{seed}");
        }
    }
}

#[test]
fn plans_survive_the_latency_simulator() {
    // end-to-end sanity: Graft's plan on a heterogeneous fleet keeps SLO
    // attainment high under the DES
    let cm = cm();
    let mi = cm.model_index("mob").unwrap();
    let clients = fleet(&cm, mi, Scale::SmallHeter, 0.95, 23);
    let t_s = 6.0;
    let specs = snapshot(&cm, &clients, t_s);
    let sched = Scheduler::new(cm.clone(), SchedulerOptions::default());
    let (plan, _) = sched.plan(&specs);
    let sim_clients: Vec<SimClient> = clients
        .iter()
        .filter_map(|c| {
            let st = c.state_at(&cm, t_s);
            st.spec.map(|s| SimClient {
                client_id: c.id.0,
                upstream_ms: st.mobile_ms + st.transfer_ms,
                slo_ms: st.slo_ms,
                budget_ms: s.budget_ms,
                rate_rps: cm.config().models[mi].rate_rps,
            })
        })
        .collect();
    let r = simulate(&cm, &plan, &sim_clients, &SimOptions::default());
    assert!(r.served > 0);
    assert!(
        r.slo_attainment > 0.95,
        "attainment {} (served {}, dropped {})",
        r.slo_attainment,
        r.served,
        r.dropped
    );
}

#[test]
fn replanning_tracks_bandwidth_changes() {
    // the trigger-based loop: plans at different trace instants differ
    // when the partition points move, and every plan stays valid
    let cm = cm();
    let mi = cm.model_index("inc").unwrap();
    let clients = fleet(&cm, mi, Scale::SmallHomo, 0.95, 31);
    let sched = Scheduler::new(cm.clone(), SchedulerOptions::default());
    let mut shares = Vec::new();
    for t in [0.0, 60.0, 120.0, 180.0, 240.0] {
        let specs = snapshot(&cm, &clients, t);
        if specs.is_empty() {
            continue;
        }
        let (plan, _) = sched.plan(&specs);
        assert!(plan_is_slo_safe(&plan));
        shares.push(plan.total_share());
    }
    assert!(shares.len() >= 3);
    assert!(
        shares.iter().any(|&s| s != shares[0]),
        "resource demand never changed across the trace: {shares:?}"
    );
}

#[test]
#[ignore] // stress tier: ~2500² dense similarity matrix (tools/ci.sh --stress)
fn lazy_similarity_table_matches_dense_at_scale() {
    // Above GroupOptions::dense_limit (default 2048) the greedy switches
    // from the precomputed dense similarity matrix to on-the-fly
    // evaluation.  The two lookups must be bit-equal, so the grouping
    // must be identical — here at a size where the default options
    // actually take the lazy path and the dense side is forced.
    let cm = cm();
    let mi = cm.model_index("res").unwrap();
    let n = 2500;
    let specs = random_fragments(&cm, mi, n, 7);
    assert_eq!(specs.len(), n);
    let lazy_opts = GroupOptions::default();
    assert!(n > lazy_opts.dense_limit, "stress size must force Lazy");
    let dense_opts =
        GroupOptions { dense_limit: usize::MAX, ..Default::default() };
    let lazy = group_fragments(&specs, &lazy_opts);
    let dense = group_fragments(&specs, &dense_opts);
    assert_eq!(lazy, dense, "lazy SimTable diverged from dense");
    // and the output is a balanced disjoint cover at this scale
    let mut all: Vec<usize> = lazy.concat();
    all.sort_unstable();
    assert_eq!(all, (0..n).collect::<Vec<_>>());
    let cap = n.div_ceil(n.div_ceil(lazy_opts.group_size));
    assert!(lazy.iter().all(|g| !g.is_empty() && g.len() <= cap));
}

#[test]
fn energy_accounting_is_consistent_across_systems() {
    let cm = cm();
    let mi = cm.model_index("vgg").unwrap();
    let frags = random_fragments(&cm, mi, 10, 99);
    let cons = AllocConstraints::default();
    let sched = Scheduler::new(cm.clone(), SchedulerOptions::default());
    let (graft, _) = sched.plan(&frags);
    let g = gslice(&cm, &frags, &cons);
    let e_graft = plan_energy_j(&cm, &graft, 30.0);
    let e_gslice = plan_energy_j(&cm, &g, 30.0);
    assert!(e_graft > 0.0 && e_gslice > 0.0);
    assert!(
        e_graft <= e_gslice * 1.1,
        "graft {e_graft} energy way above gslice {e_gslice}"
    );
}

#[test]
fn sharded_warm_replay_matches_sequential_counters() {
    // A warm sharded replan replays each shard's own MergeCache /
    // GroupState / DP hints.  It must not only reproduce the
    // sequential plan byte-for-byte but take the same incremental
    // path: the merge / group / reuse counters agree with a
    // `planner_threads = 1` scheduler warmed on the same triggers.
    let cm = cm();
    let n = 96;
    let mut specs = random_mixed_fragments(&cm, n, 0x5EED);
    let mk = |t: usize| {
        Scheduler::new(
            cm.clone(),
            SchedulerOptions { planner_threads: t, ..Default::default() },
        )
    };
    let seq = mk(1);
    let par = mk(4);
    let (p0, _) = seq.plan(&specs);
    let (q0, t0) = par.plan(&specs);
    assert_eq!(p0, q0, "cold sharded plan diverged");
    assert!(t0.planner_shards >= 2, "mixed fleet made one shard");
    // move ~10% of split points, then warm-replan on both lanes
    for (i, s) in specs.iter_mut().enumerate() {
        if i % 10 == 0 {
            let m = &cm.config().models[s.model];
            s.p = (s.p + 1) % m.layers;
            let tail = m.server_ms_ref * m.rel_cost_range(s.p, m.layers);
            s.budget_ms = tail * 4.0;
        }
    }
    let (p1, a) = seq.plan(&specs);
    let (q1, b) = par.plan(&specs);
    assert_eq!(p1, q1, "warm sharded replan diverged from sequential");
    assert_eq!(a.merge_classes, b.merge_classes, "merge_classes");
    assert_eq!(a.classes_remerged, b.classes_remerged, "classes_remerged");
    assert_eq!(a.groups_replayed, b.groups_replayed, "groups_replayed");
    assert_eq!(
        a.fragments_regrouped, b.fragments_regrouped,
        "fragments_regrouped"
    );
    assert_eq!(a.n_groups_reused, b.n_groups_reused, "n_groups_reused");
    assert_eq!(a.n_groups, b.n_groups, "n_groups");
    assert!(
        a.groups_replayed > 0 || a.n_groups_reused > 0,
        "warm replan never replayed anything: {a:?}"
    );
}

#[test]
#[ignore] // stress tier: 100k-client sharded planning point (tools/ci.sh --stress)
fn sharded_plan_100k_identical_and_profiled() {
    // The `bench-scheduler` n=100k point as a self-checked test: at
    // scale the parallel plan must still be byte-identical to the
    // sequential oracle, with sane shard accounting.  The speedup
    // itself is only asserted by `graft bench-scheduler`, and only on
    // multi-core runners.
    let r = sharded_plan_scenario(100_000, 4, 0xB15C);
    assert!(r.identical, "100k sharded plan diverged from sequential");
    assert!(r.planner_shards >= 2, "100k mixed fleet made one shard");
    assert!(r.shard_max_ms <= r.par_ms, "shard wall time exceeds plan");
    assert!(r.shard_imbalance >= 1.0 - 1e-9, "imbalance below 1.0");
    assert!(r.total_share > 0 && r.gpus > 0, "placement missing");
    println!(
        "n=100000 threads={}: seq {:.0} ms, par {:.0} ms ({:.2}x), \
         {} shards, slowest {:.0} ms, imbalance {:.2}x",
        r.threads,
        r.seq_ms,
        r.par_ms,
        r.speedup,
        r.planner_shards,
        r.shard_max_ms,
        r.shard_imbalance
    );
}
