//! Integration: plan → server → batched execution → responses, over both
//! the in-process path (mock executor, no artifacts needed) and the TCP
//! front with the real PJRT engine (skipped without artifacts).

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{mpsc, Arc};

use graft::config::Config;
use graft::coordinator::repartition::{realign_group, RepartitionOptions};
use graft::coordinator::{ClientId, FragmentSpec};
use graft::profiler::CostModel;
use graft::serving::{
    MockExecutor, Request, Server, ServerOptions, TcpClient, TcpFront,
};
use graft::util::Rng;

fn cm() -> CostModel {
    CostModel::new(Config::embedded())
}

fn plan_for(
    cm: &CostModel,
    model: &str,
    specs: &[(u32, usize, f64, f64)],
) -> graft::coordinator::ExecutionPlan {
    let mi = cm.model_index(model).unwrap();
    let specs: Vec<FragmentSpec> = specs
        .iter()
        .map(|&(c, p, t, q)| FragmentSpec::single(ClientId(c), mi, p, t, q))
        .collect();
    let points = cm.config().models[mi].points();
    let plan = realign_group(
        cm,
        &specs,
        &RepartitionOptions { point_set: Some(points), ..Default::default() },
    );
    assert!(plan.infeasible.is_empty());
    plan
}

fn mock_executor(cm: &CostModel) -> Arc<MockExecutor> {
    let dims: HashMap<String, Vec<usize>> = cm
        .config()
        .models
        .iter()
        .map(|m| (m.name.clone(), m.dims.clone()))
        .collect();
    Arc::new(MockExecutor { dims })
}

#[test]
fn mock_serving_roundtrip() {
    let cm = cm();
    let plan = plan_for(
        &cm,
        "inc",
        &[(0, 2, 110.0, 30.0), (1, 3, 95.0, 30.0), (2, 3, 100.0, 30.0)],
    );
    let server = Server::start(
        mock_executor(&cm),
        &cm,
        &plan,
        ServerOptions { time_scale: 0.0, drop_on_slo: false },
    );

    let mi = cm.model_index("inc").unwrap();
    let dims = &cm.config().models[mi].dims;
    let (tx, rx) = mpsc::channel();
    let mut rng = Rng::seed_from_u64(5);
    for c in 0..3u32 {
        for seq in 0..10u32 {
            let p = if c == 0 { 2 } else { 3 };
            server.submit(
                Request {
                    client_id: c,
                    model: mi as u16,
                    p: p as u16,
                    seq,
                    t_capture_ms: 0.0,
                    upstream_ms: 12.0,
                    budget_ms: 100.0,
                    payload: (0..dims[p]).map(|_| rng.normal() as f32).collect(),
                },
                tx.clone(),
            );
        }
    }
    drop(tx);
    let mut got = 0;
    let dim_out = *dims.last().unwrap();
    for resp in rx.iter() {
        assert!(!resp.dropped, "{resp:?}");
        assert_eq!(resp.output.len(), dim_out);
        assert!(resp.e2e_ms >= resp.server_ms);
        got += 1;
        if got == 30 {
            break;
        }
    }
    assert_eq!(got, 30);
    server.shutdown();
}

#[test]
fn unknown_client_is_rejected() {
    let cm = cm();
    let plan = plan_for(&cm, "vgg", &[(0, 1, 80.0, 30.0)]);
    let server = Server::start(
        mock_executor(&cm),
        &cm,
        &plan,
        ServerOptions { time_scale: 0.0, drop_on_slo: false },
    );
    let (tx, rx) = mpsc::channel();
    server.submit(
        Request {
            client_id: 99,
            model: 0,
            p: 1,
            seq: 0,
            t_capture_ms: 0.0,
            upstream_ms: 0.0,
            budget_ms: 50.0,
            payload: vec![0.0; 8],
        },
        tx,
    );
    let resp = rx.recv().unwrap();
    assert!(resp.dropped);
    server.shutdown();
}

#[test]
fn slo_hopeless_requests_are_dropped() {
    let cm = cm();
    let plan = plan_for(&cm, "inc", &[(0, 3, 120.0, 30.0)]);
    let server = Server::start(
        mock_executor(&cm),
        &cm,
        &plan,
        ServerOptions { time_scale: 0.0, drop_on_slo: true },
    );
    let mi = cm.model_index("inc").unwrap();
    let dims = &cm.config().models[mi].dims;
    let (tx, rx) = mpsc::channel();
    server.submit(
        Request {
            client_id: 0,
            model: mi as u16,
            p: 3,
            seq: 0,
            t_capture_ms: 0.0,
            upstream_ms: 0.0,
            budget_ms: 0.001, // cannot possibly execute in time
            payload: vec![0.1; dims[3]],
        },
        tx,
    );
    let resp = rx.recv().unwrap();
    assert!(resp.dropped);
    assert_eq!(
        server.counters.dropped.load(std::sync::atomic::Ordering::Relaxed),
        1
    );
    server.shutdown();
}

#[test]
fn batching_actually_forms_batches() {
    // Submit a burst far above one instance's pop rate and check the
    // counters show multi-request batches.
    let cm = cm();
    let plan = plan_for(&cm, "vgg", &[(0, 2, 120.0, 30.0)]);
    let server = Server::start(
        mock_executor(&cm),
        &cm,
        &plan,
        // small pacing so the queue has time to fill while a batch runs
        ServerOptions { time_scale: 0.05, drop_on_slo: false },
    );
    let mi = cm.model_index("vgg").unwrap();
    let dims = &cm.config().models[mi].dims;
    let (tx, rx) = mpsc::channel();
    let n = 40u32;
    for seq in 0..n {
        server.submit(
            Request {
                client_id: 0,
                model: mi as u16,
                p: 2,
                seq,
                t_capture_ms: 0.0,
                upstream_ms: 0.0,
                budget_ms: 1e9,
                payload: vec![0.5; dims[2]],
            },
            tx.clone(),
        );
    }
    drop(tx);
    let responses: Vec<_> = rx.iter().collect();
    assert_eq!(responses.len(), n as usize);
    let batches = server
        .counters
        .batches
        .load(std::sync::atomic::Ordering::Relaxed);
    assert!(batches < n as u64, "no batching: {batches} batches for {n}");
    server.shutdown();
}

#[test]
fn tcp_front_with_real_engine() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    let cm = cm();
    let engine = Arc::new(graft::runtime::Engine::new(&dir).unwrap());
    // two vgg clients at p=1 and p=2 realigned; compiled points only
    let plan =
        plan_for(&cm, "vgg", &[(0, 1, 90.0, 30.0), (1, 2, 80.0, 30.0)]);
    let server = Arc::new(Server::start(
        engine.clone(),
        &cm,
        &plan,
        ServerOptions { time_scale: 0.0, drop_on_slo: false },
    ));
    let front = TcpFront::start("127.0.0.1:0", server.clone()).unwrap();

    let mi = cm.model_index("vgg").unwrap();
    let dims = cm.config().models[mi].dims.clone();
    let mut rng = Rng::seed_from_u64(11);
    let mut c0 = TcpClient::connect(front.addr).unwrap();
    let mut c1 = TcpClient::connect(front.addr).unwrap();
    for seq in 0..5u32 {
        c0.send(&Request {
            client_id: 0,
            model: mi as u16,
            p: 1,
            seq,
            t_capture_ms: 0.0,
            upstream_ms: 10.0,
            budget_ms: 90.0,
            payload: (0..dims[1]).map(|_| rng.normal() as f32).collect(),
        })
        .unwrap();
        c1.send(&Request {
            client_id: 1,
            model: mi as u16,
            p: 2,
            seq,
            t_capture_ms: 0.0,
            upstream_ms: 10.0,
            budget_ms: 80.0,
            payload: (0..dims[2]).map(|_| rng.normal() as f32).collect(),
        })
        .unwrap();
    }
    for _ in 0..5 {
        let r = c0.recv().unwrap();
        assert!(!r.dropped);
        assert_eq!(r.output.len(), *dims.last().unwrap());
        assert!(r.output.iter().all(|x| x.is_finite()));
        let r = c1.recv().unwrap();
        assert!(!r.dropped);
    }
    // close the client sockets before stopping the front: connection
    // threads block on read until their peer hangs up
    drop(c0);
    drop(c1);
    front.stop();
    match Arc::try_unwrap(server) {
        Ok(s) => s.shutdown(),
        Err(_) => panic!("server still shared"),
    }
}
