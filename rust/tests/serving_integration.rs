//! Integration: plan → server → batched execution → responses, over both
//! executor cores ([`ExecutorMode::Threads`] and [`ExecutorMode::Pool`])
//! with the mock executor, plus the TCP front with the real PJRT engine
//! (skipped without artifacts).  The cross-mode tests assert the pooled
//! executor is behaviourally equivalent to the thread-per-instance
//! reference: same response multiset, same SLO-drop accounting.

mod common;

use std::path::PathBuf;
use std::sync::{mpsc, Arc};
use std::time::Duration;

use graft::serving::{
    ExecutorMode, Request, Server, ServerOptions, SpanKind, TcpClient,
    TcpFront, TraceOptions,
};
use graft::util::Rng;

use common::{cm, mock_executor, plan_for, watchdog};

const MODES: [ExecutorMode; 2] = [ExecutorMode::Threads, ExecutorMode::Pool];

fn roundtrip(mode: ExecutorMode) {
    let _wd = watchdog("mock_serving_roundtrip", Duration::from_secs(120));
    let cm = cm();
    let plan = plan_for(
        &cm,
        "inc",
        &[(0, 2, 110.0, 30.0), (1, 3, 95.0, 30.0), (2, 3, 100.0, 30.0)],
    );
    let server = Server::start(
        mock_executor(&cm),
        &cm,
        &plan,
        ServerOptions { time_scale: 0.0, drop_on_slo: false, mode, ..Default::default() },
    );

    let mi = cm.model_index("inc").unwrap();
    let dims = &cm.config().models[mi].dims;
    let (tx, rx) = mpsc::channel();
    let mut rng = Rng::seed_from_u64(5);
    for c in 0..3u32 {
        for seq in 0..10u32 {
            let p = if c == 0 { 2 } else { 3 };
            server.submit(
                Request {
                    client_id: c,
                    model: mi as u16,
                    p: p as u16,
                    seq,
                    t_capture_ms: 0.0,
                    upstream_ms: 12.0,
                    budget_ms: 100.0,
                    payload: (0..dims[p]).map(|_| rng.normal() as f32).collect(),
                },
                tx.clone(),
            );
        }
    }
    drop(tx);
    let mut got = 0;
    let dim_out = *dims.last().unwrap();
    for resp in rx.iter() {
        assert!(!resp.dropped, "{resp:?}");
        assert_eq!(resp.output.len(), dim_out);
        assert!(resp.e2e_ms >= resp.server_ms);
        got += 1;
        if got == 30 {
            break;
        }
    }
    assert_eq!(got, 30);
    server.shutdown();
}

#[test]
fn mock_serving_roundtrip_threads() {
    roundtrip(ExecutorMode::Threads);
}

#[test]
fn mock_serving_roundtrip_pool() {
    roundtrip(ExecutorMode::Pool);
}

#[test]
fn unknown_client_is_rejected() {
    let _wd = watchdog("unknown_client", Duration::from_secs(60));
    for mode in MODES {
        let cm = cm();
        let plan = plan_for(&cm, "vgg", &[(0, 1, 80.0, 30.0)]);
        let server = Server::start(
            mock_executor(&cm),
            &cm,
            &plan,
            ServerOptions { time_scale: 0.0, drop_on_slo: false, mode, ..Default::default() },
        );
        let (tx, rx) = mpsc::channel();
        server.submit(
            Request {
                client_id: 99,
                model: 0,
                p: 1,
                seq: 0,
                t_capture_ms: 0.0,
                upstream_ms: 0.0,
                budget_ms: 50.0,
                payload: vec![0.0; 8],
            },
            tx,
        );
        let resp = rx.recv().unwrap();
        assert!(resp.dropped);
        server.shutdown();
    }
}

#[test]
fn slo_hopeless_requests_are_dropped() {
    let _wd = watchdog("slo_hopeless", Duration::from_secs(60));
    for mode in MODES {
        let cm = cm();
        let plan = plan_for(&cm, "inc", &[(0, 3, 120.0, 30.0)]);
        let server = Server::start(
            mock_executor(&cm),
            &cm,
            &plan,
            ServerOptions { time_scale: 0.0, drop_on_slo: true, mode, ..Default::default() },
        );
        let mi = cm.model_index("inc").unwrap();
        let dims = &cm.config().models[mi].dims;
        let (tx, rx) = mpsc::channel();
        server.submit(
            Request {
                client_id: 0,
                model: mi as u16,
                p: 3,
                seq: 0,
                t_capture_ms: 0.0,
                upstream_ms: 0.0,
                budget_ms: 0.001, // cannot possibly execute in time
                payload: vec![0.1; dims[3]],
            },
            tx,
        );
        let resp = rx.recv().unwrap();
        assert!(resp.dropped, "{mode:?}");
        assert_eq!(
            server.counters.dropped.load(std::sync::atomic::Ordering::Relaxed),
            1,
            "{mode:?}"
        );
        server.shutdown();
    }
}

/// Run one mixed feasible/hopeless workload and collect the per-request
/// verdicts plus counters.
fn drop_accounting(
    mode: ExecutorMode,
) -> (Vec<(u32, u32, bool)>, u64, u64) {
    let cm = cm();
    // client 0 needs an alignment stage (p=2 < repartition point), the
    // others feed the shared stage directly
    let plan = plan_for(
        &cm,
        "inc",
        &[(0, 2, 150.0, 30.0), (1, 3, 150.0, 30.0), (2, 3, 150.0, 30.0)],
    );
    let server = Server::start(
        mock_executor(&cm),
        &cm,
        &plan,
        ServerOptions { time_scale: 0.0, drop_on_slo: true, mode, ..Default::default() },
    );
    let mi = cm.model_index("inc").unwrap();
    let dims = &cm.config().models[mi].dims;
    let (tx, rx) = mpsc::channel();
    let mut expected_drops = 0u64;
    let total = 3 * 20;
    for c in 0..3u32 {
        for seq in 0..20u32 {
            let p = if c == 0 { 2 } else { 3 };
            // every third request is hopeless (budget below the noise
            // margin alone), the rest are un-droppable; in between the
            // verdict would depend on batch formation, so we avoid it —
            // that keeps the outcome deterministic across executors
            let hopeless = seq % 3 == 0;
            if hopeless {
                expected_drops += 1;
            }
            server.submit(
                Request {
                    client_id: c,
                    model: mi as u16,
                    p: p as u16,
                    seq,
                    t_capture_ms: 0.0,
                    upstream_ms: 0.0,
                    budget_ms: if hopeless { 0.001 } else { 1e9 },
                    payload: vec![0.25; dims[p]],
                },
                tx.clone(),
            );
        }
    }
    drop(tx);
    let mut verdicts: Vec<(u32, u32, bool)> = Vec::new();
    for resp in rx.iter() {
        verdicts.push((resp.client_id, resp.seq, resp.dropped));
        if verdicts.len() == total {
            break;
        }
    }
    assert_eq!(verdicts.len(), total);
    let served =
        server.counters.served.load(std::sync::atomic::Ordering::Relaxed);
    let dropped =
        server.counters.dropped.load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(dropped, expected_drops, "{mode:?}");
    server.shutdown();
    verdicts.sort_unstable();
    (verdicts, served, dropped)
}

#[test]
fn slo_drop_accounting_identical_across_modes() {
    let _wd = watchdog("slo_drop_accounting", Duration::from_secs(120));
    let (v_threads, served_t, dropped_t) =
        drop_accounting(ExecutorMode::Threads);
    let (v_pool, served_p, dropped_p) = drop_accounting(ExecutorMode::Pool);
    assert_eq!(v_threads, v_pool, "per-request verdicts diverged");
    assert_eq!(served_t, served_p);
    assert_eq!(dropped_t, dropped_p);
}

/// Same workload, no drops: the full response multiset (including the
/// output tensors) must be identical under both executors.
#[test]
fn response_multiset_identical_across_modes() {
    let _wd = watchdog("response_multiset", Duration::from_secs(120));
    let run = |mode: ExecutorMode| -> Vec<(u32, u32, Vec<u32>)> {
        let cm = cm();
        let plan = plan_for(
            &cm,
            "vgg",
            &[(0, 1, 120.0, 30.0), (1, 2, 110.0, 30.0)],
        );
        let server = Server::start(
            mock_executor(&cm),
            &cm,
            &plan,
            ServerOptions { time_scale: 0.0, drop_on_slo: false, mode, ..Default::default() },
        );
        let mi = cm.model_index("vgg").unwrap();
        let dims = &cm.config().models[mi].dims;
        let (tx, rx) = mpsc::channel();
        let mut rng = Rng::seed_from_u64(21);
        let total = 2 * 25;
        for c in 0..2u32 {
            let p = (c + 1) as usize;
            for seq in 0..25u32 {
                server.submit(
                    Request {
                        client_id: c,
                        model: mi as u16,
                        p: p as u16,
                        seq,
                        t_capture_ms: 0.0,
                        upstream_ms: 0.0,
                        budget_ms: 1e9,
                        payload: (0..dims[p])
                            .map(|_| rng.normal() as f32)
                            .collect(),
                    },
                    tx.clone(),
                );
            }
        }
        drop(tx);
        let mut got = Vec::new();
        for resp in rx.iter() {
            assert!(!resp.dropped);
            // compare exact bit patterns of the outputs
            got.push((
                resp.client_id,
                resp.seq,
                resp.output.iter().map(|x| x.to_bits()).collect::<Vec<u32>>(),
            ));
            if got.len() == total {
                break;
            }
        }
        server.shutdown();
        got.sort();
        got
    };
    assert_eq!(run(ExecutorMode::Threads), run(ExecutorMode::Pool));
}

fn batching_forms_batches(mode: ExecutorMode) {
    // Submit a burst far above one instance's pop rate and check the
    // counters show multi-request batches (with pacing enabled this
    // also exercises the pool's deadline wheel).
    let _wd = watchdog("batching", Duration::from_secs(120));
    let cm = cm();
    let plan = plan_for(&cm, "vgg", &[(0, 2, 120.0, 30.0)]);
    let server = Server::start(
        mock_executor(&cm),
        &cm,
        &plan,
        // small pacing so the queue has time to fill while a batch runs
        ServerOptions { time_scale: 0.05, drop_on_slo: false, mode, ..Default::default() },
    );
    let mi = cm.model_index("vgg").unwrap();
    let dims = &cm.config().models[mi].dims;
    let (tx, rx) = mpsc::channel();
    let n = 40u32;
    for seq in 0..n {
        server.submit(
            Request {
                client_id: 0,
                model: mi as u16,
                p: 2,
                seq,
                t_capture_ms: 0.0,
                upstream_ms: 0.0,
                budget_ms: 1e9,
                payload: vec![0.5; dims[2]],
            },
            tx.clone(),
        );
    }
    drop(tx);
    let responses: Vec<_> = rx.iter().collect();
    assert_eq!(responses.len(), n as usize);
    let batches = server
        .counters
        .batches
        .load(std::sync::atomic::Ordering::Relaxed);
    assert!(
        batches < n as u64,
        "{mode:?}: no batching: {batches} batches for {n}"
    );
    server.shutdown();
}

#[test]
fn batching_actually_forms_batches_threads() {
    batching_forms_batches(ExecutorMode::Threads);
}

#[test]
fn batching_actually_forms_batches_pool() {
    batching_forms_batches(ExecutorMode::Pool);
}

/// Run a small mixed workload with every request traced and return the
/// per-request span-kind multiset, keyed by (client_id, seq).
fn traced_span_kinds(
    mode: ExecutorMode,
) -> std::collections::BTreeMap<(u32, u32), Vec<SpanKind>> {
    let cm = cm();
    // client 0 takes the two-hop path (alignment stage at p=2, then the
    // shared stage), clients 1 and 2 feed the shared stage directly
    let plan = plan_for(
        &cm,
        "inc",
        &[(0, 2, 150.0, 30.0), (1, 3, 150.0, 30.0), (2, 3, 150.0, 30.0)],
    );
    let server = Server::start(
        mock_executor(&cm),
        &cm,
        &plan,
        ServerOptions {
            time_scale: 0.0,
            drop_on_slo: false,
            mode,
            trace: TraceOptions { sample_every: 1 },
            ..Default::default()
        },
    );
    let mi = cm.model_index("inc").unwrap();
    let dims = &cm.config().models[mi].dims;
    let (tx, rx) = mpsc::channel();
    let total = 3 * 10;
    for c in 0..3u32 {
        for seq in 0..10u32 {
            let p = if c == 0 { 2 } else { 3 };
            server.submit(
                Request {
                    client_id: c,
                    model: mi as u16,
                    p: p as u16,
                    seq,
                    t_capture_ms: 0.0,
                    upstream_ms: 0.0,
                    budget_ms: 1e9,
                    payload: vec![0.5; dims[p]],
                },
                tx.clone(),
            );
        }
    }
    drop(tx);
    assert_eq!(rx.iter().take(total).count(), total);
    let obs = server.obs();
    // shutdown joins the workers, so every trace has been recorded
    server.shutdown();
    assert_eq!(obs.traced_count(), total as u64, "{mode:?}");
    let mut by_req = std::collections::BTreeMap::new();
    for t in obs.traces() {
        // timestamps are monotone along the span log
        assert!(
            t.spans.windows(2).all(|w| w[0].t_us <= w[1].t_us),
            "{mode:?}: non-monotone trace {t:?}"
        );
        let mut kinds: Vec<SpanKind> = t.spans.iter().map(|s| s.kind).collect();
        kinds.sort();
        by_req.insert((t.client_id, t.seq), kinds);
    }
    assert_eq!(by_req.len(), total, "{mode:?}");
    by_req
}

/// Both executor cores must stamp the same span-kind multiset for every
/// request: the six within-hop kinds once per hop, twice for the
/// two-hop (alignment → shared) path.
#[test]
fn span_kinds_identical_across_modes() {
    let _wd = watchdog("span_kinds_across_modes", Duration::from_secs(120));
    let threads = traced_span_kinds(ExecutorMode::Threads);
    let pool = traced_span_kinds(ExecutorMode::Pool);
    assert_eq!(threads, pool, "span-kind multisets diverged across modes");
    for ((client, seq), kinds) in &threads {
        let hops = if *client == 0 { 2 } else { 1 };
        let mut want: Vec<SpanKind> = SpanKind::ALL
            .iter()
            .flat_map(|&k| std::iter::repeat(k).take(hops))
            .collect();
        want.sort();
        assert_eq!(kinds, &want, "client {client} seq {seq}");
    }
}

#[test]
fn pool_thread_count_is_bounded_by_cpus() {
    let _wd = watchdog("pool_thread_count", Duration::from_secs(60));
    let cm = cm();
    let plan = plan_for(
        &cm,
        "inc",
        &[(0, 2, 110.0, 30.0), (1, 3, 95.0, 30.0), (2, 3, 100.0, 30.0)],
    );
    let server = Server::start(
        mock_executor(&cm),
        &cm,
        &plan,
        ServerOptions {
            time_scale: 0.0,
            drop_on_slo: false,
            mode: ExecutorMode::Pool,
            ..Default::default()
        },
    );
    let cpus = std::thread::available_parallelism()
        .map(|c| c.get())
        .unwrap_or(4);
    assert!(
        server.thread_count() <= cpus.max(1),
        "pool spawned {} workers on {} cpus",
        server.thread_count(),
        cpus
    );
    server.shutdown();
}

#[test]
fn placed_plan_reports_per_gpu_utilization() {
    use graft::coordinator::placement::{place, stamp};
    let _wd = watchdog("per_gpu_utilization", Duration::from_secs(120));
    for mode in MODES {
        let cm = cm();
        let mut plan = plan_for(
            &cm,
            "inc",
            &[(0, 2, 110.0, 30.0), (1, 3, 95.0, 30.0), (2, 3, 100.0, 30.0)],
        );
        let placement = place(&cm, &plan, None).unwrap();
        stamp(&mut plan, &placement);
        let server = Server::start(
            mock_executor(&cm),
            &cm,
            &plan,
            ServerOptions { time_scale: 0.0, drop_on_slo: false, mode, ..Default::default() },
        );
        assert_eq!(server.gpu_count(), placement.gpus(), "{mode:?}");

        let mi = cm.model_index("inc").unwrap();
        let dims = &cm.config().models[mi].dims;
        let (tx, rx) = mpsc::channel();
        for c in 0..3u32 {
            for seq in 0..8u32 {
                let p = if c == 0 { 2 } else { 3 };
                server.submit(
                    Request {
                        client_id: c,
                        model: mi as u16,
                        p: p as u16,
                        seq,
                        t_capture_ms: 0.0,
                        upstream_ms: 0.0,
                        budget_ms: 1e9,
                        payload: vec![0.5; dims[p]],
                    },
                    tx.clone(),
                );
            }
        }
        drop(tx);
        let got = rx.iter().take(24).count();
        assert_eq!(got, 24, "{mode:?}");
        // every executed batch attributed modeled busy time to a GPU
        let busy: u64 = server
            .counters
            .gpu_busy_share_us
            .iter()
            .map(|c| c.load(std::sync::atomic::Ordering::Relaxed))
            .sum();
        assert!(busy > 0, "{mode:?}: no per-GPU busy time recorded");
        let util = server.counters.gpu_utilization(1000.0, 100);
        assert_eq!(util.len(), placement.gpus(), "{mode:?}");
        assert!(util.iter().any(|&u| u > 0.0), "{mode:?}");
        server.shutdown();
    }
}

#[test]
fn unplaced_plan_has_no_gpu_counters() {
    let _wd = watchdog("unplaced_no_gpu_counters", Duration::from_secs(60));
    let cm = cm();
    let plan = plan_for(&cm, "vgg", &[(0, 1, 80.0, 30.0)]);
    let server = Server::start(
        mock_executor(&cm),
        &cm,
        &plan,
        ServerOptions {
            time_scale: 0.0,
            drop_on_slo: false,
            mode: ExecutorMode::Pool,
            ..Default::default()
        },
    );
    assert_eq!(server.gpu_count(), 0);
    assert!(server.counters.gpu_utilization(1000.0, 100).is_empty());
    server.shutdown();
}

#[test]
fn tcp_front_with_real_engine() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    let cm = cm();
    let engine = Arc::new(graft::runtime::Engine::new(&dir).unwrap());
    // two vgg clients at p=1 and p=2 realigned; compiled points only
    let plan =
        plan_for(&cm, "vgg", &[(0, 1, 90.0, 30.0), (1, 2, 80.0, 30.0)]);
    let server = Arc::new(Server::start(
        engine.clone(),
        &cm,
        &plan,
        ServerOptions {
            time_scale: 0.0,
            drop_on_slo: false,
            mode: ExecutorMode::Pool,
            ..Default::default()
        },
    ));
    let front = TcpFront::start("127.0.0.1:0", server.clone()).unwrap();

    let mi = cm.model_index("vgg").unwrap();
    let dims = cm.config().models[mi].dims.clone();
    let mut rng = Rng::seed_from_u64(11);
    let mut c0 = TcpClient::connect(front.addr).unwrap();
    let mut c1 = TcpClient::connect(front.addr).unwrap();
    for seq in 0..5u32 {
        c0.send(&Request {
            client_id: 0,
            model: mi as u16,
            p: 1,
            seq,
            t_capture_ms: 0.0,
            upstream_ms: 10.0,
            budget_ms: 90.0,
            payload: (0..dims[1]).map(|_| rng.normal() as f32).collect(),
        })
        .unwrap();
        c1.send(&Request {
            client_id: 1,
            model: mi as u16,
            p: 2,
            seq,
            t_capture_ms: 0.0,
            upstream_ms: 10.0,
            budget_ms: 80.0,
            payload: (0..dims[2]).map(|_| rng.normal() as f32).collect(),
        })
        .unwrap();
    }
    for _ in 0..5 {
        let r = c0.recv().unwrap();
        assert!(!r.dropped);
        assert_eq!(r.output.len(), *dims.last().unwrap());
        assert!(r.output.iter().all(|x| x.is_finite()));
        let r = c1.recv().unwrap();
        assert!(!r.dropped);
    }
    // close the client sockets before stopping the front: connection
    // threads block on read until their peer hangs up
    drop(c0);
    drop(c1);
    front.stop();
    match Arc::try_unwrap(server) {
        Ok(s) => s.shutdown(),
        Err(_) => panic!("server still shared"),
    }
}
