//! Property-based tests (in-tree generator: `graft::util::Rng` — the
//! offline crate set has no proptest).  Each property runs over many
//! random cases; failures print the seed for reproduction.

mod common;

use std::time::Duration;

use graft::config::Config;
use graft::coordinator::grouping::{
    group_fragments, group_fragments_incremental, GroupOptions, GroupState,
};
use graft::coordinator::merging::{
    merge_fragments, merge_fragments_incremental, MergeCache, MergeOptions,
};
use graft::coordinator::repartition::{
    plan_covers_demand, plan_is_slo_safe, realign_group, realign_group_warm,
    RepartitionOptions,
};
use graft::coordinator::scheduler::{Scheduler, SchedulerOptions};
use graft::coordinator::{ClientId, FragmentSpec};
use graft::profiler::{AllocConstraints, CostModel};
use graft::serving::{
    BatchQueue, Request, Response, ShardedBatchQueue, WorkItem,
};
use graft::util::{Json, Rng};

fn cm() -> CostModel {
    CostModel::new(Config::embedded())
}

/// Random same-model fragment set with plausible budgets.
fn random_specs(rng: &mut Rng, cm: &CostModel, model: usize, n: usize) -> Vec<FragmentSpec> {
    let m = &cm.config().models[model];
    (0..n)
        .map(|i| {
            let p = rng.below(m.layers);
            // budget comfortably above the tail's ref latency so most
            // cases are feasible
            let tail_ms = m.server_ms_ref * m.rel_cost_range(p, m.layers);
            let budget = tail_ms * rng.range(2.5, 8.0);
            let rate = *[1.0, 10.0, 30.0, 60.0][..].get(rng.below(4)).unwrap();
            FragmentSpec::single(ClientId(i as u32), model, p, budget, rate)
        })
        .collect()
}

#[test]
fn prop_merging_conserves_rate_and_clients() {
    let cm = cm();
    for case in 0..60u64 {
        let mut rng = Rng::seed_from_u64(case);
        let model = rng.below(cm.config().models.len());
        let n = 1 + rng.below(40);
        let specs = random_specs(&mut rng, &cm, model, n);
        for opts in [
            MergeOptions::none(),
            MergeOptions::merge_all(),
            MergeOptions::default(),
        ] {
            let merged = merge_fragments(&cm, &specs, &opts);
            let rate_in: f64 = specs.iter().map(|s| s.rate_rps).sum();
            let rate_out: f64 = merged.iter().map(|s| s.rate_rps).sum();
            assert!(
                (rate_in - rate_out).abs() < 1e-6,
                "case {case}: rate {rate_in} vs {rate_out}"
            );
            let mut cin: Vec<u32> = specs
                .iter()
                .flat_map(|s| s.clients.iter().map(|c| c.0))
                .collect();
            let mut cout: Vec<u32> = merged
                .iter()
                .flat_map(|s| s.clients.iter().map(|c| c.0))
                .collect();
            cin.sort_unstable();
            cout.sort_unstable();
            assert_eq!(cin, cout, "case {case}");
            // merged members stay uniform: one (model, p) per spec and
            // budget == min of members is conserved implicitly; at least
            // check the point never changes
            for ms in &merged {
                assert!(ms.p < cm.config().models[model].layers);
            }
        }
    }
}

#[test]
fn prop_merging_never_increases_fragment_count_with_lower_threshold() {
    let cm = cm();
    for case in 0..30u64 {
        let mut rng = Rng::seed_from_u64(1000 + case);
        let model = rng.below(cm.config().models.len());
        let n = 5 + rng.below(30);
        let specs = random_specs(&mut rng, &cm, model, n);
        let mut prev = usize::MAX;
        for thr in [f64::INFINITY, 0.4, 0.2, 0.05, f64::NEG_INFINITY] {
            let n = merge_fragments(
                &cm,
                &specs,
                &MergeOptions { threshold: thr, ..Default::default() },
            )
            .len();
            assert!(
                n <= prev,
                "case {case}: thr {thr} gives {n} > {prev}"
            );
            prev = n;
        }
    }
}

#[test]
fn prop_grouping_is_balanced_disjoint_cover() {
    let cm = cm();
    for case in 0..60u64 {
        let mut rng = Rng::seed_from_u64(2000 + case);
        let model = rng.below(cm.config().models.len());
        let n = 1 + rng.below(50);
        let specs = random_specs(&mut rng, &cm, model, n);
        let gs = 2 + rng.below(6);
        let groups = group_fragments(
            &specs,
            &GroupOptions { group_size: gs, seed: case, ..Default::default() },
        );
        let mut all: Vec<usize> = groups.concat();
        all.sort_unstable();
        assert_eq!(all, (0..n).collect::<Vec<_>>(), "case {case}");
        let k = n.div_ceil(gs);
        let cap = n.div_ceil(k);
        for g in &groups {
            assert!(!g.is_empty() && g.len() <= cap, "case {case}: {groups:?}");
        }
    }
}

#[test]
fn prop_incremental_grouping_replays_and_bounds_drift() {
    // The heuristic delta-aware grouping's contracts across evolving
    // same-model demand sets:
    //  (a) every trigger's output is a balanced disjoint cover;
    //  (b) a perturbed trigger regroups exactly the perturbed fragments
    //      and churns at most two groups per perturbed fragment (its old
    //      group and its new one) — unless it fell back to scratch;
    //  (c) with the ε-audit forced (`audit_limit: usize::MAX`), any
    //      surviving (non-fallback) grouping is within ε of the scratch
    //      oracle by construction — breaches fall back, so the output is
    //      ε-bounded either way;
    //  (d) replaying the identical demand replays every group
    //      byte-identically and leaves the persisted state untouched.
    // a grouping as the set of its groups' sorted member identities
    fn key_sets(
        specs: &[FragmentSpec],
        groups: &[Vec<usize>],
    ) -> std::collections::HashSet<Vec<Vec<u32>>> {
        groups
            .iter()
            .map(|g| {
                let mut ks: Vec<Vec<u32>> = g
                    .iter()
                    .map(|&i| {
                        let mut c: Vec<u32> = specs[i]
                            .clients
                            .iter()
                            .map(|c| c.0)
                            .collect();
                        c.sort_unstable();
                        c
                    })
                    .collect();
                ks.sort();
                ks
            })
            .collect()
    }
    let cm = cm();
    for case in 0..20u64 {
        let mut rng = Rng::seed_from_u64(17_000 + case);
        let model = rng.below(cm.config().models.len());
        let n = 20 + rng.below(120);
        let mut specs = random_specs(&mut rng, &cm, model, n);
        let opts = GroupOptions {
            audit_limit: usize::MAX, // force the ε-audit at every n
            seed: case,
            ..Default::default()
        };
        let mut state: Option<GroupState> = None;
        let mut prev_sets: Option<std::collections::HashSet<Vec<Vec<u32>>>> =
            None;
        for step in 0..4 {
            let mut perturbed = Vec::new();
            if step > 0 {
                // move a few budgets (identities — client sets — stay)
                for _ in 0..1 + rng.below(3) {
                    perturbed.push(rng.below(n));
                }
                perturbed.sort_unstable();
                perturbed.dedup();
                for &i in &perturbed {
                    specs[i].budget_ms += rng.range(0.5, 2.0);
                }
            }
            let (delta, next) =
                group_fragments_incremental(&specs, &opts, state.as_ref());
            // (a) balanced disjoint cover, same cap as the scratch greedy
            let mut all: Vec<usize> = delta.groups.concat();
            all.sort_unstable();
            assert_eq!(
                all,
                (0..n).collect::<Vec<_>>(),
                "case {case} step {step}"
            );
            let cap = n.div_ceil(n.div_ceil(opts.group_size));
            for g in &delta.groups {
                assert!(
                    !g.is_empty() && g.len() <= cap,
                    "case {case} step {step}: group sizes {:?}",
                    delta.groups.iter().map(Vec::len).collect::<Vec<_>>()
                );
            }
            if step > 0 && !delta.fell_back {
                // (b) only the perturbed fragments went back through
                // the greedy, and the group churn is bounded by them
                assert_eq!(
                    delta.regrouped,
                    perturbed.len(),
                    "case {case} step {step}"
                );
                let next_sets = key_sets(&specs, &delta.groups);
                let churned = next_sets
                    .iter()
                    .filter(|s| !prev_sets.as_ref().unwrap().contains(*s))
                    .count();
                assert!(
                    churned <= 2 * perturbed.len(),
                    "case {case} step {step}: {churned} groups churned \
                     for {} perturbed fragments",
                    perturbed.len()
                );
            }
            prev_sets = Some(key_sets(&specs, &delta.groups));
            state = Some(next);
        }
        // (d) unchanged replay: nothing regrouped, state bit-stable
        let before = state.clone().unwrap();
        let (replay, after) =
            group_fragments_incremental(&specs, &opts, state.as_ref());
        assert_eq!(replay.regrouped, 0, "case {case}");
        assert_eq!(replay.replayed, before.groups.len(), "case {case}");
        assert!(!replay.fell_back, "case {case}");
        assert_eq!(after, before, "case {case}: replay state drifted");
        assert_eq!(
            key_sets(&specs, &replay.groups),
            prev_sets.unwrap(),
            "case {case}: replayed groups differ"
        );
    }
}

#[test]
fn prop_realign_plans_are_safe_and_cover_all_clients() {
    let cm = cm();
    for case in 0..40u64 {
        let mut rng = Rng::seed_from_u64(3000 + case);
        let model = rng.below(cm.config().models.len());
        let n = 1 + rng.below(6);
        let specs = random_specs(&mut rng, &cm, model, n);
        let plan =
            realign_group(&cm, &specs, &RepartitionOptions::default());
        assert!(plan_is_slo_safe(&plan), "case {case}");
        assert!(plan_covers_demand(&plan), "case {case}");
        let mut planned: Vec<u32> = plan
            .sets
            .iter()
            .flat_map(|s| s.members.iter())
            .flat_map(|m| m.spec.clients.iter().map(|c| c.0))
            .chain(
                plan.infeasible
                    .iter()
                    .flat_map(|s| s.clients.iter().map(|c| c.0)),
            )
            .collect();
        planned.sort_unstable();
        let mut want: Vec<u32> = specs
            .iter()
            .flat_map(|s| s.clients.iter().map(|c| c.0))
            .collect();
        want.sort_unstable();
        assert_eq!(planned, want, "case {case}");
        // structural invariants
        for set in &plan.sets {
            assert!(set.point <= cm.config().models[model].layers);
            for m in &set.members {
                assert!(m.spec.p <= set.point, "case {case}");
            }
        }
    }
}

/// Random mixed-model demand set with globally unique client ids.
fn random_mixed_specs(
    rng: &mut Rng,
    cm: &CostModel,
    n: usize,
) -> Vec<FragmentSpec> {
    let n_models = cm.config().models.len();
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let model = rng.below(n_models);
        let m = &cm.config().models[model];
        let p = rng.below(m.layers);
        let tail_ms = m.server_ms_ref * m.rel_cost_range(p, m.layers);
        let budget = tail_ms * rng.range(2.5, 8.0);
        let rate = *[1.0, 10.0, 30.0, 60.0][..].get(rng.below(4)).unwrap();
        out.push(FragmentSpec::single(
            ClientId(i as u32),
            model,
            p,
            budget,
            rate,
        ));
    }
    out
}

#[test]
fn prop_cached_planner_identical_to_uncached() {
    // The allocation memo cache keys on exact bit patterns, so the cached
    // planner must produce a byte-identical ExecutionPlan (total_share
    // and full structure) to the cache-free reference planner.
    for case in 0..12u64 {
        let mut rng = Rng::seed_from_u64(7000 + case);
        let cfg = Config::embedded();
        let n = 5 + rng.below(60);
        let cached_cm = CostModel::new(cfg.clone());
        let specs = random_mixed_specs(&mut rng, &cached_cm, n);
        let cached = Scheduler::new(cached_cm, SchedulerOptions::default());
        let reference = Scheduler::new(
            CostModel::new_uncached(cfg),
            SchedulerOptions { incremental: false, ..Default::default() },
        );
        let (a, _) = cached.plan(&specs);
        let (b, _) = reference.plan(&specs);
        assert_eq!(
            a.total_share(),
            b.total_share(),
            "case {case}: cached {} vs uncached {}",
            a.total_share(),
            b.total_share()
        );
        assert_eq!(a, b, "case {case}: plans structurally differ");
        // planning twice through the cache is also stable
        let (a2, _) = cached.plan(&specs);
        assert_eq!(a, a2, "case {case}: cached re-plan differs");
    }
}

#[test]
fn prop_sharded_plan_identical_to_sequential() {
    // The sharded-planning determinism contract: every stage before
    // placement is per-model, so planning with any `planner_threads`
    // count must yield a plan byte-identical to the sequential oracle
    // (`planner_threads = 1`) — on cold triggers and on warm (perturbed)
    // triggers where each shard replays its own MergeCache / GroupState
    // / DP hints.  Long-lived schedulers on every lane, so the warm
    // state evolves independently per thread count and must still agree.
    for case in 0..5u64 {
        let mut rng = Rng::seed_from_u64(15_000 + case);
        let cfg = Config::embedded();
        let cm = CostModel::new(cfg.clone());
        let n_models = cfg.models.len();
        // draw demand from a random 2..=n_models model prefix so the
        // shard count varies across cases
        let use_models = (2 + rng.below(n_models.max(2) - 1)).min(n_models);
        let n = 12 + rng.below(48);
        let mut specs = Vec::with_capacity(n);
        for i in 0..n {
            let model = rng.below(use_models);
            let m = &cfg.models[model];
            let p = rng.below(m.layers);
            let tail_ms = m.server_ms_ref * m.rel_cost_range(p, m.layers);
            let budget = tail_ms * rng.range(2.5, 8.0);
            let rate =
                *[1.0, 10.0, 30.0, 60.0][..].get(rng.below(4)).unwrap();
            specs.push(FragmentSpec::single(
                ClientId(i as u32),
                model,
                p,
                budget,
                rate,
            ));
        }
        let mk = |threads: usize| {
            Scheduler::new(
                cm.clone(),
                SchedulerOptions {
                    planner_threads: threads,
                    ..Default::default()
                },
            )
        };
        let seq = mk(1);
        let pars: Vec<(usize, Scheduler)> =
            [2usize, 4, 8].iter().map(|&t| (t, mk(t))).collect();
        for step in 0..3 {
            if step > 0 {
                // warm trigger: move some split points / budgets
                for s in specs.iter_mut() {
                    if rng.f64() < 0.3 {
                        let m = &cfg.models[s.model];
                        s.p = rng.below(m.layers);
                        let tail =
                            m.server_ms_ref * m.rel_cost_range(s.p, m.layers);
                        s.budget_ms = tail * rng.range(2.5, 8.0);
                    }
                }
            }
            let (oracle, ostats) = seq.plan(&specs);
            for (t, sched) in &pars {
                let (plan, stats) = sched.plan(&specs);
                assert_eq!(
                    plan, oracle,
                    "case {case} step {step}: threads={t} diverged"
                );
                assert_eq!(
                    stats.planner_shards, ostats.planner_shards,
                    "case {case} step {step}: shard count differs at \
                     threads={t}"
                );
            }
            assert!(
                ostats.planner_shards >= 1
                    && ostats.planner_shards <= use_models,
                "case {case} step {step}: {} shards from {use_models} models",
                ostats.planner_shards
            );
        }
    }
}

/// Scheduler options with the heuristic delta-aware grouping pinned off:
/// the exact lane, where incremental replanning is byte-identical to a
/// from-scratch plan (the default lane's grouping is ε-bounded instead —
/// `prop_incremental_grouping_replays_and_bounds_drift`).
fn exact_opts() -> SchedulerOptions {
    SchedulerOptions {
        group: GroupOptions { incremental: false, ..Default::default() },
        ..Default::default()
    }
}

#[test]
fn prop_incremental_replanning_identical_to_from_scratch() {
    // Trigger-based re-planning: a long-lived scheduler re-planning an
    // evolving demand set must match a fresh scheduler at every step.
    for case in 0..6u64 {
        let mut rng = Rng::seed_from_u64(8000 + case);
        let cfg = Config::embedded();
        let cm = CostModel::new(cfg.clone());
        let n = 10 + rng.below(50);
        let mut specs = random_mixed_specs(&mut rng, &cm, n);
        let live = Scheduler::new(cm.clone(), exact_opts());
        for step in 0..4 {
            if step > 0 {
                // perturb a random subset (partition points and budgets
                // move; some specs stay identical → cache replay)
                for s in specs.iter_mut() {
                    if rng.f64() < 0.3 {
                        let m = &cm.config().models[s.model];
                        s.p = rng.below(m.layers);
                        let tail = m.server_ms_ref
                            * m.rel_cost_range(s.p, m.layers);
                        s.budget_ms = tail * rng.range(2.5, 8.0);
                    }
                }
            }
            let (incremental, stats) = live.plan(&specs);
            let fresh = Scheduler::new(
                CostModel::new_uncached(cfg.clone()),
                SchedulerOptions { incremental: false, ..Default::default() },
            );
            let (scratch, _) = fresh.plan(&specs);
            assert_eq!(
                incremental.total_share(),
                scratch.total_share(),
                "case {case} step {step}"
            );
            assert_eq!(incremental, scratch, "case {case} step {step}");
            if step > 0 {
                assert!(
                    stats.n_groups_reused <= stats.n_groups,
                    "case {case} step {step}"
                );
            }
        }
        // unchanged final step: everything replays
        let (replay, stats) = live.plan(&specs);
        assert_eq!(stats.n_groups_reused, stats.n_groups);
        let fresh = Scheduler::new(
            CostModel::new_uncached(cfg),
            SchedulerOptions { incremental: false, ..Default::default() },
        );
        assert_eq!(replay, fresh.plan(&specs).0, "case {case} final replay");
    }
}

#[test]
fn prop_adaptive_grid_identical_to_exhaustive() {
    // The adaptive d_shared search (coarse sweep + bound-screened
    // refinement) must return byte-identical plans to the exhaustive
    // grid scan at the same resolution, for any grid/coarse setting.
    let cm = cm();
    for case in 0..40u64 {
        let mut rng = Rng::seed_from_u64(13_000 + case);
        let model = rng.below(cm.config().models.len());
        let n = 1 + rng.below(6);
        let specs = random_specs(&mut rng, &cm, model, n);
        let d_grid = 2 + rng.below(31);
        let adaptive = RepartitionOptions {
            d_grid,
            coarse_grid: 2 + rng.below(10),
            adaptive_grid: true,
            ..Default::default()
        };
        let exhaustive = RepartitionOptions {
            d_grid,
            adaptive_grid: false,
            ..Default::default()
        };
        let a = realign_group(&cm, &specs, &adaptive);
        let b = realign_group(&cm, &specs, &exhaustive);
        assert_eq!(
            a, b,
            "case {case}: adaptive (d_grid={d_grid}) diverged"
        );
    }
}

#[test]
fn prop_warm_hints_are_advisory() {
    // Any hint — the true winning points, a random subset, garbage —
    // must yield exactly the cold plan: hints seed the DP incumbent,
    // they never replace the search.
    let cm = cm();
    for case in 0..30u64 {
        let mut rng = Rng::seed_from_u64(14_000 + case);
        let model = rng.below(cm.config().models.len());
        let layers = cm.config().models[model].layers;
        let n = 1 + rng.below(6);
        let specs = random_specs(&mut rng, &cm, model, n);
        let opts = RepartitionOptions::default();
        let cold = realign_group(&cm, &specs, &opts);
        let mut hints: Vec<Vec<usize>> = vec![cold.realign_points()];
        hints.push(
            (0..1 + rng.below(5)).map(|_| rng.below(layers + 4)).collect(),
        );
        hints.push(Vec::new());
        for hint in hints {
            let warm =
                realign_group_warm(&cm, &specs, &opts, Some(&hint), None);
            assert_eq!(warm, cold, "case {case}: hint {hint:?} changed plan");
        }
    }
}

#[test]
fn prop_incremental_merge_identical_to_scratch() {
    // Dirty-class incremental merging must splice to exactly the
    // from-scratch merge output across an evolving demand set, for
    // every merging strategy sharing one cache.
    for case in 0..8u64 {
        let mut rng = Rng::seed_from_u64(15_000 + case);
        let cfg = Config::embedded();
        let cm = CostModel::new(cfg);
        let n = 10 + rng.below(60);
        let mut specs = random_mixed_specs(&mut rng, &cm, n);
        let mut cache = MergeCache::default();
        for step in 0..4 {
            if step > 0 {
                for s in specs.iter_mut() {
                    if rng.f64() < 0.25 {
                        let m = &cm.config().models[s.model];
                        s.p = rng.below(m.layers);
                        let tail =
                            m.server_ms_ref * m.rel_cost_range(s.p, m.layers);
                        s.budget_ms = tail * rng.range(2.5, 8.0);
                    }
                }
            }
            for opts in [
                MergeOptions::default(),
                MergeOptions::merge_all(),
                MergeOptions::none(),
            ] {
                let inc = merge_fragments_incremental(
                    &cm, &specs, &opts, &mut cache,
                );
                let scratch = merge_fragments(&cm, &specs, &opts);
                assert_eq!(
                    inc.merged, scratch,
                    "case {case} step {step} thr={}",
                    opts.threshold
                );
                assert!(inc.classes_remerged <= inc.classes);
                // replaying the identical demand is all cache hits
                let replay = merge_fragments_incremental(
                    &cm, &specs, &opts, &mut cache,
                );
                assert_eq!(replay.merged, scratch);
                assert_eq!(
                    replay.classes_remerged, 0,
                    "case {case} step {step}"
                );
            }
        }
    }
}

#[test]
fn prop_warm_replan_never_worse_than_cold() {
    // The exact-lane delta-aware pipeline (dirty-class merge + group
    // replay + warm-started DP + adaptive grid, heuristic incremental
    // grouping pinned off) must track a fresh cold planner exactly
    // across perturbation triggers: same total share, same GPU count —
    // in fact byte-identical plans.
    for case in 0..5u64 {
        let mut rng = Rng::seed_from_u64(16_000 + case);
        let cfg = Config::embedded();
        let cm = CostModel::new(cfg.clone());
        let n = 10 + rng.below(50);
        let mut specs = random_mixed_specs(&mut rng, &cm, n);
        let live = Scheduler::new(cm.clone(), exact_opts());
        for step in 0..4 {
            if step > 0 {
                for s in specs.iter_mut() {
                    if rng.f64() < 0.2 {
                        let m = &cm.config().models[s.model];
                        s.p = rng.below(m.layers);
                        s.budget_ms += rng.range(0.5, 3.0);
                    }
                }
            }
            let (warm, _) = live.plan(&specs);
            let cold =
                Scheduler::new(CostModel::new(cfg.clone()), exact_opts());
            let (cold_plan, _) = cold.plan(&specs);
            // the stated bound: no worse on share or GPUs …
            assert!(
                warm.total_share() <= cold_plan.total_share(),
                "case {case} step {step}: {} > {}",
                warm.total_share(),
                cold_plan.total_share()
            );
            let wg = warm.placed_gpus().expect("warm plan placed");
            let cg = cold_plan.placed_gpus().expect("cold plan placed");
            assert!(wg <= cg, "case {case} step {step}: {wg} > {cg} GPUs");
            // … and the stronger invariant the design guarantees
            assert_eq!(warm, cold_plan, "case {case} step {step}");
        }
    }
}

#[test]
fn prop_ffd_placement_respects_caps_for_random_plans() {
    // FFD placement of arbitrary (baseline-built) plans never loads a
    // GPU beyond the share or memory cap, covers every instance, and
    // agrees with the offline `pack` oracle on the GPU count.
    use graft::coordinator::baselines::{gslice, gslice_plus};
    use graft::coordinator::placement::place;
    use graft::sim::pack;

    let cm = cm();
    let g = &cm.config().gpu;
    for case in 0..30u64 {
        let mut rng = Rng::seed_from_u64(11_000 + case);
        let n = 1 + rng.below(40);
        let specs = random_mixed_specs(&mut rng, &cm, n);
        let cons = AllocConstraints::default();
        let plan = if case % 2 == 0 {
            gslice(&cm, &specs, &cons)
        } else {
            gslice_plus(&cm, &specs, &cons)
        };
        let placement = match place(&cm, &plan, None) {
            Ok(p) => p,
            Err(_) => {
                // the oracle must agree the plan is unpackable
                assert!(pack(&cm, &plan, None).is_none(), "case {case}");
                continue;
            }
        };
        for u in &placement.usage {
            assert!(u.share <= g.max_share, "case {case}: {u:?}");
            assert!(u.mem_mb <= g.gpu_mem_mb + 1e-9, "case {case}: {u:?}");
        }
        let stages: Vec<_> = plan.stages().collect();
        assert_eq!(placement.by_stage.len(), stages.len(), "case {case}");
        for (s, gpus) in stages.iter().zip(&placement.by_stage) {
            assert_eq!(
                gpus.len(),
                s.alloc.instances as usize,
                "case {case}"
            );
            for &gpu in gpus {
                assert!((gpu as usize) < placement.gpus(), "case {case}");
            }
        }
        let oracle = pack(&cm, &plan, None).expect("oracle packs too");
        assert_eq!(placement.gpus(), oracle.gpus, "case {case}");
        assert!(
            placement.gpus() as u32
                >= plan.gpus_share_lower_bound(g.max_share),
            "case {case}"
        );
    }
}

#[test]
fn prop_integrated_placement_never_exceeds_posthoc_ffd() {
    // The planner's placement feedback loop: the stamped plan (a) never
    // violates a per-GPU cap and (b) never packs onto more GPUs than
    // FFD-packing the feedback-free plan for the same demand after the
    // fact — tightening may only ever help.
    use graft::coordinator::placement::{stamped_usage, PlacementOptions};
    use graft::sim::pack;

    let cm = cm();
    let g = &cm.config().gpu;
    for case in 0..12u64 {
        let mut rng = Rng::seed_from_u64(12_000 + case);
        let n = 5 + rng.below(60);
        let specs = random_mixed_specs(&mut rng, &cm, n);

        let integrated =
            Scheduler::new(cm.clone(), SchedulerOptions::default());
        let (plan, stats) = integrated.plan(&specs);
        let gpus_int = plan
            .placed_gpus()
            .expect("integrated planner stamps every instance");
        assert_eq!(stats.gpus, gpus_int, "case {case}");
        let usage = stamped_usage(&cm, &plan).unwrap();
        for u in &usage {
            assert!(u.share <= g.max_share, "case {case}: {u:?}");
            assert!(u.mem_mb <= g.gpu_mem_mb + 1e-9, "case {case}: {u:?}");
        }

        let baseline = Scheduler::new(
            cm.clone(),
            SchedulerOptions {
                placement: PlacementOptions {
                    enabled: false,
                    ..Default::default()
                },
                ..Default::default()
            },
        );
        let (plan0, _) = baseline.plan(&specs);
        if !plan0.sets.is_empty() {
            assert_eq!(plan0.placed_gpus(), None, "case {case}");
        }
        if let Some(oracle) = pack(&cm, &plan0, None) {
            assert!(
                gpus_int <= oracle.gpus,
                "case {case}: integrated {gpus_int} > post-hoc FFD {}",
                oracle.gpus
            );
            // tightening must never shed clients relative to round 0
            assert!(
                plan.infeasible.len() <= plan0.infeasible.len(),
                "case {case}"
            );
        }
        // when the round-0 plan is unpackable, the feedback loop must
        // still have produced a placeable (stamped) plan — asserted by
        // the placed_gpus() expect above
    }
}

#[test]
fn prop_min_alloc_meets_constraints() {
    let cm = cm();
    for case in 0..300u64 {
        let mut rng = Rng::seed_from_u64(4000 + case);
        let model = rng.below(cm.config().models.len());
        let m = &cm.config().models[model];
        let start = rng.below(m.layers);
        let end = start + 1 + rng.below(m.layers - start);
        let frag = graft::profiler::FragmentId::new(model, start, end);
        let budget = rng.range(0.5, 300.0);
        let demand = rng.range(0.5, 400.0);
        if let Some(a) =
            cm.min_alloc(frag, budget, demand, AllocConstraints::default())
        {
            assert!(a.latency_ms <= budget + 1e-9, "case {case}: {a:?}");
            assert!(
                a.throughput_rps >= demand - 1e-9,
                "case {case}: {a:?} for demand {demand}"
            );
            assert!(a.share <= cm.config().gpu.max_share);
            assert_eq!(a.share % cm.config().gpu.share_unit, 0);
            assert!(cm
                .config()
                .gpu
                .batch_buckets
                .contains(&a.batch));
        }
    }
}

#[test]
fn prop_json_roundtrip() {
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.f64() < 0.5),
            2 => Json::Num((rng.range(-1e6, 1e6) * 100.0).round() / 100.0),
            3 => {
                let n = rng.below(12);
                Json::Str(
                    (0..n)
                        .map(|_| {
                            char::from_u32(32 + rng.below(90) as u32).unwrap()
                        })
                        .collect(),
                )
            }
            4 => Json::Arr(
                (0..rng.below(5))
                    .map(|_| random_json(rng, depth - 1))
                    .collect(),
            ),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    for case in 0..200u64 {
        let mut rng = Rng::seed_from_u64(5000 + case);
        let v = random_json(&mut rng, 3);
        let re = Json::parse(&v.to_string())
            .unwrap_or_else(|e| panic!("case {case}: {e} on {v}"));
        assert_eq!(v, re, "case {case}");
    }
}

/// Minimal queue interface so one harness drives both the sharded queue
/// under test and the single-lock reference as the oracle.
trait QueueUnderTest: Sync {
    fn push_item(&self, item: WorkItem<u32>) -> bool;
    fn pop_items(
        &self,
        home: usize,
        max_batch: usize,
    ) -> Option<Vec<WorkItem<u32>>>;
    fn close_queue(&self);
    fn rejected(&self) -> u64;
}

impl QueueUnderTest for ShardedBatchQueue<u32> {
    fn push_item(&self, item: WorkItem<u32>) -> bool {
        self.push(item)
    }
    fn pop_items(
        &self,
        home: usize,
        max_batch: usize,
    ) -> Option<Vec<WorkItem<u32>>> {
        self.pop_batch(home, max_batch)
    }
    fn close_queue(&self) {
        self.close()
    }
    fn rejected(&self) -> u64 {
        self.metrics().rejected()
    }
}

impl QueueUnderTest for BatchQueue<u32> {
    fn push_item(&self, item: WorkItem<u32>) -> bool {
        self.push(item)
    }
    fn pop_items(
        &self,
        _home: usize,
        max_batch: usize,
    ) -> Option<Vec<WorkItem<u32>>> {
        self.pop_batch(max_batch)
    }
    fn close_queue(&self) {
        self.close()
    }
    fn rejected(&self) -> u64 {
        self.metrics().rejected()
    }
}

fn qitem(v: u32) -> WorkItem<u32> {
    WorkItem {
        payload: Vec::new(),
        server_arrival: std::time::Instant::now(),
        budget_ms: 1e9,
        accumulated_ms: 0.0,
        ctx: v,
    }
}

/// N producers push disjoint id ranges while M consumers pop batches
/// until the queue closes; returns every popped id (unsorted).  Also
/// asserts the batch-size bound and the rejected-after-close contract.
fn run_queue<Q: QueueUnderTest>(
    q: &Q,
    producers: usize,
    consumers: usize,
    per_producer: usize,
    max_batch: usize,
) -> Vec<u32> {
    std::thread::scope(|scope| {
        let mut consumer_handles = Vec::new();
        for cid in 0..consumers {
            consumer_handles.push(scope.spawn(move || {
                let mut got = Vec::new();
                while let Some(batch) = q.pop_items(cid, max_batch) {
                    assert!(
                        batch.len() <= max_batch.max(1),
                        "batch {} exceeds max_batch {max_batch}",
                        batch.len()
                    );
                    got.extend(batch.into_iter().map(|w| w.ctx));
                }
                got
            }));
        }
        let mut producer_handles = Vec::new();
        for pid in 0..producers {
            producer_handles.push(scope.spawn(move || {
                for i in 0..per_producer {
                    assert!(q.push_item(qitem((pid * 1_000_000 + i) as u32)));
                }
            }));
        }
        for h in producer_handles {
            h.join().expect("producer");
        }
        q.close_queue();
        // the shutdown contract: a late push is rejected and counted,
        // never silently dropped
        assert!(!q.push_item(qitem(u32::MAX)));
        let mut got = Vec::new();
        for h in consumer_handles {
            got.extend(h.join().expect("consumer"));
        }
        got
    })
}

#[test]
fn prop_sharded_queue_equivalent_to_reference() {
    let _wd = common::watchdog(
        "prop_sharded_queue_equivalent_to_reference",
        Duration::from_secs(180),
    );
    for case in 0..10u64 {
        let mut rng = Rng::seed_from_u64(9000 + case);
        let shards = 1 + rng.below(8);
        let producers = 1 + rng.below(4);
        let consumers = 1 + rng.below(4);
        let per_producer = 50 + rng.below(250);
        let max_batch = 1 + rng.below(12);

        let mut expected: Vec<u32> = (0..producers)
            .flat_map(|p| (0..per_producer).map(move |i| (p * 1_000_000 + i) as u32))
            .collect();
        expected.sort_unstable();

        let sharded: ShardedBatchQueue<u32> = ShardedBatchQueue::new(shards);
        let mut got = run_queue(
            &sharded, producers, consumers, per_producer, max_batch,
        );
        got.sort_unstable();
        assert_eq!(
            got, expected,
            "case {case}: sharded queue lost or duplicated items"
        );
        assert_eq!(sharded.rejected(), 1, "case {case}");
        let n = (producers * per_producer) as u64;
        assert_eq!(sharded.metrics().pushed(), n, "case {case}");
        assert_eq!(sharded.metrics().popped(), n, "case {case}");

        // same harness against the single-lock reference as the oracle
        let reference: BatchQueue<u32> = BatchQueue::new();
        let mut got_ref = run_queue(
            &reference, producers, consumers, per_producer, max_batch,
        );
        got_ref.sort_unstable();
        assert_eq!(
            got, got_ref,
            "case {case}: sharded diverged from the reference queue"
        );
        assert_eq!(reference.rejected(), 1, "case {case}");
    }
}

#[test]
fn prop_wire_protocol_roundtrip() {
    for case in 0..200u64 {
        let mut rng = Rng::seed_from_u64(6000 + case);
        let req = Request {
            client_id: rng.next_u64() as u32,
            model: rng.below(5) as u16,
            p: rng.below(18) as u16,
            seq: rng.next_u64() as u32,
            t_capture_ms: rng.range(0.0, 1e6),
            upstream_ms: rng.range(0.0, 1e3),
            budget_ms: rng.range(0.0, 1e3),
            payload: (0..rng.below(300))
                .map(|_| rng.normal() as f32)
                .collect(),
        };
        assert_eq!(Request::decode(&req.encode()).unwrap(), req, "case {case}");
        let resp = Response {
            client_id: req.client_id,
            seq: req.seq,
            server_ms: rng.range(0.0, 1e3),
            e2e_ms: rng.range(0.0, 1e3),
            dropped: rng.f64() < 0.2,
            output: (0..rng.below(64)).map(|_| rng.normal() as f32).collect(),
        };
        assert_eq!(
            Response::decode(&resp.encode()).unwrap(),
            resp,
            "case {case}"
        );
    }
}

#[test]
fn prop_delta_replacement_never_exceeds_repack_and_respects_caps() {
    use graft::coordinator::placement::{place_delta, stamp};
    let cm = cm();
    for case in 0..20u64 {
        let mut rng = Rng::seed_from_u64(9100 + case);
        let n = 10 + rng.below(40);
        let mut specs = random_mixed_specs(&mut rng, &cm, n);
        let sched = Scheduler::new(cm.clone(), SchedulerOptions::default());
        let (old, _) = sched.plan(&specs);
        if old.placed_gpus().is_none() {
            continue; // degenerate draw: nothing deployed
        }
        // perturb a random subset of the demand (rates + budgets): the
        // live-reconfiguration trigger
        for s in specs.iter_mut() {
            if rng.below(4) == 0 {
                s.rate_rps *= rng.range(1.2, 2.0);
                s.budget_ms += rng.range(0.5, 3.0);
            }
        }
        let (new_plan, _) = sched.plan(&specs);
        let d = place_delta(&cm, &old, &new_plan, None, &[])
            .expect("scheduler-placed demand stays placeable");
        let total: usize = new_plan
            .stages()
            .map(|s| s.alloc.instances as usize)
            .sum();
        // conservation: every instance is pinned or migrated
        assert_eq!(d.pinned + d.migrated, total, "case {case}");
        // migration-minimality vs the full-repack oracle
        assert!(
            d.migrated <= d.repack_migrated,
            "case {case}: delta migrated {} > repack {}",
            d.migrated,
            d.repack_migrated
        );
        // never more GPUs than the repack (the fallback guarantees it)
        assert!(
            d.gpus_used <= d.repack_gpus,
            "case {case}: delta {} GPUs > repack {}",
            d.gpus_used,
            d.repack_gpus
        );
        // per-GPU caps hold on the (possibly partially vacated) usage
        let g = &cm.config().gpu;
        for u in &d.placement.usage {
            assert!(u.share <= g.max_share, "case {case}");
            assert!(u.mem_mb <= g.gpu_mem_mb + 1e-6, "case {case}");
        }
        // stamping the delta placement yields a fully placed plan
        let mut stamped = new_plan.clone();
        stamp(&mut stamped, &d.placement);
        assert!(stamped.placed_gpus().is_some(), "case {case}");
        // an unperturbed replay pins everything and migrates nothing
        let d0 = place_delta(&cm, &old, &old, None, &[]).unwrap();
        assert_eq!(d0.migrated, 0, "case {case}");
    }
}

/// Soft avoidance is *advisory only* (ISSUE acceptance): with an empty
/// constraint set the constrained entry points are byte-identical to
/// their historical unconstrained counterparts, and with suspect GPUs
/// active the uncapped packing vacates them entirely while the delta
/// path keeps its oracle bounds (coverage, caps, `migrated ≤
/// repack_migrated`, `gpus_used ≤ repack_gpus`).
#[test]
fn prop_soft_avoidance_advisory_and_bounded() {
    use graft::coordinator::placement::{
        place, place_constrained, place_delta, place_delta_constrained,
        PlacementConstraints,
    };
    let cm = cm();
    let g = &cm.config().gpu;
    for case in 0..20u64 {
        let mut rng = Rng::seed_from_u64(9700 + case);
        let n = 10 + rng.below(40);
        let mut specs = random_mixed_specs(&mut rng, &cm, n);
        let sched = Scheduler::new(cm.clone(), SchedulerOptions::default());
        let (old, _) = sched.plan(&specs);
        if old.placed_gpus().is_none() {
            continue; // degenerate draw: nothing deployed
        }
        // (a) empty constraints: bit-for-bit the unconstrained paths
        let p0 = place(&cm, &old, None).expect("placeable");
        let p1 = place_constrained(
            &cm,
            &old,
            None,
            &PlacementConstraints::default(),
        )
        .expect("placeable");
        assert_eq!(p0.usage, p1.usage, "case {case}");
        assert_eq!(p0.by_stage, p1.by_stage, "case {case}");
        for s in specs.iter_mut() {
            if rng.below(4) == 0 {
                s.rate_rps *= rng.range(1.2, 2.0);
                s.budget_ms += rng.range(0.5, 3.0);
            }
        }
        let (new_plan, _) = sched.plan(&specs);
        let d0 = place_delta(&cm, &old, &new_plan, None, &[]).expect("delta");
        let d1 = place_delta_constrained(
            &cm,
            &old,
            &new_plan,
            None,
            &PlacementConstraints::default(),
        )
        .expect("delta");
        assert_eq!(d0.pinned, d1.pinned, "case {case}");
        assert_eq!(d0.migrated, d1.migrated, "case {case}");
        assert_eq!(d0.fell_back, d1.fell_back, "case {case}");
        assert_eq!(d0.placement.usage, d1.placement.usage, "case {case}");
        assert_eq!(
            d0.placement.by_stage, d1.placement.by_stage,
            "case {case}"
        );
        // (b) suspects drawn from the deployed range: the uncapped
        // strict pass always succeeds, so suspects are fully vacated
        let deployed = p0.gpus().max(1);
        let mut soft: Vec<u32> =
            (0..1 + rng.below(2)).map(|_| rng.below(deployed) as u32).collect();
        soft.sort_unstable();
        soft.dedup();
        let cons = PlacementConstraints {
            soft_avoid: soft.clone(),
            ..Default::default()
        };
        let pc = place_constrained(&cm, &new_plan, None, &cons)
            .expect("uncapped constrained placement");
        for &s in &soft {
            let u = pc.usage.get(s as usize);
            assert!(
                u.map_or(true, |u| u.share == 0 && u.mem_mb == 0.0),
                "case {case}: suspect {s} used uncapped: {u:?}"
            );
        }
        // coverage + caps under constraints
        let want: Vec<usize> = new_plan
            .stages()
            .map(|s| s.alloc.instances as usize)
            .collect();
        let got: Vec<usize> = pc.by_stage.iter().map(|v| v.len()).collect();
        assert_eq!(got, want, "case {case}");
        for u in &pc.usage {
            assert!(u.share <= g.max_share, "case {case}");
            assert!(u.mem_mb <= g.gpu_mem_mb + 1e-6, "case {case}");
        }
        // (c) delta under soft constraints keeps the oracle bounds and
        // proactively unpins everything stamped onto a suspect
        let dc = place_delta_constrained(&cm, &old, &new_plan, None, &cons)
            .expect("delta under soft constraints");
        let total: usize = want.iter().sum();
        assert_eq!(dc.pinned + dc.migrated, total, "case {case}");
        assert!(
            dc.migrated <= dc.repack_migrated,
            "case {case}: delta migrated {} > repack {}",
            dc.migrated,
            dc.repack_migrated
        );
        assert!(
            dc.gpus_used <= dc.repack_gpus,
            "case {case}: delta {} GPUs > repack {}",
            dc.gpus_used,
            dc.repack_gpus
        );
        for u in &dc.placement.usage {
            assert!(u.share <= g.max_share, "case {case}");
            assert!(u.mem_mb <= g.gpu_mem_mb + 1e-6, "case {case}");
        }
        for &s in &soft {
            let u = dc.placement.usage.get(s as usize);
            assert!(
                u.map_or(true, |u| u.share == 0 && u.mem_mb == 0.0),
                "case {case}: delta left load on suspect {s}: {u:?}"
            );
        }
    }
}

#[test]
fn prop_shard_close_reroute_preserves_every_item() {
    for case in 0..40u64 {
        let mut rng = Rng::seed_from_u64(9300 + case);
        let shards = 2 + rng.below(6);
        let q: ShardedBatchQueue<u32> = ShardedBatchQueue::new(shards);
        let n = 20 + rng.below(200);
        for i in 0..n {
            assert!(q.push(qitem(i as u32)), "case {case}");
        }
        // close a random subset of shards (possibly all of them)
        let mut n_closed = 0;
        for s in 0..shards {
            if rng.below(2) == 0 {
                q.close_shard(s);
                n_closed += 1;
            }
        }
        // later pushes land only on open shards — or are rejected like
        // a closed queue when every shard is closed
        let m = rng.below(100);
        let mut accepted = 0;
        for i in 0..m {
            if q.push(qitem((n + i) as u32)) {
                accepted += 1;
            }
        }
        if n_closed < shards {
            assert_eq!(accepted, m, "case {case}");
            // an open shard existed at every close, so every closed
            // shard handed its backlog off completely
            for s in 0..shards {
                if q.shard_closed(s) {
                    assert_eq!(q.shard_len(s), 0, "case {case} shard {s}");
                }
            }
        } else {
            assert_eq!(accepted, 0, "case {case}");
            assert_eq!(q.metrics().rejected(), m as u64, "case {case}");
        }
        // exactly-once drain of everything accepted
        let mut got = Vec::new();
        loop {
            let b = q.try_pop_batch(rng.below(shards), 1 + rng.below(9));
            if b.is_empty() {
                break;
            }
            got.extend(b.into_iter().map(|w| w.ctx));
        }
        got.sort_unstable();
        let want: Vec<u32> = (0..(n + accepted) as u32).collect();
        assert_eq!(got, want, "case {case}");
    }
}

/// Robustness property (ISSUE acceptance): an injected worker panic is
/// contained at the execution boundary — it lands in the
/// `HealthRegistry` as one dead instance but never poisons serving
/// state past it.  Submits on the surviving shards afterwards complete
/// *exactly once*, with a response multiset identical to a fault-free
/// server running the same surviving demand.
#[test]
fn prop_worker_kill_contained_survivors_serve_exactly_once() {
    use std::sync::{mpsc, Arc};
    use std::time::{Duration, Instant};

    use graft::serving::{
        ExecutorMode, FaultEvent, FaultKind, FaultPlan, FaultyExecutor,
        Server, ServerOptions,
    };

    let _wd = common::watchdog(
        "prop_worker_kill_contained",
        Duration::from_secs(240),
    );
    let cm = cm();
    let mi = cm.model_index("inc").unwrap();
    let dims = cm.config().models[mi].dims.clone();
    let opts = |mode| ServerOptions {
        time_scale: 0.0,
        drop_on_slo: false,
        mode,
        ..Default::default()
    };
    // client 0 routes through an alignment stage (p=2 below the
    // repartition point); clients 1 and 2 feed the shared stage directly
    let specs: [(u32, usize, f64, f64); 3] =
        [(0, 2, 150.0, 30.0), (1, 3, 150.0, 30.0), (2, 3, 150.0, 30.0)];

    for case in 0..4u64 {
        for mode in [ExecutorMode::Threads, ExecutorMode::Pool] {
            let mut rng = Rng::seed_from_u64(9500 + case);
            // the surviving demand: random payloads for clients 1 and 2
            let mut demand: Vec<(u32, u32, Vec<f32>)> = Vec::new();
            for c in [1u32, 2u32] {
                let m = 5 + rng.below(20) as u32;
                for seq in 0..m {
                    let payload: Vec<f32> = (0..dims[3])
                        .map(|_| rng.normal() as f32)
                        .collect();
                    demand.push((c, seq, payload));
                }
            }
            let submit_demand = |server: &Server,
                                 tx: &mpsc::Sender<
                graft::serving::Response,
            >| {
                for (c, seq, payload) in &demand {
                    server.submit(
                        Request {
                            client_id: *c,
                            model: mi as u16,
                            p: 3,
                            seq: *seq,
                            t_capture_ms: 0.0,
                            upstream_ms: 0.0,
                            budget_ms: 1e9,
                            payload: payload.clone(),
                        },
                        tx.clone(),
                    );
                }
            };
            let collect = |rx: mpsc::Receiver<graft::serving::Response>| {
                let mut got: Vec<(u32, u32, Vec<u32>)> = rx
                    .iter()
                    .map(|r| {
                        assert!(!r.dropped, "case {case} {mode:?}");
                        (
                            r.client_id,
                            r.seq,
                            r.output.iter().map(|x| x.to_bits()).collect(),
                        )
                    })
                    .collect();
                got.sort();
                got
            };

            // --- faulty run: the first executed batch kills its worker.
            // Only client 0 has submitted by then, so the kill lands on
            // the alignment stage — the shared stage survives.
            let plan = common::plan_for(&cm, "inc", &specs);
            let faults = Arc::new(FaultPlan::new(
                case,
                vec![FaultEvent { at_tick: 1, kind: FaultKind::WorkerKill }],
            ));
            let server = Server::start(
                Arc::new(FaultyExecutor::new(
                    common::mock_executor(&cm),
                    faults,
                )),
                &cm,
                &plan,
                opts(mode),
            );
            let (tx1, rx1) = mpsc::channel();
            let k = 6u32;
            for seq in 0..k {
                server.submit(
                    Request {
                        client_id: 0,
                        model: mi as u16,
                        p: 2,
                        seq,
                        t_capture_ms: 0.0,
                        upstream_ms: 0.0,
                        budget_ms: 1e9,
                        payload: vec![0.5; dims[2]],
                    },
                    tx1.clone(),
                );
            }
            drop(tx1);
            // the kill is observed through the health ledger, not a
            // poisoned lock
            let deadline = Instant::now() + Duration::from_secs(20);
            while server.health().dead_instance_count() == 0 {
                assert!(
                    Instant::now() < deadline,
                    "case {case} {mode:?}: kill never landed"
                );
                std::thread::sleep(Duration::from_millis(2));
            }
            assert_eq!(
                server.health().dead_instance_count(),
                1,
                "case {case} {mode:?}"
            );
            assert_eq!(
                server.poison_recoveries(),
                0,
                "case {case} {mode:?}: panic leaked into a lock"
            );
            // --- surviving shards: same demand as the baseline below
            let (tx2, rx2) = mpsc::channel();
            submit_demand(&server, &tx2);
            drop(tx2);
            let survivors = collect(rx2);
            assert_eq!(
                survivors.len(),
                demand.len(),
                "case {case} {mode:?}: not exactly-once"
            );
            server.drain();
            // every phase-1 request reached exactly one outcome too
            assert_eq!(
                rx1.iter().count(),
                k as usize,
                "case {case} {mode:?}: silent loss on the dead stage"
            );

            // --- fault-free baseline of the surviving demand
            let plan = common::plan_for(&cm, "inc", &specs);
            let baseline_server = Server::start(
                common::mock_executor(&cm),
                &cm,
                &plan,
                opts(mode),
            );
            let (tx3, rx3) = mpsc::channel();
            submit_demand(&baseline_server, &tx3);
            drop(tx3);
            let baseline = collect(rx3);
            baseline_server.drain();
            assert_eq!(
                survivors, baseline,
                "case {case} {mode:?}: multiset diverged"
            );
        }
    }
}

/// The log-bucketed streaming histogram stays within its documented 1%
/// relative error of the exact-sample oracle, across distributions with
/// very different shapes (uniform, heavy-tailed, bimodal).
#[test]
fn prop_histogram_tracks_exact_percentiles_within_one_percent() {
    use graft::metrics::LatencyStats;
    use graft::obs::Histogram;

    for case in 0..40u64 {
        let mut rng = Rng::seed_from_u64(11_000 + case);
        let n = 200 + rng.below(5000);
        let shape = rng.below(3);
        let h = Histogram::new();
        let mut exact = LatencyStats::new();
        for _ in 0..n {
            // keep values inside the interior bucket range [1e-3, 1e7)
            let v = match shape {
                // uniform milliseconds
                0 => rng.range(0.05, 500.0),
                // heavy tail: exp of a normal, spans several decades
                1 => (rng.normal() * 2.0).exp().clamp(1e-2, 1e6),
                // bimodal: fast path vs slow path
                _ => {
                    if rng.below(4) == 0 {
                        rng.range(80.0, 120.0)
                    } else {
                        rng.range(0.5, 2.0)
                    }
                }
            };
            h.record(v);
            exact.record(v);
        }
        assert_eq!(h.count(), n as u64, "case {case}");
        for p in [1.0, 10.0, 50.0, 90.0, 95.0, 99.0, 99.9] {
            let approx = h.percentile(p);
            let truth = exact.percentile(p);
            assert!(
                (approx - truth).abs() / truth <= 0.01,
                "case {case} shape {shape} p{p}: approx {approx} vs exact {truth}"
            );
        }
        // extremes are exact, mean within the same bound
        assert_eq!(h.percentile(0.0), exact.percentile(0.0), "case {case}");
        assert_eq!(h.percentile(100.0), exact.percentile(100.0), "case {case}");
        assert!(
            (h.mean() - exact.mean()).abs() / exact.mean() <= 0.01,
            "case {case}"
        );
    }
}

/// Sampled tracing is pure observation: for any sampling rate the
/// response multiset (exact output bits, drop verdicts) is identical to
/// an untraced run of the same workload.
#[test]
fn prop_sampled_tracing_never_changes_responses() {
    use std::sync::mpsc;

    use graft::serving::{ExecutorMode, Server, ServerOptions, TraceOptions};

    let _wd = common::watchdog(
        "prop_tracing_response_invariance",
        Duration::from_secs(240),
    );
    let cm = cm();
    let mi = cm.model_index("inc").unwrap();
    let dims = cm.config().models[mi].dims.clone();
    let specs: [(u32, usize, f64, f64); 3] =
        [(0, 2, 150.0, 30.0), (1, 3, 150.0, 30.0), (2, 3, 150.0, 30.0)];

    for case in 0..3u64 {
        for mode in [ExecutorMode::Threads, ExecutorMode::Pool] {
            let mut run = |sample_every: u32| -> Vec<(u32, u32, bool, Vec<u32>)> {
                let mut rng = Rng::seed_from_u64(12_000 + case);
                let plan = common::plan_for(&cm, "inc", &specs);
                let server = Server::start(
                    common::mock_executor(&cm),
                    &cm,
                    &plan,
                    ServerOptions {
                        time_scale: 0.0,
                        drop_on_slo: false,
                        mode,
                        trace: TraceOptions { sample_every },
                        ..Default::default()
                    },
                );
                let (tx, rx) = mpsc::channel();
                let mut total = 0;
                for c in 0..3u32 {
                    let p = if c == 0 { 2 } else { 3 };
                    let m = 5 + rng.below(15) as u32;
                    for seq in 0..m {
                        server.submit(
                            Request {
                                client_id: c,
                                model: mi as u16,
                                p: p as u16,
                                seq,
                                t_capture_ms: 0.0,
                                upstream_ms: 0.0,
                                budget_ms: 1e9,
                                payload: (0..dims[p])
                                    .map(|_| rng.normal() as f32)
                                    .collect(),
                            },
                            tx.clone(),
                        );
                        total += 1;
                    }
                }
                drop(tx);
                let mut got: Vec<(u32, u32, bool, Vec<u32>)> = rx
                    .iter()
                    .take(total)
                    .map(|r| {
                        (
                            r.client_id,
                            r.seq,
                            r.dropped,
                            r.output.iter().map(|x| x.to_bits()).collect(),
                        )
                    })
                    .collect();
                assert_eq!(got.len(), total, "case {case} {mode:?}");
                let obs = server.obs();
                server.shutdown();
                if sample_every == 1 {
                    // everything sampled → everything traced
                    assert_eq!(
                        obs.traced_count(),
                        total as u64,
                        "case {case} {mode:?}"
                    );
                } else if sample_every == 0 {
                    assert_eq!(obs.traced_count(), 0, "case {case} {mode:?}");
                }
                got.sort();
                got
            };
            let untraced = run(0);
            for sample_every in [1u32, 3u32] {
                assert_eq!(
                    untraced,
                    run(sample_every),
                    "case {case} {mode:?} sample_every {sample_every}: \
                     tracing changed responses"
                );
            }
        }
    }
}
