//! Helpers shared by the integration/property test binaries (not a test
//! target itself: lives in `tests/common/`, pulled in via `mod common`).
#![allow(dead_code)]

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use graft::config::Config;
use graft::coordinator::repartition::{realign_group, RepartitionOptions};
use graft::coordinator::{ClientId, ExecutionPlan, FragmentSpec};
use graft::profiler::CostModel;
use graft::serving::MockExecutor;

/// Per-test deadlock guard: aborts the whole process (so `cargo test`
/// fails fast with a message) if the guard is still armed after
/// `limit`.  Drop disarms it.  This is what gives the concurrency suite
/// a *per-test* timeout — a deadlocked queue kills the run in seconds
/// instead of hanging CI until the job-level timeout.
pub struct Watchdog {
    armed: Arc<AtomicBool>,
}

impl Drop for Watchdog {
    fn drop(&mut self) {
        self.armed.store(false, Ordering::SeqCst);
    }
}

pub fn watchdog(label: &str, limit: Duration) -> Watchdog {
    let armed = Arc::new(AtomicBool::new(true));
    let flag = armed.clone();
    let label = label.to_string();
    std::thread::spawn(move || {
        let deadline = Instant::now() + limit;
        while flag.load(Ordering::SeqCst) {
            if Instant::now() >= deadline {
                eprintln!(
                    "WATCHDOG: test {label} still running after {limit:?} \
                     — aborting (likely deadlocked queue/executor)"
                );
                std::process::abort();
            }
            std::thread::sleep(Duration::from_millis(50));
        }
    });
    Watchdog { armed }
}

pub fn cm() -> CostModel {
    CostModel::new(Config::embedded())
}

/// Re-align a small same-model client set into an execution plan
/// (compiled partition points only, so the plan also runs on PJRT).
pub fn plan_for(
    cm: &CostModel,
    model: &str,
    specs: &[(u32, usize, f64, f64)],
) -> ExecutionPlan {
    let mi = cm.model_index(model).unwrap();
    let specs: Vec<FragmentSpec> = specs
        .iter()
        .map(|&(c, p, t, q)| FragmentSpec::single(ClientId(c), mi, p, t, q))
        .collect();
    let points = cm.config().models[mi].points();
    let plan = realign_group(
        cm,
        &specs,
        &RepartitionOptions { point_set: Some(points), ..Default::default() },
    );
    assert!(plan.infeasible.is_empty());
    plan
}

pub fn mock_executor(cm: &CostModel) -> Arc<MockExecutor> {
    let dims: HashMap<String, Vec<usize>> = cm
        .config()
        .models
        .iter()
        .map(|m| (m.name.clone(), m.dims.clone()))
        .collect();
    Arc::new(MockExecutor { dims })
}
