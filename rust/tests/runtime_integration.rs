//! Integration: the PJRT engine against the real AOT artifacts.
//!
//! Requires `make artifacts` to have run (skips, loudly, otherwise —
//! `make test` always builds artifacts first).

use std::path::PathBuf;

use graft::runtime::{Engine, Manifest};
use graft::util::Rng;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("SKIP: artifacts/ missing; run `make artifacts`");
                return;
            }
        }
    };
}

fn rand_rows(rng: &mut Rng, n: usize, dim: usize) -> Vec<Vec<f32>> {
    (0..n)
        .map(|_| (0..dim).map(|_| rng.normal() as f32).collect())
        .collect()
}

#[test]
fn manifest_covers_all_models_and_buckets() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    for name in ["inc", "res", "vgg", "mob", "vit"] {
        assert!(!m.fragments(name).is_empty(), "{name} missing");
        // whole-model fragment exists at batch 1
        let model = &m.models[name];
        let last = *model.points.last().unwrap();
        assert!(m.get(name, 0, last, 1).is_some());
    }
    assert_eq!(m.batches, vec![1, 2, 4, 8]);
}

#[test]
fn engine_runs_whole_model() {
    let dir = require_artifacts!();
    let engine = Engine::new(&dir).unwrap();
    let mf = engine.manifest();
    let dims = mf.models["vgg"].dims.clone();
    let mut rng = Rng::seed_from_u64(1);
    let rows = rand_rows(&mut rng, 2, dims[0]);
    let out = engine.run("vgg", 0, 6, &rows).unwrap();
    assert_eq!(out.batch, 2);
    assert_eq!(out.dim_out, *dims.last().unwrap());
    assert_eq!(out.data.len(), 2 * out.dim_out);
    assert!(out.data.iter().all(|x| x.is_finite()));
    // deterministic
    let out2 = engine.run("vgg", 0, 6, &rows).unwrap();
    assert_eq!(out.data, out2.data);
}

#[test]
fn fragment_composition_matches_whole_model() {
    // frag(0,L) == frag(p,L) ∘ frag(0,p) through two *different*
    // executables — this is the end-to-end numerical check that the
    // AOT pipeline, weight blobs and engine argument order all agree.
    let dir = require_artifacts!();
    let engine = Engine::new(&dir).unwrap();
    for (model, p) in [("vgg", 2usize), ("inc", 4), ("res", 8), ("mob", 2), ("vit", 2)]
    {
        let mf = engine.manifest();
        let dims = mf.models[model].dims.clone();
        let last = *mf.models[model].points.last().unwrap();
        let mut rng = Rng::seed_from_u64(7);
        let rows = rand_rows(&mut rng, 3, dims[0]);

        let whole = engine.run(model, 0, last, &rows).unwrap();
        let mid = engine.run(model, 0, p, &rows).unwrap();
        let mid_rows: Vec<Vec<f32>> = mid
            .data
            .chunks_exact(mid.dim_out)
            .map(|c| c.to_vec())
            .collect();
        let tail = engine.run(model, p, last, &mid_rows).unwrap();

        assert_eq!(whole.data.len(), tail.data.len(), "{model}");
        for (i, (a, b)) in whole.data.iter().zip(tail.data.iter()).enumerate()
        {
            assert!(
                (a - b).abs() <= 1e-3 * (1.0 + a.abs().max(b.abs())),
                "{model} p={p} idx {i}: {a} vs {b}"
            );
        }
    }
}

#[test]
fn partial_batches_are_padded() {
    let dir = require_artifacts!();
    let engine = Engine::new(&dir).unwrap();
    let dims = engine.manifest().models["vgg"].dims.clone();
    let mut rng = Rng::seed_from_u64(3);
    let rows = rand_rows(&mut rng, 3, dims[0]); // 3 -> bucket 4
    let out = engine.run("vgg", 0, 6, &rows).unwrap();
    assert_eq!(out.batch, 3);
    // row results must be independent of batch padding
    let single = engine.run("vgg", 0, 6, &rows[..1]).unwrap();
    for (a, b) in single.data.iter().zip(out.data.iter()) {
        assert!((a - b).abs() <= 1e-4, "{a} vs {b}");
    }
}

#[test]
fn engine_rejects_bad_inputs() {
    let dir = require_artifacts!();
    let engine = Engine::new(&dir).unwrap();
    assert!(engine.run("vgg", 0, 6, &[]).is_err());
    assert!(engine.run("vgg", 0, 6, &[vec![0.0; 7]]).is_err());
    assert!(engine.run("nope", 0, 6, &[vec![0.0; 256]]).is_err());
    // batch above the largest bucket
    let rows: Vec<Vec<f32>> = (0..9).map(|_| vec![0.0; 256]).collect();
    assert!(engine.run("vgg", 0, 6, &rows).is_err());
}

#[test]
fn warmup_compiles_requested_fragments() {
    let dir = require_artifacts!();
    let engine = Engine::new(&dir).unwrap();
    let n = engine
        .warmup(&[("vgg".to_string(), 0, 6), ("vgg".to_string(), 2, 6)])
        .unwrap();
    assert_eq!(n, 8); // 2 fragments x 4 batch buckets
}
