//! Analytical MPS GPU model + min-resource allocation search.
//!
//! Latency of fragment `(model, start, end)` at batch `b`, share `s`:
//!
//! ```text
//! lat(b, s) = T_ref(frag) * (alpha + (1 - alpha) * b) * (ref_share / s)^gamma
//! ```
//!
//! where `T_ref(frag) = server_ms_ref * Σ rel_cost[start..end]` is the
//! calibrated batch-1 latency at the reference share (Table 2 column at
//! share 30).  `gamma < 1` gives the sub-linear MPS speedup; `alpha` is
//! the un-amortised fixed fraction that makes batching pay off.  Shares
//! are discrete 1% units, batches are integers — the discreteness that
//! Fig 4 shows and that Graft's merging step exploits.

use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::config::{Config, ModelSpec};

/// A fragment of one model: layers `start+1 ..= end`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct FragmentId {
    pub model: usize, // index into Config::models
    pub start: usize,
    pub end: usize,
}

impl FragmentId {
    pub fn new(model: usize, start: usize, end: usize) -> Self {
        assert!(start < end, "empty fragment {start}..{end}");
        Self { model, start, end }
    }
}

/// A resource allocation for one fragment: `instances` instances, each
/// with `share`% of a GPU forming batches of (up to) `batch`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Alloc {
    pub batch: u32,
    pub share: u32,
    pub instances: u32,
    /// Execution latency of a full batch at this share (ms).
    pub latency_ms: f64,
    /// Aggregate achievable throughput across instances (RPS).
    pub throughput_rps: f64,
}

impl Alloc {
    /// Total GPU consumption in share percentage points.
    pub fn total_share(&self) -> u32 {
        self.share * self.instances
    }

    /// Resource margin `(q_a - q_d) / q_d` (paper §4.1).
    pub fn margin(&self, demand_rps: f64) -> f64 {
        (self.throughput_rps - demand_rps) / demand_rps
    }
}

/// Constraints on the allocation search.
#[derive(Debug, Clone, Copy)]
pub struct AllocConstraints {
    /// Cap on instances per fragment (paper §5.3 uses 5 at large scale).
    pub max_instances: u32,
    /// Cap on batch size (defaults to the GPU model's max_batch).
    pub max_batch: u32,
    /// GPU memory budget (MiB) for *this fragment's* instances, if any.
    pub mem_budget_mb: Option<f64>,
    /// Per-instance share ceiling (%).  The placement feedback loop
    /// tightens this below the GPU's `max_share` to split fat instances
    /// into placeable ones when first-fit packing fragments badly.
    pub max_share: u32,
    /// Per-instance memory ceiling (MiB): an instance above it can never
    /// be placed on a single GPU, so the placement-aware planner caps it
    /// at `gpu_mem_mb` rather than emitting an unpackable plan.
    pub max_instance_mem_mb: Option<f64>,
}

impl Default for AllocConstraints {
    fn default() -> Self {
        Self {
            max_instances: u32::MAX,
            max_batch: u32::MAX,
            mem_budget_mb: None,
            max_share: u32::MAX,
            max_instance_mem_mb: None,
        }
    }
}

/// Exact memo-cache key for one `min_alloc` query.  Budgets/rates are
/// keyed on their f64 bit patterns (a lossless "quantisation" onto the
/// f64 grid), so a cache hit returns *bit-identical* results to an
/// uncached search — the property the planner-equality proptests assert.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct AllocKey {
    frag: FragmentId,
    budget_bits: u64,
    rate_bits: u64,
    max_instances: u32,
    max_batch: u32,
    mem_bits: Option<u64>,
    max_share: u32,
    inst_mem_bits: Option<u64>,
}

impl AllocKey {
    fn new(
        frag: FragmentId,
        budget_ms: f64,
        demand_rps: f64,
        cons: &AllocConstraints,
    ) -> Self {
        Self {
            frag,
            budget_bits: budget_ms.to_bits(),
            rate_bits: demand_rps.to_bits(),
            max_instances: cons.max_instances,
            max_batch: cons.max_batch,
            mem_bits: cons.mem_budget_mb.map(f64::to_bits),
            max_share: cons.max_share,
            inst_mem_bits: cons.max_instance_mem_mb.map(f64::to_bits),
        }
    }

    fn shard(&self) -> usize {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        self.hash(&mut h);
        h.finish() as usize % CACHE_SHARDS
    }
}

const CACHE_SHARDS: usize = 16;
/// Per-shard entry cap; a full shard is cleared rather than evicted
/// (bounds long-running services without an LRU on the hot path).
const SHARD_CAPACITY: usize = 1 << 16;

/// Sharded `min_alloc` memo cache.  The allocation search is the
/// innermost loop of merging, the d_shared grid sweep, the suffix DP and
/// every parallel per-group worker; identical `(fragment, budget, rate,
/// constraints)` queries recur thousands of times per scheduling trigger
/// at scale, and across triggers under trigger-based re-planning.
#[derive(Debug, Default)]
struct AllocCache {
    shards: [RwLock<HashMap<AllocKey, Option<Alloc>>>; CACHE_SHARDS],
    hits: AtomicU64,
    misses: AtomicU64,
}

impl AllocCache {
    fn get(&self, key: &AllocKey) -> Option<Option<Alloc>> {
        let got =
            self.shards[key.shard()].read().unwrap().get(key).copied();
        match got {
            Some(v) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(v)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    fn insert(&self, key: AllocKey, value: Option<Alloc>) {
        let mut shard = self.shards[key.shard()].write().unwrap();
        if shard.len() >= SHARD_CAPACITY {
            shard.clear();
        }
        shard.insert(key, value);
    }
}

/// The analytical cost model over a configuration.
///
/// Cloning shares both the configuration and the allocation cache, so a
/// scheduler, its parallel re-alignment workers and the baselines all
/// pool their `min_alloc` results.
#[derive(Debug, Clone)]
pub struct CostModel {
    cfg: Arc<Config>,
    cache: Option<Arc<AllocCache>>,
}

impl CostModel {
    pub fn new(cfg: Arc<Config>) -> Self {
        Self { cfg, cache: Some(Arc::new(AllocCache::default())) }
    }

    /// A cost model with the allocation memo cache disabled (reference
    /// path for the cached-vs-uncached equality tests and benches).
    pub fn new_uncached(cfg: Arc<Config>) -> Self {
        Self { cfg, cache: None }
    }

    /// `(hits, misses)` of the allocation cache since construction.
    pub fn cache_stats(&self) -> (u64, u64) {
        match &self.cache {
            Some(c) => (
                c.hits.load(Ordering::Relaxed),
                c.misses.load(Ordering::Relaxed),
            ),
            None => (0, 0),
        }
    }

    pub fn config(&self) -> &Arc<Config> {
        &self.cfg
    }

    pub fn model_spec(&self, frag: FragmentId) -> &ModelSpec {
        &self.cfg.models[frag.model]
    }

    pub fn model_index(&self, name: &str) -> Option<usize> {
        self.cfg.models.iter().position(|m| m.name == name)
    }

    /// Calibrated batch-1 latency at the reference share (ms).
    pub fn t_ref_ms(&self, frag: FragmentId) -> f64 {
        let m = self.model_spec(frag);
        m.server_ms_ref * m.rel_cost_range(frag.start, frag.end)
    }

    /// Fragment execution latency (ms) at batch `b`, share `s`%.
    pub fn latency_ms(&self, frag: FragmentId, batch: u32, share: u32) -> f64 {
        assert!(batch >= 1 && share >= 1);
        let g = &self.cfg.gpu;
        let batchf = g.batch_alpha + (1.0 - g.batch_alpha) * batch as f64;
        let sharef = (g.ref_share / share as f64).powf(g.share_gamma);
        self.t_ref_ms(frag) * batchf * sharef
    }

    /// Aggregate throughput (RPS) of one instance at batch `b`, share `s`%.
    pub fn throughput_rps(&self, frag: FragmentId, batch: u32, share: u32) -> f64 {
        batch as f64 / self.latency_ms(frag, batch, share) * 1000.0
    }

    /// Minimum integer share (%) for which `latency <= budget_ms`, if any.
    pub fn min_share_for(
        &self,
        frag: FragmentId,
        batch: u32,
        budget_ms: f64,
    ) -> Option<u32> {
        if budget_ms <= 0.0 {
            return None;
        }
        let g = &self.cfg.gpu;
        let batchf = g.batch_alpha + (1.0 - g.batch_alpha) * batch as f64;
        let base = self.t_ref_ms(frag) * batchf;
        // share >= ref_share * (base / budget)^(1/gamma)
        let s = g.ref_share * (base / budget_ms).powf(1.0 / g.share_gamma);
        let unit = g.share_unit.max(1);
        let units = (s / unit as f64).ceil().max(1.0);
        // guard before casting: tiny budgets demand astronomic shares
        if !units.is_finite() || units * unit as f64 > g.max_share as f64 {
            return None;
        }
        Some(units as u32 * unit)
    }

    /// GPU memory (MiB) of one instance of `frag` at batch `b`.
    pub fn instance_mem_mb(&self, frag: FragmentId, batch: u32) -> f64 {
        let m = self.model_spec(frag);
        let g = &self.cfg.gpu;
        let act_kb: f64 = m.act_kb[frag.start..frag.end].iter().sum();
        m.frag_params_mb(frag.start, frag.end)
            + act_kb * g.act_mem_scale_mb_per_kb * batch as f64
    }

    /// Min-total-share allocation serving `demand_rps` with per-request
    /// execution latency `<= budget_ms` (the caller applies the /2
    /// worst-case-queueing rule of §4.3 before calling).
    ///
    /// Memoised: results are shared across the d_shared grid sweep, the
    /// suffix DP and the parallel per-group workers through the sharded
    /// [`AllocCache`]; keys are exact, so cached and uncached searches
    /// are interchangeable.
    pub fn min_alloc(
        &self,
        frag: FragmentId,
        budget_ms: f64,
        demand_rps: f64,
        cons: AllocConstraints,
    ) -> Option<Alloc> {
        let Some(cache) = &self.cache else {
            return self.min_alloc_uncached(frag, budget_ms, demand_rps, cons);
        };
        let key = AllocKey::new(frag, budget_ms, demand_rps, &cons);
        if let Some(v) = cache.get(&key) {
            return v;
        }
        let v = self.min_alloc_uncached(frag, budget_ms, demand_rps, cons);
        cache.insert(key, v);
        v
    }

    /// The underlying allocation search: batch sizes from the compiled
    /// buckets; for each, the minimal feasible share, then also trading
    /// share up to save a whole instance (the only regime where more
    /// share lowers total consumption, since total ~ s^(1-gamma) grows
    /// in s otherwise).
    fn min_alloc_uncached(
        &self,
        frag: FragmentId,
        budget_ms: f64,
        demand_rps: f64,
        cons: AllocConstraints,
    ) -> Option<Alloc> {
        if budget_ms <= 0.0 || demand_rps <= 0.0 {
            return None;
        }
        let g = &self.cfg.gpu;
        let max_batch = cons.max_batch.min(g.max_batch).max(1);
        let share_cap = cons.max_share.min(g.max_share);
        let mut best: Option<Alloc> = None;

        for &batch in g.batch_buckets.iter().filter(|&&b| b <= max_batch) {
            let Some(s_min) = self.min_share_for(frag, batch, budget_ms)
            else {
                continue; // larger batches only get slower — but share
                          // saturation depends on batch, keep scanning
            };
            if s_min > share_cap {
                continue; // only more share could meet the budget
            }
            if let Some(mem) = cons.max_instance_mem_mb {
                if self.instance_mem_mb(frag, batch) > mem {
                    continue; // instance would never fit one GPU
                }
            }
            if let Some(mem) = cons.mem_budget_mb {
                if self.instance_mem_mb(frag, batch) > mem {
                    continue;
                }
            }
            // candidate A: minimal share, as many instances as needed
            let (shares, n_shares) =
                self.candidate_shares(frag, batch, s_min, demand_rps);
            for &share in &shares[..n_shares] {
                if share > share_cap {
                    continue;
                }
                let lat = self.latency_ms(frag, batch, share);
                if lat > budget_ms + 1e-9 {
                    continue;
                }
                let per_inst = batch as f64 / lat * 1000.0;
                let inst = (demand_rps / per_inst).ceil().max(1.0) as u32;
                if inst > cons.max_instances {
                    continue;
                }
                if let Some(mem) = cons.mem_budget_mb {
                    if self.instance_mem_mb(frag, batch) * inst as f64 > mem {
                        continue;
                    }
                }
                let cand = Alloc {
                    batch,
                    share,
                    instances: inst,
                    latency_ms: lat,
                    throughput_rps: per_inst * inst as f64,
                };
                if better(&cand, &best) {
                    best = Some(cand);
                }
            }
        }
        best
    }

    /// Shares worth trying for a batch: the minimal feasible one plus the
    /// minimal share achieving each smaller instance count, deduplicated
    /// (consecutive instance targets often land on the same share-grid
    /// point, which previously wasted inner-loop iterations).  Returns a
    /// fixed-capacity buffer (no heap allocation — this sits on the
    /// scheduler's innermost loop); instance-count targets beyond the
    /// capacity cannot win anyway (total share grows with s^(1-gamma)).
    fn candidate_shares(
        &self,
        frag: FragmentId,
        batch: u32,
        s_min: u32,
        demand_rps: f64,
    ) -> ([u32; 8], usize) {
        let g = &self.cfg.gpu;
        let mut out = [0u32; 8];
        let mut n = 0;
        out[n] = s_min;
        n += 1;
        let lat_min = self.latency_ms(frag, batch, s_min);
        let inst_at_min =
            (demand_rps * lat_min / (batch as f64 * 1000.0)).ceil() as u32;
        // target inst' < inst_at_min: need per-instance throughput
        // demand/inst' => latency <= batch*1000*inst'/demand
        for target in 1..inst_at_min.max(1).min(out.len() as u32) {
            let lat_needed = batch as f64 * 1000.0 * target as f64 / demand_rps;
            if let Some(s) = self.min_share_for(frag, batch, lat_needed) {
                if s > s_min
                    && s <= g.max_share
                    && !out[..n].contains(&s)
                {
                    out[n] = s;
                    n += 1;
                }
            }
        }
        (out, n)
    }

    /// Energy (J) consumed by an allocation busy for `busy_s` seconds.
    pub fn energy_j(&self, alloc: &Alloc, busy_s: f64, util: f64) -> f64 {
        let g = &self.cfg.gpu;
        let w = alloc.instances as f64
            * (g.p_share_w_per_pct * alloc.share as f64 * util + g.p_base_w);
        w * busy_s
    }
}

fn better(cand: &Alloc, best: &Option<Alloc>) -> bool {
    match best {
        None => true,
        Some(b) => {
            let (c, bt) = (cand.total_share(), b.total_share());
            c < bt
                // tie-break: prefer higher throughput (more margin), then
                // fewer instances (less memory)
                || (c == bt
                    && (cand.throughput_rps > b.throughput_rps + 1e-9
                        || (cand.throughput_rps >= b.throughput_rps - 1e-9
                            && cand.instances < b.instances)))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cm() -> CostModel {
        CostModel::new(Config::embedded())
    }

    fn frag(cm: &CostModel, name: &str) -> FragmentId {
        let i = cm.model_index(name).unwrap();
        FragmentId::new(i, 0, cm.config().models[i].layers)
    }

    #[test]
    fn table2_calibration() {
        // batch 1, share 30 must reproduce Table 2's server latency column
        let cm = cm();
        for (name, ms) in
            [("inc", 29.0), ("res", 30.0), ("vgg", 6.0), ("mob", 19.0), ("vit", 58.0)]
        {
            let f = frag(&cm, name);
            let got = cm.latency_ms(f, 1, 30);
            assert!((got - ms).abs() < 1e-9, "{name}: {got} vs {ms}");
        }
    }

    #[test]
    fn latency_monotonic_in_share_and_batch() {
        let cm = cm();
        let f = frag(&cm, "inc");
        assert!(cm.latency_ms(f, 1, 60) < cm.latency_ms(f, 1, 30));
        assert!(cm.latency_ms(f, 8, 30) > cm.latency_ms(f, 1, 30));
        // but throughput grows with batch
        assert!(cm.throughput_rps(f, 8, 30) > cm.throughput_rps(f, 1, 30));
    }

    #[test]
    fn min_share_matches_latency() {
        let cm = cm();
        let f = frag(&cm, "inc");
        let s = cm.min_share_for(f, 4, 40.0).unwrap();
        let unit = cm.config().gpu.share_unit;
        assert!(cm.latency_ms(f, 4, s) <= 40.0);
        assert_eq!(s % unit, 0, "share {s} not on the {unit}% grid");
        if s > unit {
            // one grid step below no longer meets the budget
            assert!(cm.latency_ms(f, 4, s - unit) > 40.0);
        }
    }

    #[test]
    fn min_share_infeasible_when_budget_tiny() {
        let cm = cm();
        let f = frag(&cm, "vit");
        assert!(cm.min_share_for(f, 32, 0.01).is_none());
        assert!(cm.min_share_for(f, 1, -5.0).is_none());
    }

    #[test]
    fn min_alloc_meets_demand_and_budget() {
        let cm = cm();
        let f = frag(&cm, "inc");
        let a = cm
            .min_alloc(f, 25.0, 200.0, AllocConstraints::default())
            .expect("feasible");
        assert!(a.latency_ms <= 25.0 + 1e-9);
        assert!(a.throughput_rps >= 200.0 - 1e-9);
        assert!(a.total_share() > 0);
    }

    #[test]
    fn min_alloc_batching_pays_off() {
        // Serving 200 RPS with a relaxed budget should use batch > 1 and
        // consume (weakly) less than forcing batch = 1.
        let cm = cm();
        let f = frag(&cm, "inc");
        let free = cm
            .min_alloc(f, 60.0, 200.0, AllocConstraints::default())
            .unwrap();
        let b1 = cm
            .min_alloc(
                f,
                60.0,
                200.0,
                AllocConstraints { max_batch: 1, ..Default::default() },
            )
            .unwrap();
        assert!(free.batch > 1, "expected batching, got {free:?}");
        assert!(free.total_share() <= b1.total_share());
    }

    #[test]
    fn min_alloc_discreteness_fig4() {
        // Fig 4: higher demanded throughput does NOT always cost more —
        // the discrete (batch, share, instance) lattice yields flat
        // regions (free extra throughput) separated by jumps.
        let cm = cm();
        let f = frag(&cm, "inc");
        let shares: Vec<u32> = (1..=40)
            .map(|k| {
                cm.min_alloc(
                    f,
                    25.0,
                    10.0 * k as f64,
                    AllocConstraints::default(),
                )
                .map(|a| a.total_share())
                .unwrap()
            })
            .collect();
        // non-decreasing overall ...
        assert!(shares.windows(2).all(|w| w[1] >= w[0]), "{shares:?}");
        // ... with at least one flat step (the Fig-4 discreteness)
        assert!(
            shares.windows(2).any(|w| w[1] == w[0]),
            "no flat step in {shares:?}"
        );
        // ... and at least one jump of several share units
        assert!(
            shares.windows(2).any(|w| w[1] >= w[0] + 2),
            "no jump in {shares:?}"
        );
    }

    #[test]
    fn min_alloc_respects_instance_cap() {
        let cm = cm();
        let f = frag(&cm, "inc");
        let capped = cm
            .min_alloc(
                f,
                40.0,
                300.0,
                AllocConstraints { max_instances: 5, ..Default::default() },
            )
            .unwrap();
        assert!(capped.instances <= 5);
        // an infeasible cap yields None rather than a violating alloc
        let impossible = cm.min_alloc(
            f,
            8.0,
            5000.0,
            AllocConstraints { max_instances: 1, ..Default::default() },
        );
        assert!(impossible.is_none());
    }

    #[test]
    fn min_alloc_respects_share_ceiling() {
        let cm = cm();
        let f = frag(&cm, "inc");
        let free = cm
            .min_alloc(f, 40.0, 300.0, AllocConstraints::default())
            .unwrap();
        let ceiling = free.share.saturating_sub(cm.config().gpu.share_unit);
        if ceiling >= cm.config().gpu.share_unit {
            let capped = cm.min_alloc(
                f,
                40.0,
                300.0,
                AllocConstraints { max_share: ceiling, ..Default::default() },
            );
            if let Some(a) = capped {
                assert!(a.share <= ceiling, "{a:?} above ceiling {ceiling}");
                // forcing away from the optimum never lowers total cost
                assert!(a.total_share() >= free.total_share());
            }
        }
        // a ceiling below the minimal feasible share is infeasible
        let s_min = cm.min_share_for(f, 1, 40.0).unwrap();
        assert!(cm
            .min_alloc(
                f,
                40.0,
                1.0,
                AllocConstraints { max_share: s_min - 1, ..Default::default() },
            )
            .is_none());
    }

    #[test]
    fn min_alloc_respects_instance_mem_ceiling() {
        let cm = cm();
        let f = frag(&cm, "vgg");
        let free = cm
            .min_alloc(f, 60.0, 200.0, AllocConstraints::default())
            .unwrap();
        let per_inst = cm.instance_mem_mb(f, free.batch);
        // a generous per-instance ceiling changes nothing
        let same = cm
            .min_alloc(
                f,
                60.0,
                200.0,
                AllocConstraints {
                    max_instance_mem_mb: Some(per_inst + 1.0),
                    ..Default::default()
                },
            )
            .unwrap();
        assert_eq!(free, same);
        // a ceiling below the batch-1 footprint is infeasible
        let floor = cm.instance_mem_mb(f, 1);
        assert!(cm
            .min_alloc(
                f,
                60.0,
                200.0,
                AllocConstraints {
                    max_instance_mem_mb: Some(floor / 2.0),
                    ..Default::default()
                },
            )
            .is_none());
        // a ceiling between batch-1 and the free batch forces a smaller
        // batch (every returned instance fits the ceiling)
        if per_inst > floor {
            let capped = cm
                .min_alloc(
                    f,
                    60.0,
                    200.0,
                    AllocConstraints {
                        max_instance_mem_mb: Some(per_inst - 1e-9),
                        ..Default::default()
                    },
                )
                .unwrap();
            assert!(cm.instance_mem_mb(f, capped.batch) < per_inst);
        }
    }

    #[test]
    fn instance_mem_grows_with_batch_and_span() {
        let cm = cm();
        let i = cm.model_index("res").unwrap();
        let whole = FragmentId::new(i, 0, 16);
        let tail = FragmentId::new(i, 8, 16);
        assert!(cm.instance_mem_mb(whole, 1) > cm.instance_mem_mb(tail, 1));
        assert!(cm.instance_mem_mb(whole, 8) > cm.instance_mem_mb(whole, 1));
    }

    #[test]
    fn cached_min_alloc_identical_to_uncached() {
        // exact-bit cache keys: the memoised search must return the same
        // Option<Alloc> as the reference search, including on repeats
        // (cache hits) and for infeasible queries (negative caching)
        let cfg = Config::embedded();
        let cached = CostModel::new(cfg.clone());
        let plain = CostModel::new_uncached(cfg);
        let mut rng = crate::util::Rng::seed_from_u64(0xA110C);
        let mut queries = Vec::new();
        for _ in 0..200 {
            let model = rng.below(cached.cfg.models.len());
            let layers = cached.cfg.models[model].layers;
            let start = rng.below(layers);
            let end = start + 1 + rng.below(layers - start);
            let frag = FragmentId::new(model, start, end);
            let budget = rng.range(0.1, 200.0);
            let rate = rng.range(0.5, 500.0);
            let cons = AllocConstraints {
                max_instances: 1 + rng.below(8) as u32,
                ..Default::default()
            };
            queries.push((frag, budget, rate, cons));
        }
        for _pass in 0..2 {
            for &(frag, budget, rate, cons) in &queries {
                assert_eq!(
                    cached.min_alloc(frag, budget, rate, cons),
                    plain.min_alloc(frag, budget, rate, cons),
                    "{frag:?} b={budget} q={rate}"
                );
            }
        }
        let (hits, misses) = cached.cache_stats();
        assert!(hits >= queries.len() as u64, "no cache hits: {hits}");
        assert!(misses <= queries.len() as u64);
        // clones share the cache
        let clone = cached.clone();
        let before = clone.cache_stats().0;
        let (frag, budget, rate, cons) = queries[0];
        let _ = clone.min_alloc(frag, budget, rate, cons);
        assert!(clone.cache_stats().0 > before);
    }

    #[test]
    fn candidate_shares_deduplicated() {
        let cm = cm();
        for name in ["inc", "res", "vgg", "mob", "vit"] {
            let f = frag(&cm, name);
            for &batch in &[1u32, 4, 16] {
                for demand in [5.0, 60.0, 300.0, 900.0] {
                    let Some(s_min) = cm.min_share_for(f, batch, 30.0)
                    else {
                        continue;
                    };
                    let (shares, n) =
                        cm.candidate_shares(f, batch, s_min, demand);
                    for i in 0..n {
                        for j in i + 1..n {
                            assert_ne!(
                                shares[i], shares[j],
                                "{name} b={batch} q={demand}: {:?}",
                                &shares[..n]
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn energy_scales_with_share_and_time() {
        let cm = cm();
        let a = Alloc { batch: 1, share: 30, instances: 2, latency_ms: 10.0, throughput_rps: 100.0 };
        let e1 = cm.energy_j(&a, 1.0, 1.0);
        let e2 = cm.energy_j(&a, 2.0, 1.0);
        assert!((e2 - 2.0 * e1).abs() < 1e-9);
        let half = cm.energy_j(&a, 1.0, 0.5);
        assert!(half < e1);
    }
}
