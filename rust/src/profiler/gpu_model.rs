//! Analytical MPS GPU model + min-resource allocation search.
//!
//! Latency of fragment `(model, start, end)` at batch `b`, share `s`:
//!
//! ```text
//! lat(b, s) = T_ref(frag) * (alpha + (1 - alpha) * b) * (ref_share / s)^gamma
//! ```
//!
//! where `T_ref(frag) = server_ms_ref * Σ rel_cost[start..end]` is the
//! calibrated batch-1 latency at the reference share (Table 2 column at
//! share 30).  `gamma < 1` gives the sub-linear MPS speedup; `alpha` is
//! the un-amortised fixed fraction that makes batching pay off.  Shares
//! are discrete 1% units, batches are integers — the discreteness that
//! Fig 4 shows and that Graft's merging step exploits.

use std::sync::Arc;

use crate::config::{Config, ModelSpec};

/// A fragment of one model: layers `start+1 ..= end`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct FragmentId {
    pub model: usize, // index into Config::models
    pub start: usize,
    pub end: usize,
}

impl FragmentId {
    pub fn new(model: usize, start: usize, end: usize) -> Self {
        assert!(start < end, "empty fragment {start}..{end}");
        Self { model, start, end }
    }
}

/// A resource allocation for one fragment: `instances` instances, each
/// with `share`% of a GPU forming batches of (up to) `batch`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Alloc {
    pub batch: u32,
    pub share: u32,
    pub instances: u32,
    /// Execution latency of a full batch at this share (ms).
    pub latency_ms: f64,
    /// Aggregate achievable throughput across instances (RPS).
    pub throughput_rps: f64,
}

impl Alloc {
    /// Total GPU consumption in share percentage points.
    pub fn total_share(&self) -> u32 {
        self.share * self.instances
    }

    /// Resource margin `(q_a - q_d) / q_d` (paper §4.1).
    pub fn margin(&self, demand_rps: f64) -> f64 {
        (self.throughput_rps - demand_rps) / demand_rps
    }
}

/// Constraints on the allocation search.
#[derive(Debug, Clone, Copy)]
pub struct AllocConstraints {
    /// Cap on instances per fragment (paper §5.3 uses 5 at large scale).
    pub max_instances: u32,
    /// Cap on batch size (defaults to the GPU model's max_batch).
    pub max_batch: u32,
    /// GPU memory budget (MiB) for *this fragment's* instances, if any.
    pub mem_budget_mb: Option<f64>,
}

impl Default for AllocConstraints {
    fn default() -> Self {
        Self { max_instances: u32::MAX, max_batch: u32::MAX, mem_budget_mb: None }
    }
}

/// The analytical cost model over a configuration.
#[derive(Debug, Clone)]
pub struct CostModel {
    cfg: Arc<Config>,
}

impl CostModel {
    pub fn new(cfg: Arc<Config>) -> Self {
        Self { cfg }
    }

    pub fn config(&self) -> &Arc<Config> {
        &self.cfg
    }

    pub fn model_spec(&self, frag: FragmentId) -> &ModelSpec {
        &self.cfg.models[frag.model]
    }

    pub fn model_index(&self, name: &str) -> Option<usize> {
        self.cfg.models.iter().position(|m| m.name == name)
    }

    /// Calibrated batch-1 latency at the reference share (ms).
    pub fn t_ref_ms(&self, frag: FragmentId) -> f64 {
        let m = self.model_spec(frag);
        m.server_ms_ref * m.rel_cost_range(frag.start, frag.end)
    }

    /// Fragment execution latency (ms) at batch `b`, share `s`%.
    pub fn latency_ms(&self, frag: FragmentId, batch: u32, share: u32) -> f64 {
        assert!(batch >= 1 && share >= 1);
        let g = &self.cfg.gpu;
        let batchf = g.batch_alpha + (1.0 - g.batch_alpha) * batch as f64;
        let sharef = (g.ref_share / share as f64).powf(g.share_gamma);
        self.t_ref_ms(frag) * batchf * sharef
    }

    /// Aggregate throughput (RPS) of one instance at batch `b`, share `s`%.
    pub fn throughput_rps(&self, frag: FragmentId, batch: u32, share: u32) -> f64 {
        batch as f64 / self.latency_ms(frag, batch, share) * 1000.0
    }

    /// Minimum integer share (%) for which `latency <= budget_ms`, if any.
    pub fn min_share_for(
        &self,
        frag: FragmentId,
        batch: u32,
        budget_ms: f64,
    ) -> Option<u32> {
        if budget_ms <= 0.0 {
            return None;
        }
        let g = &self.cfg.gpu;
        let batchf = g.batch_alpha + (1.0 - g.batch_alpha) * batch as f64;
        let base = self.t_ref_ms(frag) * batchf;
        // share >= ref_share * (base / budget)^(1/gamma)
        let s = g.ref_share * (base / budget_ms).powf(1.0 / g.share_gamma);
        let unit = g.share_unit.max(1);
        let units = (s / unit as f64).ceil().max(1.0);
        // guard before casting: tiny budgets demand astronomic shares
        if !units.is_finite() || units * unit as f64 > g.max_share as f64 {
            return None;
        }
        Some(units as u32 * unit)
    }

    /// GPU memory (MiB) of one instance of `frag` at batch `b`.
    pub fn instance_mem_mb(&self, frag: FragmentId, batch: u32) -> f64 {
        let m = self.model_spec(frag);
        let g = &self.cfg.gpu;
        let act_kb: f64 = m.act_kb[frag.start..frag.end].iter().sum();
        m.frag_params_mb(frag.start, frag.end)
            + act_kb * g.act_mem_scale_mb_per_kb * batch as f64
    }

    /// Min-total-share allocation serving `demand_rps` with per-request
    /// execution latency `<= budget_ms` (the caller applies the /2
    /// worst-case-queueing rule of §4.3 before calling).
    ///
    /// Searches batch sizes 1..=max_batch; for each, the minimal feasible
    /// share, then also tries trading share up to save a whole instance
    /// (the only regime where more share lowers total consumption, since
    /// total ~ s^(1-gamma) grows in s otherwise).
    pub fn min_alloc(
        &self,
        frag: FragmentId,
        budget_ms: f64,
        demand_rps: f64,
        cons: AllocConstraints,
    ) -> Option<Alloc> {
        if budget_ms <= 0.0 || demand_rps <= 0.0 {
            return None;
        }
        let g = &self.cfg.gpu;
        let max_batch = cons.max_batch.min(g.max_batch).max(1);
        let mut best: Option<Alloc> = None;

        for &batch in g.batch_buckets.iter().filter(|&&b| b <= max_batch) {
            let Some(s_min) = self.min_share_for(frag, batch, budget_ms)
            else {
                continue; // larger batches only get slower — but share
                          // saturation depends on batch, keep scanning
            };
            if let Some(mem) = cons.mem_budget_mb {
                if self.instance_mem_mb(frag, batch) > mem {
                    continue;
                }
            }
            // candidate A: minimal share, as many instances as needed
            let (shares, n_shares) =
                self.candidate_shares(frag, batch, s_min, demand_rps);
            for &share in &shares[..n_shares] {
                let lat = self.latency_ms(frag, batch, share);
                if lat > budget_ms + 1e-9 {
                    continue;
                }
                let per_inst = batch as f64 / lat * 1000.0;
                let inst = (demand_rps / per_inst).ceil().max(1.0) as u32;
                if inst > cons.max_instances {
                    continue;
                }
                if let Some(mem) = cons.mem_budget_mb {
                    if self.instance_mem_mb(frag, batch) * inst as f64 > mem {
                        continue;
                    }
                }
                let cand = Alloc {
                    batch,
                    share,
                    instances: inst,
                    latency_ms: lat,
                    throughput_rps: per_inst * inst as f64,
                };
                if better(&cand, &best) {
                    best = Some(cand);
                }
            }
        }
        best
    }

    /// Shares worth trying for a batch: the minimal feasible one plus the
    /// minimal share achieving each smaller instance count.  Returns a
    /// fixed-capacity buffer (no heap allocation — this sits on the
    /// scheduler's innermost loop); instance-count targets beyond the
    /// capacity cannot win anyway (total share grows with s^(1-gamma)).
    fn candidate_shares(
        &self,
        frag: FragmentId,
        batch: u32,
        s_min: u32,
        demand_rps: f64,
    ) -> ([u32; 8], usize) {
        let g = &self.cfg.gpu;
        let mut out = [0u32; 8];
        let mut n = 0;
        out[n] = s_min;
        n += 1;
        let lat_min = self.latency_ms(frag, batch, s_min);
        let inst_at_min =
            (demand_rps * lat_min / (batch as f64 * 1000.0)).ceil() as u32;
        // target inst' < inst_at_min: need per-instance throughput
        // demand/inst' => latency <= batch*1000*inst'/demand
        for target in 1..inst_at_min.max(1).min(out.len() as u32) {
            let lat_needed = batch as f64 * 1000.0 * target as f64 / demand_rps;
            if let Some(s) = self.min_share_for_latency(frag, batch, lat_needed)
            {
                if s > s_min && s <= g.max_share {
                    out[n] = s;
                    n += 1;
                }
            }
        }
        (out, n)
    }

    fn min_share_for_latency(
        &self,
        frag: FragmentId,
        batch: u32,
        lat_ms: f64,
    ) -> Option<u32> {
        self.min_share_for(frag, batch, lat_ms)
    }

    /// Energy (J) consumed by an allocation busy for `busy_s` seconds.
    pub fn energy_j(&self, alloc: &Alloc, busy_s: f64, util: f64) -> f64 {
        let g = &self.cfg.gpu;
        let w = alloc.instances as f64
            * (g.p_share_w_per_pct * alloc.share as f64 * util + g.p_base_w);
        w * busy_s
    }
}

fn better(cand: &Alloc, best: &Option<Alloc>) -> bool {
    match best {
        None => true,
        Some(b) => {
            let (c, bt) = (cand.total_share(), b.total_share());
            c < bt
                // tie-break: prefer higher throughput (more margin), then
                // fewer instances (less memory)
                || (c == bt
                    && (cand.throughput_rps > b.throughput_rps + 1e-9
                        || (cand.throughput_rps >= b.throughput_rps - 1e-9
                            && cand.instances < b.instances)))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cm() -> CostModel {
        CostModel::new(Config::embedded())
    }

    fn frag(cm: &CostModel, name: &str) -> FragmentId {
        let i = cm.model_index(name).unwrap();
        FragmentId::new(i, 0, cm.config().models[i].layers)
    }

    #[test]
    fn table2_calibration() {
        // batch 1, share 30 must reproduce Table 2's server latency column
        let cm = cm();
        for (name, ms) in
            [("inc", 29.0), ("res", 30.0), ("vgg", 6.0), ("mob", 19.0), ("vit", 58.0)]
        {
            let f = frag(&cm, name);
            let got = cm.latency_ms(f, 1, 30);
            assert!((got - ms).abs() < 1e-9, "{name}: {got} vs {ms}");
        }
    }

    #[test]
    fn latency_monotonic_in_share_and_batch() {
        let cm = cm();
        let f = frag(&cm, "inc");
        assert!(cm.latency_ms(f, 1, 60) < cm.latency_ms(f, 1, 30));
        assert!(cm.latency_ms(f, 8, 30) > cm.latency_ms(f, 1, 30));
        // but throughput grows with batch
        assert!(cm.throughput_rps(f, 8, 30) > cm.throughput_rps(f, 1, 30));
    }

    #[test]
    fn min_share_matches_latency() {
        let cm = cm();
        let f = frag(&cm, "inc");
        let s = cm.min_share_for(f, 4, 40.0).unwrap();
        let unit = cm.config().gpu.share_unit;
        assert!(cm.latency_ms(f, 4, s) <= 40.0);
        assert_eq!(s % unit, 0, "share {s} not on the {unit}% grid");
        if s > unit {
            // one grid step below no longer meets the budget
            assert!(cm.latency_ms(f, 4, s - unit) > 40.0);
        }
    }

    #[test]
    fn min_share_infeasible_when_budget_tiny() {
        let cm = cm();
        let f = frag(&cm, "vit");
        assert!(cm.min_share_for(f, 32, 0.01).is_none());
        assert!(cm.min_share_for(f, 1, -5.0).is_none());
    }

    #[test]
    fn min_alloc_meets_demand_and_budget() {
        let cm = cm();
        let f = frag(&cm, "inc");
        let a = cm
            .min_alloc(f, 25.0, 200.0, AllocConstraints::default())
            .expect("feasible");
        assert!(a.latency_ms <= 25.0 + 1e-9);
        assert!(a.throughput_rps >= 200.0 - 1e-9);
        assert!(a.total_share() > 0);
    }

    #[test]
    fn min_alloc_batching_pays_off() {
        // Serving 200 RPS with a relaxed budget should use batch > 1 and
        // consume (weakly) less than forcing batch = 1.
        let cm = cm();
        let f = frag(&cm, "inc");
        let free = cm
            .min_alloc(f, 60.0, 200.0, AllocConstraints::default())
            .unwrap();
        let b1 = cm
            .min_alloc(
                f,
                60.0,
                200.0,
                AllocConstraints { max_batch: 1, ..Default::default() },
            )
            .unwrap();
        assert!(free.batch > 1, "expected batching, got {free:?}");
        assert!(free.total_share() <= b1.total_share());
    }

    #[test]
    fn min_alloc_discreteness_fig4() {
        // Fig 4: higher demanded throughput does NOT always cost more —
        // the discrete (batch, share, instance) lattice yields flat
        // regions (free extra throughput) separated by jumps.
        let cm = cm();
        let f = frag(&cm, "inc");
        let shares: Vec<u32> = (1..=40)
            .map(|k| {
                cm.min_alloc(
                    f,
                    25.0,
                    10.0 * k as f64,
                    AllocConstraints::default(),
                )
                .map(|a| a.total_share())
                .unwrap()
            })
            .collect();
        // non-decreasing overall ...
        assert!(shares.windows(2).all(|w| w[1] >= w[0]), "{shares:?}");
        // ... with at least one flat step (the Fig-4 discreteness)
        assert!(
            shares.windows(2).any(|w| w[1] == w[0]),
            "no flat step in {shares:?}"
        );
        // ... and at least one jump of several share units
        assert!(
            shares.windows(2).any(|w| w[1] >= w[0] + 2),
            "no jump in {shares:?}"
        );
    }

    #[test]
    fn min_alloc_respects_instance_cap() {
        let cm = cm();
        let f = frag(&cm, "inc");
        let capped = cm
            .min_alloc(
                f,
                40.0,
                300.0,
                AllocConstraints { max_instances: 5, ..Default::default() },
            )
            .unwrap();
        assert!(capped.instances <= 5);
        // an infeasible cap yields None rather than a violating alloc
        let impossible = cm.min_alloc(
            f,
            8.0,
            5000.0,
            AllocConstraints { max_instances: 1, ..Default::default() },
        );
        assert!(impossible.is_none());
    }

    #[test]
    fn instance_mem_grows_with_batch_and_span() {
        let cm = cm();
        let i = cm.model_index("res").unwrap();
        let whole = FragmentId::new(i, 0, 16);
        let tail = FragmentId::new(i, 8, 16);
        assert!(cm.instance_mem_mb(whole, 1) > cm.instance_mem_mb(tail, 1));
        assert!(cm.instance_mem_mb(whole, 8) > cm.instance_mem_mb(whole, 1));
    }

    #[test]
    fn energy_scales_with_share_and_time() {
        let cm = cm();
        let a = Alloc { batch: 1, share: 30, instances: 2, latency_ms: 10.0, throughput_rps: 100.0 };
        let e1 = cm.energy_j(&a, 1.0, 1.0);
        let e2 = cm.energy_j(&a, 2.0, 1.0);
        assert!((e2 - 2.0 * e1).abs() < 1e-9);
        let half = cm.energy_j(&a, 1.0, 0.5);
        assert!(half < e1);
    }
}
