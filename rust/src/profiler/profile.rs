//! Performance-profile curves + efficient-point pruning (Fig 4).
//!
//! The profiler exposes the two curves of Fig 4 for any fragment:
//!
//! * [`Profile::share_vs_budget`] — required total GPU share to meet a
//!   range of time budgets at a fixed demanded throughput (Fig 4a);
//! * [`Profile::share_vs_throughput`] — required total GPU share to meet
//!   a range of demanded throughputs at a fixed latency budget (Fig 4b).
//!
//! Both are *step* functions because batch, share unit and instance
//! count are discrete.  The step knees — the paper's "blue dots", i.e.
//! the only points where relaxing the requirement actually saves
//! resources — are extracted by [`knees`] and used by the scheduler's
//! search-space pruning (§4.3 optimisation 3).

use super::gpu_model::{Alloc, AllocConstraints, CostModel, FragmentId};

/// One point of a share-requirement curve.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CurvePoint {
    /// The swept requirement (budget in ms, or demanded RPS).
    pub x: f64,
    /// Minimal total share meeting it (None = infeasible).
    pub total_share: Option<u32>,
    pub alloc: Option<Alloc>,
}

/// Profile curves of one fragment.
#[derive(Debug, Clone)]
pub struct Profile {
    pub frag: FragmentId,
}

impl Profile {
    pub fn new(frag: FragmentId) -> Self {
        Self { frag }
    }

    /// Fig 4a: required share vs time budget at fixed throughput.
    pub fn share_vs_budget(
        &self,
        cm: &CostModel,
        demand_rps: f64,
        budgets_ms: impl IntoIterator<Item = f64>,
        cons: AllocConstraints,
    ) -> Vec<CurvePoint> {
        budgets_ms
            .into_iter()
            .map(|b| {
                let alloc = cm.min_alloc(self.frag, b, demand_rps, cons);
                CurvePoint {
                    x: b,
                    total_share: alloc.map(|a| a.total_share()),
                    alloc,
                }
            })
            .collect()
    }

    /// Fig 4b: required share vs demanded throughput at fixed budget.
    pub fn share_vs_throughput(
        &self,
        cm: &CostModel,
        budget_ms: f64,
        demands_rps: impl IntoIterator<Item = f64>,
        cons: AllocConstraints,
    ) -> Vec<CurvePoint> {
        demands_rps
            .into_iter()
            .map(|q| {
                let alloc = cm.min_alloc(self.frag, budget_ms, q, cons);
                CurvePoint {
                    x: q,
                    total_share: alloc.map(|a| a.total_share()),
                    alloc,
                }
            })
            .collect()
    }
}

/// Extract the efficient points (the "blue dots" of Fig 4a): the last
/// point of each flat step of a non-increasing or non-decreasing step
/// curve — relaxing/tightening beyond them is what changes cost.
pub fn knees(curve: &[CurvePoint]) -> Vec<CurvePoint> {
    let mut out = Vec::new();
    for (i, p) in curve.iter().enumerate() {
        let next_differs = curve
            .get(i + 1)
            .map_or(true, |n| n.total_share != p.total_share);
        if p.total_share.is_some() && next_differs {
            out.push(*p);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    fn setup() -> (CostModel, Profile) {
        let cm = CostModel::new(Config::embedded());
        let i = cm.model_index("inc").unwrap();
        (cm, Profile::new(FragmentId::new(i, 0, 17)))
    }

    #[test]
    fn fig4a_share_decreases_with_budget() {
        let (cm, p) = setup();
        let curve = p.share_vs_budget(
            &cm,
            200.0,
            (10..=60).map(|b| b as f64),
            AllocConstraints::default(),
        );
        let shares: Vec<u32> =
            curve.iter().filter_map(|c| c.total_share).collect();
        assert!(!shares.is_empty());
        assert!(
            shares.windows(2).all(|w| w[1] <= w[0]),
            "not non-increasing: {shares:?}"
        );
        // step structure: some flat segments
        assert!(shares.windows(2).any(|w| w[1] == w[0]));
    }

    #[test]
    fn fig4b_share_increases_with_throughput() {
        let (cm, p) = setup();
        let curve = p.share_vs_throughput(
            &cm,
            25.0,
            (1..=30).map(|k| 10.0 * k as f64),
            AllocConstraints::default(),
        );
        let shares: Vec<u32> =
            curve.iter().filter_map(|c| c.total_share).collect();
        assert!(shares.windows(2).all(|w| w[1] >= w[0]));
    }

    #[test]
    fn infeasible_budgets_are_none() {
        let (cm, p) = setup();
        let curve = p.share_vs_budget(
            &cm,
            200.0,
            [0.01, 50.0],
            AllocConstraints::default(),
        );
        assert!(curve[0].total_share.is_none());
        assert!(curve[1].total_share.is_some());
    }

    #[test]
    fn knees_are_sparse_and_cover_all_levels() {
        let (cm, p) = setup();
        let curve = p.share_vs_budget(
            &cm,
            200.0,
            (10..=80).map(|b| b as f64),
            AllocConstraints::default(),
        );
        let k = knees(&curve);
        assert!(!k.is_empty());
        assert!(k.len() < curve.len() / 2, "{} of {}", k.len(), curve.len());
        // every distinct share level appears exactly once among knees
        let mut levels: Vec<u32> =
            curve.iter().filter_map(|c| c.total_share).collect();
        levels.dedup();
        let knee_levels: Vec<u32> =
            k.iter().filter_map(|c| c.total_share).collect();
        assert_eq!(levels, knee_levels);
    }
}
