//! Offline profiler (paper §3): performance profiles of DNN fragments in
//! batch size and GPU share, plus the min-resource allocation search that
//! the scheduler (§4) consumes.
//!
//! The paper profiles PyTorch models under CUDA MPS; we substitute an
//! analytical MPS GPU model calibrated to Table 2 (see DESIGN.md §2) —
//! Graft's algorithms only ever see the profile surface
//! `latency(fragment, batch, share)`, so the substitution preserves the
//! decision problem (discreteness of batch/share/instances, sub-linear
//! share scaling, batch amortisation — the phenomena behind Fig 4).

mod gpu_model;
mod profile;

pub use gpu_model::{Alloc, AllocConstraints, CostModel, FragmentId};
pub use profile::{knees, CurvePoint, Profile};
