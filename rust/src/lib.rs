//! # Graft — inference serving for hybrid deep learning via DNN re-alignment
//!
//! A reproduction of *"Graft: Efficient Inference Serving for Hybrid Deep
//! Learning with SLO Guarantees via DNN Re-alignment"* (Wu et al., 2023)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the Graft coordinator: profiler, scheduler
//!   (merge → group → re-partition), executor/serving data path, the
//!   baselines (GSLICE/GSLICE⁺/Static/Static⁺/Optimal), the hybrid-DL
//!   substrate (Neurosurgeon, mobile devices, 5G traces), simulators and
//!   the experiment harness regenerating every paper table and figure.
//! * **L2/L1 (build-time Python)** — stand-in DNNs in JAX whose per-layer
//!   hot-spot is a tiled Pallas `linear_block` kernel, AOT-lowered to HLO
//!   text (`make artifacts`).
//! * **Runtime** — [`runtime`] loads the HLO artifacts through the PJRT C
//!   API (`xla` crate) and executes them on the request path; Python is
//!   never on the request path.
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for the
//! paper-vs-measured record.

pub mod config;
pub mod util;
pub mod coordinator;
pub mod experiments;
pub mod hybrid;
pub mod metrics;
pub mod obs;
pub mod profiler;
pub mod runtime;
pub mod serving;
pub mod sim;
pub mod workload;

pub use config::Config;
pub use coordinator::fragment::{ClientId, FragmentSpec};
pub use profiler::{Alloc, AllocConstraints, CostModel, FragmentId};
