//! Latency metrics: streaming histograms, percentiles, SLO accounting.

/// A simple exact-sample latency recorder (serving runs are small enough
/// to keep every sample; the DES uses it too).
#[derive(Debug, Clone, Default)]
pub struct LatencyStats {
    samples_ms: Vec<f64>,
}

impl LatencyStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, ms: f64) {
        self.samples_ms.push(ms);
    }

    pub fn len(&self) -> usize {
        self.samples_ms.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples_ms.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples_ms.is_empty() {
            return f64::NAN;
        }
        self.samples_ms.iter().sum::<f64>() / self.samples_ms.len() as f64
    }

    /// Percentile in [0, 100] by the nearest-rank definition: the
    /// smallest sample such that at least `p`% of the samples are ≤ it
    /// — `rank = ⌈p/100 · n⌉` (1-indexed, clamped to [1, n]).  Exact
    /// midpoints take the *lower* of the two middle samples (p50 of
    /// 200 samples is the 100th, not the 101st); p = 0 returns the
    /// minimum.  Always an actual sample, never an interpolation.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples_ms.is_empty() {
            return f64::NAN;
        }
        let mut v = self.samples_ms.clone();
        v.sort_by(f64::total_cmp);
        let n = v.len();
        let rank = ((p / 100.0) * n as f64).ceil() as usize;
        v[rank.clamp(1, n) - 1]
    }

    /// Fraction of samples ≤ `slo_ms`.
    pub fn slo_attainment(&self, slo_ms: f64) -> f64 {
        if self.samples_ms.is_empty() {
            return f64::NAN;
        }
        self.samples_ms.iter().filter(|&&s| s <= slo_ms).count() as f64
            / self.samples_ms.len() as f64
    }

    /// CDF points (x sorted latency, y cumulative fraction) for
    /// figures.  The last point is always `(max, 1.0)` — in particular
    /// `cdf(1)` summarizes the whole distribution as its maximum, not
    /// (as it used to) the minimum with cumulative fraction 1/n.
    pub fn cdf(&self, points: usize) -> Vec<(f64, f64)> {
        if self.samples_ms.is_empty() || points == 0 {
            return Vec::new();
        }
        let mut v = self.samples_ms.clone();
        v.sort_by(f64::total_cmp);
        let n = v.len();
        if points == 1 {
            return vec![(v[n - 1], 1.0)];
        }
        (0..points)
            .map(|i| {
                let f = i as f64 / (points - 1) as f64;
                let idx = ((n - 1) as f64 * f).round() as usize;
                (v[idx], (idx + 1) as f64 / n as f64)
            })
            .collect()
    }

    pub fn samples(&self) -> &[f64] {
        &self.samples_ms
    }

    pub fn merge(&mut self, other: &LatencyStats) {
        self.samples_ms.extend_from_slice(&other.samples_ms);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(v: &[f64]) -> LatencyStats {
        let mut s = LatencyStats::new();
        for &x in v {
            s.record(x);
        }
        s
    }

    #[test]
    fn mean_and_percentiles() {
        let s = stats(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(50.0), 3.0);
        assert_eq!(s.percentile(100.0), 5.0);
    }

    #[test]
    fn slo_attainment_counts_fraction() {
        let s = stats(&[10.0, 20.0, 30.0, 40.0]);
        assert_eq!(s.slo_attainment(25.0), 0.5);
        assert_eq!(s.slo_attainment(5.0), 0.0);
        assert_eq!(s.slo_attainment(100.0), 1.0);
    }

    #[test]
    fn empty_stats_are_nan() {
        let s = LatencyStats::new();
        assert!(s.mean().is_nan());
        assert!(s.percentile(50.0).is_nan());
        assert!(s.cdf(5).is_empty());
    }

    #[test]
    fn percentile_is_nearest_rank_at_exact_midpoints() {
        // p50 of an even count: nearest-rank takes the lower middle
        // sample (the round-half-away indexing it replaced took the
        // upper one)
        let s = stats(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.percentile(50.0), 2.0);
        assert_eq!(s.percentile(25.0), 1.0);
        assert_eq!(s.percentile(75.0), 3.0);
        // 200 samples 1..=200: p50 is the 100th sample, p99 the 198th
        let v: Vec<f64> = (1..=200).map(|i| i as f64).collect();
        let s = stats(&v);
        assert_eq!(s.percentile(50.0), 100.0);
        assert_eq!(s.percentile(99.0), 198.0);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 200.0);
    }

    #[test]
    fn single_point_cdf_covers_the_distribution() {
        let s = stats(&[5.0, 1.0, 3.0]);
        assert_eq!(s.cdf(1), vec![(5.0, 1.0)]);
        assert!(s.cdf(0).is_empty());
    }

    #[test]
    fn cdf_is_monotone() {
        let s = stats(&[5.0, 1.0, 3.0, 2.0, 4.0, 9.0]);
        let cdf = s.cdf(10);
        assert!(cdf.windows(2).all(|w| w[0].0 <= w[1].0 && w[0].1 <= w[1].1));
        assert_eq!(cdf.last().unwrap().1, 1.0);
    }

    #[test]
    fn merge_combines_samples() {
        let mut a = stats(&[1.0, 2.0]);
        a.merge(&stats(&[3.0]));
        assert_eq!(a.len(), 3);
    }
}
