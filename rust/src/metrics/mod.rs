//! Latency metrics: streaming histograms, percentiles, SLO accounting.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// A simple exact-sample latency recorder (serving runs are small enough
/// to keep every sample; the DES uses it too).
///
/// Quantile queries sort lazily: the first `percentile`/`cdf` call
/// after a `record`/`merge` builds a sorted copy, subsequent calls
/// reuse it.  The old behavior — clone + sort on *every* call — made a
/// percentile sweep over an n-sample run O(k·n log n).
#[derive(Debug, Default)]
pub struct LatencyStats {
    samples_ms: Vec<f64>,
    /// Sorted view, built on the first quantile query and invalidated
    /// by the next mutation.
    sorted: Mutex<Option<Vec<f64>>>,
    /// Times the sorted view was (re)built — the regression guard.
    sorts: AtomicU64,
}

impl Clone for LatencyStats {
    fn clone(&self) -> Self {
        LatencyStats {
            samples_ms: self.samples_ms.clone(),
            sorted: Mutex::new(None),
            sorts: AtomicU64::new(0),
        }
    }
}

impl LatencyStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, ms: f64) {
        self.samples_ms.push(ms);
        *self.sorted.get_mut().unwrap_or_else(|e| e.into_inner()) = None;
    }

    /// Run `f` on the lazily-sorted samples (empty case handled by the
    /// callers, which all return early on no samples).
    fn with_sorted<R>(&self, f: impl FnOnce(&[f64]) -> R) -> R {
        let mut guard = self.sorted.lock().unwrap_or_else(|e| e.into_inner());
        let v = guard.get_or_insert_with(|| {
            self.sorts.fetch_add(1, Ordering::Relaxed);
            let mut v = self.samples_ms.clone();
            v.sort_by(f64::total_cmp);
            v
        });
        f(v)
    }

    /// How many times the sorted view has been rebuilt (test hook for
    /// the caching contract).
    pub fn sort_count(&self) -> u64 {
        self.sorts.load(Ordering::Relaxed)
    }

    pub fn len(&self) -> usize {
        self.samples_ms.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples_ms.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples_ms.is_empty() {
            return f64::NAN;
        }
        self.samples_ms.iter().sum::<f64>() / self.samples_ms.len() as f64
    }

    /// Percentile in [0, 100] by the nearest-rank definition: the
    /// smallest sample such that at least `p`% of the samples are ≤ it
    /// — `rank = ⌈p/100 · n⌉` (1-indexed, clamped to [1, n]).  Exact
    /// midpoints take the *lower* of the two middle samples (p50 of
    /// 200 samples is the 100th, not the 101st); p = 0 returns the
    /// minimum.  Always an actual sample, never an interpolation.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples_ms.is_empty() {
            return f64::NAN;
        }
        self.with_sorted(|v| {
            let n = v.len();
            let rank = ((p / 100.0) * n as f64).ceil() as usize;
            v[rank.clamp(1, n) - 1]
        })
    }

    /// Fraction of samples ≤ `slo_ms`.
    pub fn slo_attainment(&self, slo_ms: f64) -> f64 {
        if self.samples_ms.is_empty() {
            return f64::NAN;
        }
        self.samples_ms.iter().filter(|&&s| s <= slo_ms).count() as f64
            / self.samples_ms.len() as f64
    }

    /// CDF points (x sorted latency, y cumulative fraction) for
    /// figures.  The last point is always `(max, 1.0)` — in particular
    /// `cdf(1)` summarizes the whole distribution as its maximum, not
    /// (as it used to) the minimum with cumulative fraction 1/n.
    pub fn cdf(&self, points: usize) -> Vec<(f64, f64)> {
        if self.samples_ms.is_empty() || points == 0 {
            return Vec::new();
        }
        self.with_sorted(|v| {
            let n = v.len();
            if points == 1 {
                return vec![(v[n - 1], 1.0)];
            }
            (0..points)
                .map(|i| {
                    let f = i as f64 / (points - 1) as f64;
                    let idx = ((n - 1) as f64 * f).round() as usize;
                    (v[idx], (idx + 1) as f64 / n as f64)
                })
                .collect()
        })
    }

    pub fn samples(&self) -> &[f64] {
        &self.samples_ms
    }

    pub fn merge(&mut self, other: &LatencyStats) {
        self.samples_ms.extend_from_slice(&other.samples_ms);
        *self.sorted.get_mut().unwrap_or_else(|e| e.into_inner()) = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(v: &[f64]) -> LatencyStats {
        let mut s = LatencyStats::new();
        for &x in v {
            s.record(x);
        }
        s
    }

    #[test]
    fn mean_and_percentiles() {
        let s = stats(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(50.0), 3.0);
        assert_eq!(s.percentile(100.0), 5.0);
    }

    #[test]
    fn slo_attainment_counts_fraction() {
        let s = stats(&[10.0, 20.0, 30.0, 40.0]);
        assert_eq!(s.slo_attainment(25.0), 0.5);
        assert_eq!(s.slo_attainment(5.0), 0.0);
        assert_eq!(s.slo_attainment(100.0), 1.0);
    }

    #[test]
    fn empty_stats_are_nan() {
        let s = LatencyStats::new();
        assert!(s.mean().is_nan());
        assert!(s.percentile(50.0).is_nan());
        assert!(s.cdf(5).is_empty());
    }

    #[test]
    fn percentile_is_nearest_rank_at_exact_midpoints() {
        // p50 of an even count: nearest-rank takes the lower middle
        // sample (the round-half-away indexing it replaced took the
        // upper one)
        let s = stats(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.percentile(50.0), 2.0);
        assert_eq!(s.percentile(25.0), 1.0);
        assert_eq!(s.percentile(75.0), 3.0);
        // 200 samples 1..=200: p50 is the 100th sample, p99 the 198th
        let v: Vec<f64> = (1..=200).map(|i| i as f64).collect();
        let s = stats(&v);
        assert_eq!(s.percentile(50.0), 100.0);
        assert_eq!(s.percentile(99.0), 198.0);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 200.0);
    }

    #[test]
    fn single_point_cdf_covers_the_distribution() {
        let s = stats(&[5.0, 1.0, 3.0]);
        assert_eq!(s.cdf(1), vec![(5.0, 1.0)]);
        assert!(s.cdf(0).is_empty());
    }

    #[test]
    fn cdf_is_monotone() {
        let s = stats(&[5.0, 1.0, 3.0, 2.0, 4.0, 9.0]);
        let cdf = s.cdf(10);
        assert!(cdf.windows(2).all(|w| w[0].0 <= w[1].0 && w[0].1 <= w[1].1));
        assert_eq!(cdf.last().unwrap().1, 1.0);
    }

    #[test]
    fn merge_combines_samples() {
        let mut a = stats(&[1.0, 2.0]);
        a.merge(&stats(&[3.0]));
        assert_eq!(a.len(), 3);
    }

    #[test]
    fn quantile_queries_sort_once_until_mutated() {
        let v: Vec<f64> = (0..500).map(|i| ((i * 7919) % 500) as f64).collect();
        let mut s = stats(&v);
        assert_eq!(s.sort_count(), 0);
        // A sweep of quantile queries shares one sorted build.
        for p in [50.0, 90.0, 95.0, 99.0, 99.9] {
            s.percentile(p);
        }
        s.cdf(32);
        assert_eq!(s.sort_count(), 1);
        // A new sample invalidates the cache and is visible.
        s.record(1e9);
        assert_eq!(s.percentile(100.0), 1e9);
        assert_eq!(s.sort_count(), 2);
        // So does a merge.
        s.merge(&stats(&[-1.0]));
        assert_eq!(s.percentile(0.0), -1.0);
        assert_eq!(s.sort_count(), 3);
        // Queries after that still reuse the rebuilt view.
        s.cdf(8);
        assert_eq!(s.sort_count(), 3);
    }
}
