//! Component ablations: Fig 11 (re-partitioning on/off), Fig 12
//! (re-partition point vs bandwidth / rate), Figs 13–15 (merging
//! strategies & thresholds), Fig 16 (group size & factor weights).

use std::time::Instant;

use crate::coordinator::grouping::{FactorWeights, GroupOptions};
use crate::coordinator::merging::{merge_fragments, MergeOptions};
use crate::coordinator::repartition::{
    no_realign_plan, realign_group, RepartitionOptions,
};
use crate::coordinator::scheduler::{Scheduler, SchedulerOptions};
use crate::coordinator::{ClientId, FragmentSpec};
use crate::hybrid::{choose_partition, DeviceKind};
use crate::profiler::{AllocConstraints, CostModel};
use crate::util::csv::{f, Table};

use super::common::{mean_over_reps, model_idx, random_fragments, MODELS};

/// Fig 11: resource consumption with re-partitioning normalised by the
/// no-re-partitioning provisioning, 5 random fragments per model.
pub fn fig11(cm: &CostModel) -> Table {
    let cons = AllocConstraints::default();
    let mut t = Table::new(vec!["model", "normalized_share", "reduction_pct"]);
    for name in MODELS {
        let mi = model_idx(cm, name);
        let ratio = mean_over_reps(10, |rep| {
            let frags = random_fragments(cm, mi, 5, 1000 + rep as u64);
            let with = realign_group(
                cm,
                &frags,
                &RepartitionOptions { constraints: cons, ..Default::default() },
            );
            let without = no_realign_plan(cm, &frags, &cons);
            with.total_share() as f64 / without.total_share().max(1) as f64
        });
        t.row(vec![
            name.to_string(),
            f(ratio, 3),
            f((1.0 - ratio) * 100.0, 1),
        ]);
    }
    t
}

/// Fig 12: re-partition point and share of Inc with four fixed fragments
/// while the fifth sweeps (a) bandwidth and (b) request rate.
pub fn fig12(cm: &CostModel) -> Table {
    let mi = model_idx(cm, "inc");
    let m = &cm.config().models[mi];
    let fixed = random_fragments(cm, mi, 4, 99);
    let slo = DeviceKind::Nano.slo_ms(m, cm.config().slo_ratio_default);
    let opts = RepartitionOptions::default();

    let mut t = Table::new(vec![
        "panel",
        "x",
        "fifth_p",
        "repartition_points",
        "total_share",
    ]);
    // (a) bandwidth sweep at the default rate
    for bw in [30.0, 50.0, 70.0, 100.0, 130.0, 160.0, 200.0] {
        if let Some(part) =
            choose_partition(cm, mi, DeviceKind::Nano, bw, slo, None)
                .partition()
        {
            let mut frags = fixed.clone();
            frags.push(FragmentSpec::single(
                ClientId(4),
                mi,
                part.p,
                part.server_budget_ms,
                m.rate_rps,
            ));
            let plan = realign_group(cm, &frags, &opts);
            let pts: Vec<String> =
                plan.sets.iter().map(|s| s.point.to_string()).collect();
            t.row(vec![
                "a:bandwidth".to_string(),
                f(bw, 0),
                part.p.to_string(),
                pts.join("|"),
                plan.total_share().to_string(),
            ]);
        }
    }
    // (b) rate sweep at 100 Mbps
    if let Some(part) =
        choose_partition(cm, mi, DeviceKind::Nano, 100.0, slo, None)
            .partition()
    {
        for rate in [10.0, 20.0, 30.0, 45.0, 60.0, 90.0, 120.0] {
            let mut frags = fixed.clone();
            frags.push(FragmentSpec::single(
                ClientId(4),
                mi,
                part.p,
                part.server_budget_ms,
                rate,
            ));
            let plan = realign_group(cm, &frags, &opts);
            let pts: Vec<String> =
                plan.sets.iter().map(|s| s.point.to_string()).collect();
            t.row(vec![
                "b:rate".to_string(),
                f(rate, 0),
                part.p.to_string(),
                pts.join("|"),
                plan.total_share().to_string(),
            ]);
        }
    }
    t
}

fn plan_with_merge(
    cm: &CostModel,
    frags: &[FragmentSpec],
    merge: MergeOptions,
) -> (u32, usize, f64) {
    let sched = Scheduler::new(
        cm.clone(),
        SchedulerOptions { merge, ..Default::default() },
    );
    let t0 = Instant::now();
    let (plan, stats) = sched.plan(frags);
    (
        plan.total_share(),
        stats.n_after_merge,
        t0.elapsed().as_secs_f64() * 1e3,
    )
}

/// Fig 13: resource consumption under No / Uniform / Uniform⁺ merging
/// (50 fragments, threshold 0.2).
pub fn fig13(cm: &CostModel) -> Table {
    let mut t =
        Table::new(vec!["model", "strategy", "total_share", "n_after_merge"]);
    for name in MODELS {
        let mi = model_idx(cm, name);
        let frags = random_fragments(cm, mi, 50, 555);
        for (label, merge) in [
            ("no-merging", MergeOptions::none()),
            ("uniform", MergeOptions::merge_all()),
            (
                "uniform+",
                MergeOptions { threshold: 0.2, ..Default::default() },
            ),
        ] {
            let (share, n, _) = plan_with_merge(cm, &frags, merge);
            t.row(vec![
                name.to_string(),
                label.to_string(),
                share.to_string(),
                n.to_string(),
            ]);
        }
    }
    t
}

/// Fig 14: Res resource consumption (top) and scheduler time (bottom)
/// normalised by no-merging, under growing fragment counts; plus the
/// fragment-count reduction of Uniform⁺ for all models.
pub fn fig14(cm: &CostModel) -> Table {
    let mut t = Table::new(vec![
        "model",
        "n_fragments",
        "share_ratio_vs_nomerge",
        "time_ratio_vs_nomerge",
        "fragments_reduction_pct",
    ]);
    for name in MODELS {
        let mi = model_idx(cm, name);
        for n in [10usize, 20, 30, 40, 50] {
            let frags = random_fragments(cm, mi, n, 777 + n as u64);
            let (s_no, _, t_no) =
                plan_with_merge(cm, &frags, MergeOptions::none());
            let (s_up, n_up, t_up) = plan_with_merge(
                cm,
                &frags,
                MergeOptions { threshold: 0.2, ..Default::default() },
            );
            t.row(vec![
                name.to_string(),
                n.to_string(),
                f(s_up as f64 / s_no.max(1) as f64, 3),
                f(t_up / t_no.max(1e-9), 3),
                f((1.0 - n_up as f64 / n as f64) * 100.0, 1),
            ]);
        }
    }
    t
}

/// Fig 15: (a) resource consumption under varying merging thresholds,
/// normalised by threshold 0.1; (b) merging time cost for 25 Res
/// fragments vs threshold.
pub fn fig15(cm: &CostModel) -> Table {
    let thresholds = [0.05, 0.1, 0.2, 0.3, 0.4];
    let mut t = Table::new(vec![
        "panel",
        "model",
        "n_fragments",
        "threshold",
        "value",
    ]);
    for name in MODELS {
        let mi = model_idx(cm, name);
        for n in [25usize, 50] {
            let frags = random_fragments(cm, mi, n, 888 + n as u64);
            let (base, _, _) = plan_with_merge(
                cm,
                &frags,
                MergeOptions { threshold: 0.1, ..Default::default() },
            );
            for thr in thresholds {
                let (share, _, _) = plan_with_merge(
                    cm,
                    &frags,
                    MergeOptions { threshold: thr, ..Default::default() },
                );
                t.row(vec![
                    "a:share_norm".to_string(),
                    name.to_string(),
                    n.to_string(),
                    f(thr, 2),
                    f(share as f64 / base.max(1) as f64, 3),
                ]);
            }
        }
    }
    // (b) merging-only time cost, Res, 25 fragments
    let mi = model_idx(cm, "res");
    let frags = random_fragments(cm, mi, 25, 999);
    for thr in thresholds {
        let t0 = Instant::now();
        let merged = merge_fragments(
            cm,
            &frags,
            &MergeOptions { threshold: thr, ..Default::default() },
        );
        t.row(vec![
            "b:merge_time_ms".to_string(),
            "res".to_string(),
            merged.len().to_string(),
            f(thr, 2),
            f(t0.elapsed().as_secs_f64() * 1e3, 3),
        ]);
    }
    t
}

/// Fig 16: (a) resource + time vs group size (Inc, 25 fragments);
/// (b) equal vs tuned factor weights.
pub fn fig16(cm: &CostModel) -> Table {
    let mi = model_idx(cm, "inc");
    let frags = random_fragments(cm, mi, 25, 1234);
    let mut t = Table::new(vec!["panel", "x", "total_share", "time_ms"]);
    for gs in [2usize, 3, 5, 8, 12] {
        let sched = Scheduler::new(
            cm.clone(),
            SchedulerOptions {
                group: GroupOptions { group_size: gs, ..Default::default() },
                ..Default::default()
            },
        );
        let t0 = Instant::now();
        let (plan, _) = sched.plan(&frags);
        t.row(vec![
            "a:group_size".to_string(),
            gs.to_string(),
            plan.total_share().to_string(),
            f(t0.elapsed().as_secs_f64() * 1e3, 2),
        ]);
    }
    // (b): equal weights vs a small weight sweep (best-of)
    let weight_sets = [
        ("equal", FactorWeights { p: 1.0, t: 1.0, q: 1.0 }),
        ("t-heavy", FactorWeights { p: 1.0, t: 2.0, q: 1.0 }),
        ("p-heavy", FactorWeights { p: 2.0, t: 1.0, q: 1.0 }),
        ("q-heavy", FactorWeights { p: 1.0, t: 1.0, q: 2.0 }),
    ];
    for (label, w) in weight_sets {
        let sched = Scheduler::new(
            cm.clone(),
            SchedulerOptions {
                group: GroupOptions { weights: w, ..Default::default() },
                ..Default::default()
            },
        );
        let (plan, _) = sched.plan(&frags);
        t.row(vec![
            format!("b:weights:{label}"),
            "25".to_string(),
            plan.total_share().to_string(),
            "".to_string(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    fn cm() -> CostModel {
        CostModel::new(Config::embedded())
    }

    #[test]
    fn fig11_realign_never_hurts() {
        let cm = cm();
        let t = fig11(&cm);
        assert_eq!(t.rows.len(), 5);
        for r in &t.rows {
            let ratio: f64 = r[1].parse().unwrap();
            assert!(ratio <= 1.0 + 1e-9, "{}: {ratio}", r[0]);
        }
        // at least one model gains substantially (paper: up to 60% ViT)
        assert!(t.rows.iter().any(|r| {
            r[2].parse::<f64>().unwrap() > 5.0
        }));
    }

    #[test]
    fn fig13_uniform_plus_never_worst() {
        let cm = cm();
        let t = fig13(&cm);
        for name in MODELS {
            let get = |strategy: &str| -> u32 {
                t.rows
                    .iter()
                    .find(|r| r[0] == name && r[1] == strategy)
                    .unwrap()[2]
                    .parse()
                    .unwrap()
            };
            let up = get("uniform+");
            let no = get("no-merging");
            assert!(up <= no, "{name}: uniform+ {up} > no-merge {no}");
        }
    }

    #[test]
    fn fig16_group_size_grows_time() {
        let cm = cm();
        let t = fig16(&cm);
        let a: Vec<&Vec<String>> =
            t.rows.iter().filter(|r| r[0] == "a:group_size").collect();
        assert_eq!(a.len(), 5);
    }
}
