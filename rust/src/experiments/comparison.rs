//! Headline comparisons: Fig 7 / Table 3 (resource consumption of Graft
//! vs GSLICE(+)/Static(+)/Optimal across scales) and Figs 8–10
//! (end-to-end latency distributions via the DES).

use crate::hybrid::DeviceKind;
use crate::profiler::{AllocConstraints, CostModel};
use crate::sim::{simulate, SimClient, SimOptions};
use crate::util::csv::{f, Table};

use super::common::{
    compare_systems, fleet, graft_plan, model_idx, snapshot,
    static_clients, Scale, SystemSet, MODELS,
};

fn scale_constraints(scale: Scale) -> AllocConstraints {
    match scale {
        // §5.3: instances per fragment capped at 5 at large scale
        Scale::LargeHomo | Scale::LargeHeter => AllocConstraints {
            max_instances: 5,
            ..Default::default()
        },
        _ => AllocConstraints::default(),
    }
}

/// Fig 7 (a–c) + Table 3: mean total GPU share per system, model, scale.
pub fn fig7(cm: &CostModel) -> Table {
    let mut t = Table::new(vec!["scale", "model", "system", "total_share"]);
    for scale in [
        Scale::SmallHomo,
        Scale::SmallHeter,
        Scale::LargeHomo,
        Scale::LargeHeter,
    ] {
        let reps = 10;
        // Optimal is exponential: only feasible at small scale
        let systems = SystemSet {
            optimal: matches!(scale, Scale::SmallHomo | Scale::SmallHeter),
        };
        for name in MODELS {
            let mi = model_idx(cm, name);
            let mut sums: std::collections::HashMap<&'static str, (f64, u32)> =
                std::collections::HashMap::new();
            for rep in 0..reps {
                let clients = fleet(cm, mi, scale, 0.95, 42 + rep as u64);
                let specs = snapshot(cm, &clients, 3.0 + rep as f64 * 5.0);
                if specs.is_empty() {
                    continue;
                }
                let st = static_clients(cm, &clients);
                for (sys, share) in compare_systems(
                    cm,
                    &specs,
                    &st,
                    scale_constraints(scale),
                    systems,
                ) {
                    let e = sums.entry(sys).or_insert((0.0, 0));
                    e.0 += share as f64;
                    e.1 += 1;
                }
            }
            for (sys, (total, n)) in sums {
                t.row(vec![
                    scale.id().to_string(),
                    name.to_string(),
                    sys.to_string(),
                    f(total / n.max(1) as f64, 1),
                ]);
            }
        }
    }
    t
}

/// Table 3: Graft's resource reduction (%) vs GSLICE (small) / GSLICE⁺
/// (large), derived from the Fig 7 data.
pub fn tab3(cm: &CostModel) -> Table {
    let fig7 = fig7(cm);
    let lookup = |scale: &str, model: &str, sys: &str| -> f64 {
        fig7.rows
            .iter()
            .find(|r| r[0] == scale && r[1] == model && r[2] == sys)
            .map(|r| r[3].parse().unwrap())
            .unwrap_or(f64::NAN)
    };
    let mut t = Table::new(vec!["scale", "model", "baseline", "reduction_pct"]);
    for (scale, base) in [
        ("small-homo", "gslice"),
        ("small-heter", "gslice"),
        ("large-homo", "gslice+"),
        ("large-heter", "gslice+"),
    ] {
        for model in MODELS {
            let g = lookup(scale, model, "graft");
            let b = lookup(scale, model, base);
            t.row(vec![
                scale.to_string(),
                model.to_string(),
                base.to_string(),
                f((1.0 - g / b) * 100.0, 1),
            ]);
        }
    }
    t
}

/// Latency-distribution experiment shared by Figs 8–10.
fn latency_dist(cm: &CostModel, scale: Scale, label: &str) -> Table {
    let mut t = Table::new(vec![
        "scenario",
        "model",
        "device",
        "p50_ms",
        "p95_ms",
        "p99_ms",
        "slo_ms",
        "slo_attainment",
        "dropped_frac",
    ]);
    for name in MODELS {
        let mi = model_idx(cm, name);
        let m = &cm.config().models[mi];
        let clients = fleet(cm, mi, scale, 0.95, 77);
        let t_s = 5.0;
        let specs = snapshot(cm, &clients, t_s);
        if specs.is_empty() {
            continue;
        }
        let plan = graft_plan(cm, &specs, scale_constraints(scale));
        let sim_clients: Vec<SimClient> = clients
            .iter()
            .filter_map(|c| {
                let st = c.state_at(cm, t_s);
                st.spec.map(|s| SimClient {
                    client_id: c.id.0,
                    upstream_ms: st.mobile_ms + st.transfer_ms,
                    slo_ms: st.slo_ms,
                    budget_ms: s.budget_ms,
                    rate_rps: m.rate_rps,
                })
            })
            .collect();
        let r = simulate(cm, &plan, &sim_clients, &SimOptions::default());
        // aggregate per device kind
        for dev in [DeviceKind::Nano, DeviceKind::Tx2] {
            let mut stats = crate::metrics::LatencyStats::new();
            let mut slo = f64::NAN;
            for c in clients.iter().filter(|c| c.device == dev) {
                if let Some((_, s)) =
                    r.per_client.iter().find(|(id, _)| *id == c.id.0)
                {
                    stats.merge(s);
                    slo = c.state_at(cm, t_s).slo_ms;
                }
            }
            if stats.is_empty() {
                continue;
            }
            let total = r.served + r.dropped;
            t.row(vec![
                label.to_string(),
                name.to_string(),
                dev.name().to_string(),
                f(stats.percentile(50.0), 1),
                f(stats.percentile(95.0), 1),
                f(stats.percentile(99.0), 1),
                f(slo, 1),
                f(stats.slo_attainment(slo), 3),
                f(r.dropped as f64 / total.max(1) as f64, 3),
            ]);
        }
    }
    t
}

/// Fig 8: latency distribution, small-scale homogeneous (4 Nanos).
pub fn fig8(cm: &CostModel) -> Table {
    latency_dist(cm, Scale::SmallHomo, "small-homo")
}

/// Fig 9: latency distribution, small-scale heterogeneous (per device).
pub fn fig9(cm: &CostModel) -> Table {
    latency_dist(cm, Scale::SmallHeter, "small-heter")
}

/// Fig 10: latency distribution, large-scale (20 emulated clients).
pub fn fig10(cm: &CostModel) -> Table {
    latency_dist(cm, Scale::LargeHomo, "large-homo")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    fn cm() -> CostModel {
        CostModel::new(Config::embedded())
    }

    #[test]
    fn fig8_attains_slos() {
        let cm = cm();
        let t = fig8(&cm);
        assert!(!t.rows.is_empty());
        for r in &t.rows {
            let att: f64 = r[7].parse().unwrap();
            assert!(att > 0.85, "model {} attainment {att}", r[1]);
        }
    }

    #[test]
    fn fig9_has_tx2_rows() {
        let cm = cm();
        let t = fig9(&cm);
        assert!(t.rows.iter().any(|r| r[2] == "tx2"));
    }
}
