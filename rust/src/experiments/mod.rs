//! Experiment harness: every table and figure of the paper's evaluation
//! regenerated as a CSV + pretty table (see DESIGN.md §5 for the index).
//!
//! `graft experiment <id>` runs one (or `all`), printing to stdout and
//! writing `results/<id>.csv`.

pub mod ablations;
pub mod common;
pub mod comparison;
pub mod motivation;
pub mod scale;

use std::path::Path;

use anyhow::{bail, Result};

use crate::profiler::CostModel;
use crate::util::csv::Table;

/// All experiment ids in paper order.
pub const ALL: &[&str] = &[
    "fig2", "fig4", "tab2", "fig6", "fig7", "tab3", "fig8", "fig9",
    "fig10", "fig11", "fig12", "fig13", "fig14", "fig15", "fig16",
    "fig17", "fig18", "fig19", "fig20", "fig21", "serving", "placement",
    "replan", "transition",
];

/// Run one experiment by id.
pub fn run(id: &str, cm: &CostModel) -> Result<Table> {
    Ok(match id {
        "fig2" => motivation::fig2(cm),
        "fig4" => motivation::fig4(cm),
        "tab2" => motivation::tab2(cm),
        "fig6" => motivation::fig6(cm),
        "fig7" => comparison::fig7(cm),
        "tab3" => comparison::tab3(cm),
        "fig8" => comparison::fig8(cm),
        "fig9" => comparison::fig9(cm),
        "fig10" => comparison::fig10(cm),
        "fig11" => ablations::fig11(cm),
        "fig12" => ablations::fig12(cm),
        "fig13" => ablations::fig13(cm),
        "fig14" => ablations::fig14(cm),
        "fig15" => ablations::fig15(cm),
        "fig16" => ablations::fig16(cm),
        "fig17" => scale::fig17(cm),
        "fig18" => scale::fig18(cm),
        "fig19" => scale::fig19(cm),
        "fig20" => scale::fig20(cm),
        "fig21" => scale::fig21(cm),
        "serving" => scale::serving_scale(cm),
        "placement" => scale::placement_scale(cm),
        "replan" => scale::replan_scale(cm),
        "transition" => scale::transition_scale(cm),
        _ => bail!("unknown experiment {id:?}; known: {ALL:?}"),
    })
}

/// Run and persist one experiment.
pub fn run_and_save(id: &str, cm: &CostModel, out_dir: &Path) -> Result<Table> {
    let t = run(id, cm)?;
    t.save(&out_dir.join(format!("{id}.csv")))?;
    Ok(t)
}
