//! Motivation & setup experiments: Fig 2 (hybrid DL under a 5G trace),
//! Fig 4 (discreteness of resource consumption), Table 2 (model specs),
//! Fig 6 (initial partition points & time budgets per scale).

use crate::coordinator::repartition::no_realign_plan;
use crate::coordinator::{ClientId, FragmentSpec};
use crate::hybrid::{choose_partition, BandwidthTrace, DeviceKind};
use crate::profiler::{AllocConstraints, CostModel, FragmentId, Profile};
use crate::util::csv::{f, Table};

use super::common::{fleet, model_idx, snapshot, Scale, MODELS};

/// Fig 2: partition point + server resource consumption of Inception-v3
/// under the embedded 50 s 5G snippet, vs the server-only baseline.
pub fn fig2(cm: &CostModel) -> Table {
    let mi = model_idx(cm, "inc");
    let m = &cm.config().models[mi];
    let trace = BandwidthTrace::embedded();
    let slo = DeviceKind::Nano.slo_ms(m, cm.config().slo_ratio_default);
    let cons = AllocConstraints::default();

    let mut t = Table::new(vec![
        "t_s",
        "mbps",
        "partition_point",
        "hybrid_share",
        "server_only_share",
        "hybrid_feasible",
    ]);
    for s in 0..trace.len_s() {
        let bw = trace.at(s as f64);
        let dec = choose_partition(cm, mi, DeviceKind::Nano, bw, slo, None);
        let (p, share, ok) = match dec.partition() {
            Some(part) => {
                let spec = FragmentSpec::single(
                    ClientId(0),
                    mi,
                    part.p,
                    part.server_budget_ms,
                    m.rate_rps,
                );
                let plan = no_realign_plan(cm, &[spec], &cons);
                (part.p as f64, plan.total_share() as f64, 1.0)
            }
            None => (f64::NAN, f64::NAN, 0.0),
        };
        // server-only: p = 0 regardless of Neurosurgeon (NaN when the
        // transfer alone blows the SLO — the §2 motivation case)
        let tx = crate::hybrid::transfer_ms(m.act_kb_at(0), bw);
        let only_share = if slo > tx {
            let only = FragmentSpec::single(
                ClientId(0),
                mi,
                0,
                slo - tx,
                m.rate_rps,
            );
            let plan = no_realign_plan(cm, &[only], &cons);
            if plan.infeasible.is_empty() {
                plan.total_share() as f64
            } else {
                f64::NAN
            }
        } else {
            f64::NAN
        };
        t.row(vec![
            s.to_string(),
            f(bw, 1),
            f(p, 0),
            f(share, 0),
            f(only_share, 0),
            f(ok, 0),
        ]);
    }
    t
}

/// Fig 4: required GPU share (a) vs time budget at 200 RPS and (b) vs
/// throughput at 25 ms, for Inception-v3 — the discreteness curves.
pub fn fig4(cm: &CostModel) -> Table {
    let mi = model_idx(cm, "inc");
    let layers = cm.config().models[mi].layers;
    let prof = Profile::new(FragmentId::new(mi, 0, layers));
    let cons = AllocConstraints::default();

    let mut t = Table::new(vec!["panel", "x", "total_share", "batch", "instances"]);
    for pt in prof.share_vs_budget(cm, 200.0, (10..=60).map(|b| b as f64), cons)
    {
        let (b, i) = pt
            .alloc
            .map(|a| (a.batch as f64, a.instances as f64))
            .unwrap_or((f64::NAN, f64::NAN));
        t.row(vec![
            "a:share_vs_budget".to_string(),
            f(pt.x, 0),
            pt.total_share.map_or("inf".into(), |s| s.to_string()),
            f(b, 0),
            f(i, 0),
        ]);
    }
    for pt in prof.share_vs_throughput(
        cm,
        25.0,
        (1..=30).map(|k| 10.0 * k as f64),
        cons,
    ) {
        let (b, i) = pt
            .alloc
            .map(|a| (a.batch as f64, a.instances as f64))
            .unwrap_or((f64::NAN, f64::NAN));
        t.row(vec![
            "b:share_vs_throughput".to_string(),
            f(pt.x, 0),
            pt.total_share.map_or("inf".into(), |s| s.to_string()),
            f(b, 0),
            f(i, 0),
        ]);
    }
    t
}

/// Table 2: layer counts + mobile/server latencies of the five models.
pub fn tab2(cm: &CostModel) -> Table {
    let mut t = Table::new(vec![
        "model",
        "layers",
        "mobile_ms_nano",
        "mobile_ms_tx2",
        "server_ms@share30_b1",
        "rate_rps",
    ]);
    for name in MODELS {
        let mi = model_idx(cm, name);
        let m = &cm.config().models[mi];
        let frag = FragmentId::new(mi, 0, m.layers);
        t.row(vec![
            name.to_string(),
            m.layers.to_string(),
            f(m.mobile_ms_nano, 0),
            f(m.mobile_ms_tx2, 0),
            f(cm.latency_ms(frag, 1, cm.config().gpu.ref_share as u32), 1),
            f(m.rate_rps, 0),
        ]);
    }
    t
}

/// Fig 6: distribution of initial partition points and time budgets per
/// model at small/large scale (10 trace snapshots each).
pub fn fig6(cm: &CostModel) -> Table {
    let mut t = Table::new(vec![
        "scale",
        "model",
        "client",
        "device",
        "t_s",
        "partition_point",
        "budget_ms",
    ]);
    for (scale, label) in
        [(Scale::SmallHeter, "S"), (Scale::LargeHeter, "L")]
    {
        for name in MODELS {
            let mi = model_idx(cm, name);
            let clients = fleet(cm, mi, scale, 0.95, 42);
            for rep in 0..10 {
                let t_s = rep as f64 * 7.0;
                for c in &clients {
                    if let Some(spec) = c.state_at(cm, t_s).spec {
                        t.row(vec![
                            label.to_string(),
                            name.to_string(),
                            c.id.0.to_string(),
                            c.device.name().to_string(),
                            f(t_s, 0),
                            spec.p.to_string(),
                            f(spec.budget_ms, 1),
                        ]);
                    }
                }
            }
        }
    }
    let _ = snapshot; // helper reused elsewhere
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    fn cm() -> CostModel {
        CostModel::new(Config::embedded())
    }

    #[test]
    fn fig2_shows_hybrid_savings_and_dynamics() {
        let cm = cm();
        let t = fig2(&cm);
        assert_eq!(t.rows.len(), 50);
        // hybrid never consumes more than server-only; strictly less
        // somewhere (paper: up to 3x less)
        let mut strictly = 0;
        let mut points = std::collections::HashSet::new();
        for r in &t.rows {
            let hybrid: f64 = r[3].parse().unwrap_or(f64::NAN);
            let only: f64 = r[4].parse().unwrap_or(f64::NAN);
            if hybrid.is_finite() {
                points.insert(r[2].clone());
                if !only.is_finite() || hybrid < only {
                    // cheaper, or feasible where server-only is not
                    strictly += 1;
                }
                if only.is_finite() {
                    assert!(hybrid <= only + 1e-9, "{r:?}");
                }
            }
        }
        assert!(strictly > 5, "hybrid never cheaper");
        assert!(points.len() >= 3, "partition point never moved: {points:?}");
    }

    #[test]
    fn fig4_has_both_panels_with_steps() {
        let cm = cm();
        let t = fig4(&cm);
        let a: Vec<&Vec<String>> =
            t.rows.iter().filter(|r| r[0].starts_with("a:")).collect();
        let b: Vec<&Vec<String>> =
            t.rows.iter().filter(|r| r[0].starts_with("b:")).collect();
        assert_eq!(a.len(), 51);
        assert_eq!(b.len(), 30);
    }

    #[test]
    fn tab2_matches_calibration() {
        let cm = cm();
        let t = tab2(&cm);
        assert_eq!(t.rows.len(), 5);
        let inc = &t.rows[0];
        assert_eq!(inc[1], "17");
        assert_eq!(inc[4], "29.0");
    }

    #[test]
    fn fig6_covers_scales_and_models() {
        let cm = cm();
        let t = fig6(&cm);
        assert!(t.rows.len() > 200);
        assert!(t.rows.iter().any(|r| r[0] == "S"));
        assert!(t.rows.iter().any(|r| r[0] == "L"));
        assert!(t.rows.iter().any(|r| r[3] == "tx2"));
    }
}
