//! Scale & robustness experiments: Fig 17 (achievable throughput under
//! capped resources), Fig 18 (massive-scale simulation), Fig 19 (system
//! overhead + realignment pool size), Fig 20 (SLO-ratio sensitivity),
//! Fig 21 (energy consumption).

use std::time::Instant;

use crate::coordinator::baselines::{gslice, gslice_plus};
use crate::coordinator::merging::MergeOptions;
use crate::coordinator::optimal::optimal_plan;
use crate::coordinator::repartition::RepartitionOptions;
use crate::coordinator::scheduler::{Scheduler, SchedulerOptions};
use crate::coordinator::FragmentSpec;
use crate::hybrid::{choose_partition, DeviceKind};
use crate::profiler::{AllocConstraints, CostModel};
use crate::sim::plan_energy_j;
use crate::util::csv::{f, Table};

use super::common::{
    fleet, graft_plan, model_idx, random_fragments, snapshot,
    static_clients, Scale, MODELS,
};

fn graft_sched(cm: &CostModel, merge_thr: f64, pool: usize) -> Scheduler {
    Scheduler::new(
        cm.clone(),
        SchedulerOptions {
            merge: MergeOptions { threshold: merge_thr, ..Default::default() },
            pool_size: pool,
            ..Default::default()
        },
    )
}

/// Fig 17: maximum aggregate throughput each system sustains under a
/// fixed resource cap (4 GPUs = 400 share points): grow the fragment
/// population until the plan no longer fits.
pub fn fig17(cm: &CostModel) -> Table {
    let cap: u32 = 400;
    let cons = AllocConstraints::default();
    let mut t = Table::new(vec![
        "model",
        "system",
        "max_throughput_rps",
        "fragments_at_cap",
    ]);
    for name in MODELS {
        let mi = model_idx(cm, name);
        let rate = cm.config().models[mi].rate_rps;
        for sys in ["graft", "gslice", "gslice+"] {
            let mut best_rps = 0.0;
            let mut best_n = 0usize;
            let mut n = 2usize;
            loop {
                let frags = random_fragments(cm, mi, n, 4321);
                let plan = match sys {
                    "graft" => graft_sched(cm, 0.2, 2).plan(&frags).0,
                    "gslice" => gslice(cm, &frags, &cons),
                    _ => gslice_plus(cm, &frags, &cons),
                };
                if plan.total_share() > cap || !plan.infeasible.is_empty() {
                    break;
                }
                best_rps = n as f64 * rate;
                best_n = n;
                n += 2;
                if n > 400 {
                    break; // safety
                }
            }
            t.row(vec![
                name.to_string(),
                sys.to_string(),
                f(best_rps, 0),
                best_n.to_string(),
            ]);
        }
    }
    t
}

/// Fig 18: massive-scale resource consumption (hundreds–thousands of
/// fragments; merging threshold 0.01 as in §5.8).
pub fn fig18(cm: &CostModel) -> Table {
    let cons = AllocConstraints::default();
    let mut t = Table::new(vec!["model", "n_fragments", "system", "total_share"]);
    for name in MODELS {
        let mi = model_idx(cm, name);
        let m = &cm.config().models[mi];
        for n in [250usize, 500, 1000] {
            let frags = random_fragments(cm, mi, n, 9000 + n as u64);
            let rows: Vec<(&str, u32)> = vec![
                (
                    "graft",
                    graft_sched(cm, 0.01, 4).plan(&frags).0.total_share(),
                ),
                ("gslice", gslice(cm, &frags, &cons).total_share()),
                ("gslice+", gslice_plus(cm, &frags, &cons).total_share()),
                ("static", {
                    // static: provision every client at the mean bandwidth
                    let bw = 120.0;
                    let slo = DeviceKind::Nano
                        .slo_ms(m, cm.config().slo_ratio_default);
                    let static_specs: Vec<FragmentSpec> = frags
                        .iter()
                        .filter_map(|frag| {
                            choose_partition(
                                cm,
                                mi,
                                DeviceKind::Nano,
                                bw,
                                slo,
                                None,
                            )
                            .partition()
                            .map(|p| {
                                let mut s = frag.clone();
                                s.p = p.p;
                                s.budget_ms = p.server_budget_ms;
                                s
                            })
                        })
                        .collect();
                    gslice(cm, &static_specs, &cons).total_share()
                }),
            ];
            for (sys, share) in rows {
                t.row(vec![
                    name.to_string(),
                    n.to_string(),
                    sys.to_string(),
                    share.to_string(),
                ]);
            }
        }
    }
    t
}

/// Fig 19: (a) Graft scheduling time vs fragment count (+ Optimal at a
/// small count for the ~99% reduction claim + memory footprint);
/// (b) time cost vs realignment pool size (50 ViT fragments).
pub fn fig19(cm: &CostModel) -> Table {
    let mut t = Table::new(vec!["panel", "model", "x", "time_ms", "note"]);
    // (a) Graft time for 10..50 fragments, every model
    for name in MODELS {
        let mi = model_idx(cm, name);
        for n in [10usize, 20, 30, 40, 50] {
            let frags = random_fragments(cm, mi, n, 1357 + n as u64);
            let sched = graft_sched(cm, 0.2, 2);
            let t0 = Instant::now();
            let _ = sched.plan(&frags);
            t.row(vec![
                "a:graft_time".to_string(),
                name.to_string(),
                n.to_string(),
                f(t0.elapsed().as_secs_f64() * 1e3, 2),
                String::new(),
            ]);
        }
    }
    // (a') Optimal time at n=8 (exponential grouping enumeration)
    {
        let mi = model_idx(cm, "inc");
        let frags = random_fragments(cm, mi, 8, 2468);
        let t0 = Instant::now();
        let _ = optimal_plan(cm, &frags, 5, &RepartitionOptions::default());
        let opt_ms = t0.elapsed().as_secs_f64() * 1e3;
        let t1 = Instant::now();
        let _ = graft_sched(cm, 0.2, 2).plan(&frags);
        let graft_ms = t1.elapsed().as_secs_f64() * 1e3;
        t.row(vec![
            "a:optimal_time".to_string(),
            "inc".to_string(),
            "8".to_string(),
            f(opt_ms, 2),
            format!(
                "graft={}ms reduction={}%",
                f(graft_ms, 2),
                f((1.0 - graft_ms / opt_ms) * 100.0, 1)
            ),
        ]);
    }
    // (b) pool sizes on 50 ViT fragments — use the Optimal-grade d_shared
    // grid so per-group re-alignment dominates the schedule time (the
    // regime Fig 19b studies; with the default coarse grid the groups
    // finish in ~1 ms each and pooling has nothing to parallelise)
    {
        let mi = model_idx(cm, "vit");
        let frags = random_fragments(cm, mi, 50, 3579);
        for pool in 1..=6usize {
            let mut sched = graft_sched(cm, 0.2, pool);
            sched.opts.repartition.d_grid = 96;
            let t0 = Instant::now();
            let _ = sched.plan(&frags);
            t.row(vec![
                "b:pool_size".to_string(),
                "vit".to_string(),
                pool.to_string(),
                f(t0.elapsed().as_secs_f64() * 1e3, 2),
                String::new(),
            ]);
        }
    }
    t
}

/// Fig 20: Graft normalised by Optimal under SLO ratios 0.5–0.9.
pub fn fig20(cm: &CostModel) -> Table {
    let mut t = Table::new(vec![
        "model",
        "slo_ratio",
        "graft_share",
        "optimal_share",
        "ratio",
    ]);
    for name in MODELS {
        let mi = model_idx(cm, name);
        for ratio in [0.5, 0.6, 0.7, 0.8, 0.9] {
            let clients = fleet(cm, mi, Scale::SmallHomo, ratio, 24680);
            let specs = snapshot(cm, &clients, 5.0);
            if specs.is_empty() {
                t.row(vec![
                    name.to_string(),
                    f(ratio, 1),
                    "inf".to_string(),
                    "inf".to_string(),
                    "nan".to_string(),
                ]);
                continue; // Neurosurgeon infeasible (paper: <0.7 for Inc)
            }
            let graft = graft_plan(cm, &specs, AllocConstraints::default());
            let opt = optimal_plan(
                cm,
                &specs,
                5,
                &RepartitionOptions::default(),
            );
            let (g, o) = (graft.total_share(), opt.total_share());
            t.row(vec![
                name.to_string(),
                f(ratio, 1),
                g.to_string(),
                o.to_string(),
                f(g as f64 / o.max(1) as f64, 3),
            ]);
        }
    }
    t
}

/// Fig 21: energy consumption over a 60 s window, small (4 fragments)
/// and large (20 fragments) homogeneous scales.
pub fn fig21(cm: &CostModel) -> Table {
    let cons = AllocConstraints::default();
    let mut t = Table::new(vec!["scale", "model", "system", "energy_j"]);
    for (scale, nfr) in [(Scale::SmallHomo, 4usize), (Scale::LargeHomo, 20)] {
        for name in MODELS {
            let mi = model_idx(cm, name);
            let clients = fleet(cm, mi, scale, 0.95, 8642);
            let specs = snapshot(cm, &clients, 5.0);
            if specs.is_empty() {
                continue;
            }
            let st = static_clients(cm, &clients);
            let plans: Vec<(&str, crate::coordinator::ExecutionPlan)> = vec![
                ("graft", graft_plan(cm, &specs, cons)),
                ("gslice", gslice(cm, &specs, &cons)),
                ("gslice+", gslice_plus(cm, &specs, &cons)),
                (
                    "static",
                    crate::coordinator::baselines::static_alloc(
                        cm, &st, &cons, None,
                    ),
                ),
                (
                    "static+",
                    crate::coordinator::baselines::static_plus(
                        cm, &st, &cons, None,
                    ),
                ),
            ];
            for (sys, plan) in plans {
                t.row(vec![
                    format!("{}x{}", scale.id(), nfr),
                    name.to_string(),
                    sys.to_string(),
                    f(plan_energy_j(cm, &plan, 60.0), 0),
                ]);
            }
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    fn cm() -> CostModel {
        CostModel::new(Config::embedded())
    }

    #[test]
    fn fig17_graft_sustains_more_throughput() {
        let cm = cm();
        let t = fig17(&cm);
        for name in ["inc", "vgg"] {
            let get = |sys: &str| -> f64 {
                t.rows
                    .iter()
                    .find(|r| r[0] == name && r[1] == sys)
                    .unwrap()[2]
                    .parse()
                    .unwrap()
            };
            assert!(
                get("graft") >= get("gslice"),
                "{name}: graft {} < gslice {}",
                get("graft"),
                get("gslice")
            );
        }
    }

    #[test]
    fn fig21_graft_beats_unmerged_baselines() {
        let cm = cm();
        let t = fig21(&cm);
        assert!(!t.rows.is_empty());
        let get = |scale_pfx: &str, model: &str, sys: &str| -> f64 {
            t.rows
                .iter()
                .find(|r| r[0].starts_with(scale_pfx) && r[1] == model && r[2] == sys)
                .map(|r| r[3].parse().unwrap())
                .unwrap_or(f64::NAN)
        };
        let g = get("small", "inc", "graft");
        let s = get("small", "inc", "gslice");
        assert!(g <= s * 1.05, "graft {g} vs gslice {s}");
    }
}
