//! Scale & robustness experiments: Fig 17 (achievable throughput under
//! capped resources), Fig 18 (massive-scale simulation), Fig 19 (system
//! overhead + realignment pool size), Fig 20 (SLO-ratio sensitivity),
//! Fig 21 (energy consumption), plus the serving-path throughput
//! harness ("serving": thread-per-instance vs pooled executor), the
//! GPU-placement comparison ("placement": planner-integrated packing
//! vs the post-hoc FFD oracle and the GSLICE baseline) and the
//! trigger-to-trigger replanning harness ("replan": perturb k% of the
//! clients, re-plan incrementally, compare against cold planning —
//! shared by `graft bench-scheduler`'s replan scenario).

use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use crate::config::Config;
use crate::coordinator::baselines::{gslice, gslice_plus};
use crate::coordinator::merging::MergeOptions;
use crate::coordinator::optimal::optimal_plan;
use crate::coordinator::placement::{place, PlacementOptions};
use crate::coordinator::repartition::{
    plan_covers_demand, plan_is_slo_safe, RepartitionOptions,
};
use crate::coordinator::scheduler::{Scheduler, SchedulerOptions};
use crate::coordinator::{ExecutionPlan, FragmentSpec};
use crate::sim::pack;
use crate::hybrid::{choose_partition, DeviceKind};
use crate::metrics::LatencyStats;
use crate::obs::{
    counter_sum, counter_value, BudgetAttribution, Metric, MetricsRegistry,
    TraceOptions,
};
use crate::profiler::{AllocConstraints, CostModel};
use crate::serving::{
    ExecutorMode, FaultDomain, FaultKind, FaultPlan, FaultyExecutor,
    FragmentExecutor, MockExecutor, Request, Response, Server,
    ServerOptions,
};
use crate::sim::plan_energy_j;
use crate::util::csv::{f, Table};

use super::common::{
    fleet, graft_plan, model_idx, random_fragments, random_mixed_fragments,
    snapshot, static_clients, Scale, MODELS,
};

fn graft_sched(cm: &CostModel, merge_thr: f64, pool: usize) -> Scheduler {
    Scheduler::new(
        cm.clone(),
        SchedulerOptions {
            merge: MergeOptions { threshold: merge_thr, ..Default::default() },
            pool_size: pool,
            ..Default::default()
        },
    )
}

/// Fig 17: maximum aggregate throughput each system sustains under a
/// fixed resource cap (4 GPUs = 400 share points): grow the fragment
/// population until the plan no longer fits.
pub fn fig17(cm: &CostModel) -> Table {
    let cap: u32 = 400;
    let cons = AllocConstraints::default();
    let mut t = Table::new(vec![
        "model",
        "system",
        "max_throughput_rps",
        "fragments_at_cap",
    ]);
    for name in MODELS {
        let mi = model_idx(cm, name);
        let rate = cm.config().models[mi].rate_rps;
        for sys in ["graft", "gslice", "gslice+"] {
            let mut best_rps = 0.0;
            let mut best_n = 0usize;
            let mut n = 2usize;
            loop {
                let frags = random_fragments(cm, mi, n, 4321);
                let plan = match sys {
                    "graft" => graft_sched(cm, 0.2, 2).plan(&frags).0,
                    "gslice" => gslice(cm, &frags, &cons),
                    _ => gslice_plus(cm, &frags, &cons),
                };
                if plan.total_share() > cap || !plan.infeasible.is_empty() {
                    break;
                }
                best_rps = n as f64 * rate;
                best_n = n;
                n += 2;
                if n > 400 {
                    break; // safety
                }
            }
            t.row(vec![
                name.to_string(),
                sys.to_string(),
                f(best_rps, 0),
                best_n.to_string(),
            ]);
        }
    }
    t
}

/// Fig 18: massive-scale resource consumption (hundreds–thousands of
/// fragments; merging threshold 0.01 as in §5.8).
pub fn fig18(cm: &CostModel) -> Table {
    let cons = AllocConstraints::default();
    let mut t = Table::new(vec!["model", "n_fragments", "system", "total_share"]);
    for name in MODELS {
        let mi = model_idx(cm, name);
        let m = &cm.config().models[mi];
        for n in [250usize, 500, 1000] {
            let frags = random_fragments(cm, mi, n, 9000 + n as u64);
            let rows: Vec<(&str, u32)> = vec![
                (
                    "graft",
                    graft_sched(cm, 0.01, 4).plan(&frags).0.total_share(),
                ),
                ("gslice", gslice(cm, &frags, &cons).total_share()),
                ("gslice+", gslice_plus(cm, &frags, &cons).total_share()),
                ("static", {
                    // static: provision every client at the mean bandwidth
                    let bw = 120.0;
                    let slo = DeviceKind::Nano
                        .slo_ms(m, cm.config().slo_ratio_default);
                    let static_specs: Vec<FragmentSpec> = frags
                        .iter()
                        .filter_map(|frag| {
                            choose_partition(
                                cm,
                                mi,
                                DeviceKind::Nano,
                                bw,
                                slo,
                                None,
                            )
                            .partition()
                            .map(|p| {
                                let mut s = frag.clone();
                                s.p = p.p;
                                s.budget_ms = p.server_budget_ms;
                                s
                            })
                        })
                        .collect();
                    gslice(cm, &static_specs, &cons).total_share()
                }),
            ];
            for (sys, share) in rows {
                t.row(vec![
                    name.to_string(),
                    n.to_string(),
                    sys.to_string(),
                    share.to_string(),
                ]);
            }
        }
    }
    t
}

/// Fig 19: (a) Graft scheduling time vs fragment count (+ Optimal at a
/// small count for the ~99% reduction claim + memory footprint);
/// (b) time cost vs realignment pool size (50 ViT fragments).
pub fn fig19(cm: &CostModel) -> Table {
    let mut t = Table::new(vec!["panel", "model", "x", "time_ms", "note"]);
    // (a) Graft time for 10..50 fragments, every model
    for name in MODELS {
        let mi = model_idx(cm, name);
        for n in [10usize, 20, 30, 40, 50] {
            let frags = random_fragments(cm, mi, n, 1357 + n as u64);
            let sched = graft_sched(cm, 0.2, 2);
            let t0 = Instant::now();
            let _ = sched.plan(&frags);
            t.row(vec![
                "a:graft_time".to_string(),
                name.to_string(),
                n.to_string(),
                f(t0.elapsed().as_secs_f64() * 1e3, 2),
                String::new(),
            ]);
        }
    }
    // (a') Optimal time at n=8 (exponential grouping enumeration)
    {
        let mi = model_idx(cm, "inc");
        let frags = random_fragments(cm, mi, 8, 2468);
        let t0 = Instant::now();
        let _ = optimal_plan(cm, &frags, 5, &RepartitionOptions::default());
        let opt_ms = t0.elapsed().as_secs_f64() * 1e3;
        let t1 = Instant::now();
        let _ = graft_sched(cm, 0.2, 2).plan(&frags);
        let graft_ms = t1.elapsed().as_secs_f64() * 1e3;
        t.row(vec![
            "a:optimal_time".to_string(),
            "inc".to_string(),
            "8".to_string(),
            f(opt_ms, 2),
            format!(
                "graft={}ms reduction={}%",
                f(graft_ms, 2),
                f((1.0 - graft_ms / opt_ms) * 100.0, 1)
            ),
        ]);
    }
    // (b) pool sizes on 50 ViT fragments — use the Optimal-grade d_shared
    // grid so per-group re-alignment dominates the schedule time (the
    // regime Fig 19b studies; with the default coarse grid the groups
    // finish in ~1 ms each and pooling has nothing to parallelise)
    {
        let mi = model_idx(cm, "vit");
        let frags = random_fragments(cm, mi, 50, 3579);
        for pool in 1..=6usize {
            let mut sched = graft_sched(cm, 0.2, pool);
            sched.opts.repartition.d_grid = 96;
            let t0 = Instant::now();
            let _ = sched.plan(&frags);
            t.row(vec![
                "b:pool_size".to_string(),
                "vit".to_string(),
                pool.to_string(),
                f(t0.elapsed().as_secs_f64() * 1e3, 2),
                String::new(),
            ]);
        }
    }
    t
}

/// Fig 20: Graft normalised by Optimal under SLO ratios 0.5–0.9.
pub fn fig20(cm: &CostModel) -> Table {
    let mut t = Table::new(vec![
        "model",
        "slo_ratio",
        "graft_share",
        "optimal_share",
        "ratio",
    ]);
    for name in MODELS {
        let mi = model_idx(cm, name);
        for ratio in [0.5, 0.6, 0.7, 0.8, 0.9] {
            let clients = fleet(cm, mi, Scale::SmallHomo, ratio, 24680);
            let specs = snapshot(cm, &clients, 5.0);
            if specs.is_empty() {
                t.row(vec![
                    name.to_string(),
                    f(ratio, 1),
                    "inf".to_string(),
                    "inf".to_string(),
                    "nan".to_string(),
                ]);
                continue; // Neurosurgeon infeasible (paper: <0.7 for Inc)
            }
            let graft = graft_plan(cm, &specs, AllocConstraints::default());
            let opt = optimal_plan(
                cm,
                &specs,
                5,
                &RepartitionOptions::default(),
            );
            let (g, o) = (graft.total_share(), opt.total_share());
            t.row(vec![
                name.to_string(),
                f(ratio, 1),
                g.to_string(),
                o.to_string(),
                f(g as f64 / o.max(1) as f64, 3),
            ]);
        }
    }
    t
}

/// Fig 21: energy consumption over a 60 s window, small (4 fragments)
/// and large (20 fragments) homogeneous scales.
pub fn fig21(cm: &CostModel) -> Table {
    let cons = AllocConstraints::default();
    let mut t = Table::new(vec!["scale", "model", "system", "energy_j"]);
    for (scale, nfr) in [(Scale::SmallHomo, 4usize), (Scale::LargeHomo, 20)] {
        for name in MODELS {
            let mi = model_idx(cm, name);
            let clients = fleet(cm, mi, scale, 0.95, 8642);
            let specs = snapshot(cm, &clients, 5.0);
            if specs.is_empty() {
                continue;
            }
            let st = static_clients(cm, &clients);
            let plans: Vec<(&str, crate::coordinator::ExecutionPlan)> = vec![
                ("graft", graft_plan(cm, &specs, cons)),
                ("gslice", gslice(cm, &specs, &cons)),
                ("gslice+", gslice_plus(cm, &specs, &cons)),
                (
                    "static",
                    crate::coordinator::baselines::static_alloc(
                        cm, &st, &cons, None,
                    ),
                ),
                (
                    "static+",
                    crate::coordinator::baselines::static_plus(
                        cm, &st, &cons, None,
                    ),
                ),
            ];
            for (sys, plan) in plans {
                t.row(vec![
                    format!("{}x{}", scale.id(), nfr),
                    name.to_string(),
                    sys.to_string(),
                    f(plan_energy_j(cm, &plan, 60.0), 0),
                ]);
            }
        }
    }
    t
}

/// One measured serving run (mock executor, pacing disabled so the
/// numbers isolate queue/dispatch overhead).
#[derive(Debug, Clone)]
pub struct ServingBenchPoint {
    pub mode: ExecutorMode,
    /// Responses actually collected (== submitted unless something
    /// wedged; the collector times out rather than hang).
    pub requests: usize,
    pub wall_ms: f64,
    pub throughput_rps: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// Executor threads (instances or pool workers).
    pub threads: usize,
    /// Planned instances across all stages.
    pub instances: usize,
    pub batches: u64,
    pub served: u64,
    pub dropped: u64,
    /// Work items refused by closed queues (balancer + per-queue
    /// rejection counters).  Queue rejections were counted in
    /// `QueueMetrics` since PR 2 but never surfaced in the bench —
    /// non-zero here means the run lost items to a shutdown race.
    pub rejected: u64,
}

/// A [`ServingBenchPoint`] plus the observability artifacts of the run:
/// the registry snapshot its counters were read from (so the bench JSON
/// and the `/metrics` endpoint can never disagree on a number) and —
/// when tracing was on — the SLO-budget attribution.
#[derive(Debug, Clone)]
pub struct ServingBenchRun {
    pub point: ServingBenchPoint,
    pub snapshot: Vec<Metric>,
    pub attribution: Option<BudgetAttribution>,
}

pub fn mode_name(mode: ExecutorMode) -> &'static str {
    match mode {
        ExecutorMode::Threads => "threads",
        ExecutorMode::Pool => "pool",
    }
}

/// Fire the control-domain faults due at this submit tick against the
/// live server: GPU failures kill every co-located instance, shard
/// poisonings panic a lock that the queue then recovers.
fn apply_control_faults(server: &Server, plan: &FaultPlan) {
    for kind in plan.tick(FaultDomain::Control) {
        match kind {
            FaultKind::GpuFail { gpu } => {
                server.fail_gpu(gpu);
            }
            FaultKind::PoisonShard { stage, shard } => {
                server.poison_stage_queue(stage, shard);
            }
            FaultKind::GpuDegrade {
                gpu,
                share_loss,
                mem_loss_mb,
            } => {
                server.degrade_gpu(gpu, share_loss, mem_loss_mb as f64);
            }
            FaultKind::GpuWarn { gpu } => {
                server.warn_gpu(gpu);
            }
            _ => {}
        }
    }
}

/// Drive `total_reqs` synthetic requests through a real [`Server`] for
/// `plan` (mock executor, no pacing, no SLO drops) and measure
/// end-to-end throughput and latency.  Producers submit round-robin
/// over every routed client from 4 threads; a collector thread stamps
/// response arrivals.
pub fn serve_synthetic(
    cm: &CostModel,
    plan: &ExecutionPlan,
    mode: ExecutorMode,
    total_reqs: usize,
) -> ServingBenchPoint {
    serve_synthetic_with_faults(cm, plan, mode, total_reqs, None)
}

/// [`serve_synthetic`] under an optional [`FaultPlan`]: executor-domain
/// events fire through a [`FaultyExecutor`] wrapper (one tick per batch
/// execution), control-domain events (GPU failures, shard poisonings)
/// tick once per submitted request in the producers.  Seeded plans make
/// the whole chaos run reproducible.
pub fn serve_synthetic_with_faults(
    cm: &CostModel,
    plan: &ExecutionPlan,
    mode: ExecutorMode,
    total_reqs: usize,
    faults: Option<Arc<FaultPlan>>,
) -> ServingBenchPoint {
    serve_synthetic_run(
        cm,
        plan,
        mode,
        total_reqs,
        faults,
        TraceOptions::default(),
    )
    .point
}

/// The full harness: [`serve_synthetic_with_faults`] with request
/// tracing configurable, returning the registry snapshot and (tracing
/// on) the budget attribution alongside the measured point.
pub fn serve_synthetic_run(
    cm: &CostModel,
    plan: &ExecutionPlan,
    mode: ExecutorMode,
    total_reqs: usize,
    faults: Option<Arc<FaultPlan>>,
    trace: TraceOptions,
) -> ServingBenchRun {
    // every routed client with its partition point / payload width
    let mut targets: Vec<(u32, u16, u16, usize)> = Vec::new();
    let mut instances = 0usize;
    for set in &plan.sets {
        instances += set.shared.alloc.instances as usize;
        for m in &set.members {
            if let Some(a) = &m.align {
                instances += a.alloc.instances as usize;
            }
            let dim = cm.config().models[set.model].dims[m.spec.p];
            for c in &m.spec.clients {
                targets.push((c.0, set.model as u16, m.spec.p as u16, dim));
            }
        }
    }
    let mut point = ServingBenchPoint {
        mode,
        requests: 0,
        wall_ms: 0.0,
        throughput_rps: 0.0,
        p50_ms: f64::NAN,
        p99_ms: f64::NAN,
        threads: 0,
        instances,
        batches: 0,
        served: 0,
        dropped: 0,
        rejected: 0,
    };
    if targets.is_empty() || total_reqs == 0 {
        return ServingBenchRun {
            point,
            snapshot: Vec::new(),
            attribution: None,
        };
    }
    let dims: HashMap<String, Vec<usize>> = cm
        .config()
        .models
        .iter()
        .map(|m| (m.name.clone(), m.dims.clone()))
        .collect();
    let mock: Arc<dyn FragmentExecutor> = Arc::new(MockExecutor { dims });
    let executor: Arc<dyn FragmentExecutor> = match &faults {
        Some(fp) => Arc::new(FaultyExecutor::new(mock, fp.clone())),
        None => mock,
    };
    let server = Arc::new(Server::start(
        executor,
        cm,
        plan,
        ServerOptions {
            time_scale: 0.0,
            drop_on_slo: false,
            mode,
            trace,
            ..Default::default()
        },
    ));
    point.threads = server.thread_count();

    let producers = 4usize.min(total_reqs).max(1);
    let (tx, rx) = mpsc::channel::<Response>();
    let t_start = Instant::now();
    let (subs, recvd, t_end) = std::thread::scope(|scope| {
        let collector = scope.spawn(move || {
            let mut recvd: Vec<(u32, Instant)> =
                Vec::with_capacity(total_reqs);
            while recvd.len() < total_reqs {
                match rx.recv_timeout(Duration::from_secs(30)) {
                    Ok(r) => recvd.push((r.seq, Instant::now())),
                    Err(_) => break, // lost responses: report what we got
                }
            }
            (recvd, Instant::now())
        });
        let mut prod_handles = Vec::new();
        for pidx in 0..producers {
            let tx = tx.clone();
            let server: &Server = &server;
            let targets = &targets;
            let faults = faults.clone();
            prod_handles.push(scope.spawn(move || {
                let mut local: Vec<(u32, Instant)> = Vec::new();
                let mut i = pidx;
                while i < total_reqs {
                    if let Some(fp) = &faults {
                        apply_control_faults(server, fp);
                    }
                    let (cid, model, p, dim) = targets[i % targets.len()];
                    let req = Request {
                        client_id: cid,
                        model,
                        p,
                        seq: i as u32,
                        t_capture_ms: 0.0,
                        upstream_ms: 0.0,
                        budget_ms: 1e9,
                        payload: vec![0.5; dim],
                    };
                    let t = Instant::now();
                    server.submit(req, tx.clone());
                    local.push((i as u32, t));
                    i += producers;
                }
                local
            }));
        }
        drop(tx);
        let mut subs: Vec<(u32, Instant)> = Vec::with_capacity(total_reqs);
        for h in prod_handles {
            subs.extend(h.join().expect("producer"));
        }
        let (recvd, t_end) = collector.join().expect("collector");
        (subs, recvd, t_end)
    });

    let mut submit_at: Vec<Option<Instant>> = vec![None; total_reqs];
    for (seq, t) in subs {
        submit_at[seq as usize] = Some(t);
    }
    let mut lat = LatencyStats::new();
    for (seq, at) in &recvd {
        if let Some(t0) = submit_at[*seq as usize] {
            lat.record(at.duration_since(t0).as_secs_f64() * 1e3);
        }
    }
    let wall_s = (t_end - t_start).as_secs_f64().max(1e-9);
    point.requests = recvd.len();
    point.wall_ms = wall_s * 1e3;
    point.throughput_rps = recvd.len() as f64 / wall_s;
    point.p50_ms = lat.percentile(50.0);
    point.p99_ms = lat.percentile(99.0);
    // counters come from the registry snapshot — the same numbers the
    // `/metrics` endpoint and the `[serve]` stats line render, so the
    // bench JSON can never disagree with the exposition
    let registry = MetricsRegistry::new();
    {
        let s = server.clone();
        registry.register("serving", move |out| s.collect_metrics(out));
    }
    let snap = registry.snapshot();
    point.batches =
        counter_value(&snap, "graft_serving_batches_total").unwrap_or(0);
    point.served =
        counter_value(&snap, "graft_serving_served_total").unwrap_or(0);
    point.dropped =
        counter_value(&snap, "graft_serving_dropped_total").unwrap_or(0);
    // queue-level count only: ServerCounters::rejected mirrors the same
    // refusals, so adding both would double-count every lost item
    point.rejected = counter_sum(&snap, "graft_queue_rejected_total");
    let attribution = if trace.enabled() {
        Some(BudgetAttribution::from_obs(
            cm,
            plan,
            &server.obs(),
            server.time_scale(),
        ))
    } else {
        None
    };
    drop(registry); // releases its Arc so the server can be torn down
    if let Ok(s) = Arc::try_unwrap(server) {
        s.shutdown();
    }
    ServingBenchRun { point, snapshot: snap, attribution }
}

/// Plan a mixed-model fleet of `n_clients` and measure the serving path
/// under `mode` (shared harness of the `serving` experiment and the
/// `bench-serving` CLI).
pub fn serving_throughput(
    cm: &CostModel,
    n_clients: usize,
    total_reqs: usize,
    mode: ExecutorMode,
    seed: u64,
) -> ServingBenchPoint {
    let specs = random_mixed_fragments(cm, n_clients, seed);
    let sched = Scheduler::new(cm.clone(), SchedulerOptions::default());
    let (plan, _) = sched.plan(&specs);
    serve_synthetic(cm, &plan, mode, total_reqs)
}

/// Experiment "serving": thread-per-instance vs pooled executor on the
/// same plans (small fleets so `experiment all` stays fast; the 1k–10k
/// sweep lives in `graft bench-serving`).
pub fn serving_scale(cm: &CostModel) -> Table {
    let mut t = Table::new(vec![
        "n_clients",
        "mode",
        "requests",
        "throughput_rps",
        "p50_ms",
        "p99_ms",
        "threads",
        "instances",
        "batches",
    ]);
    for &n in &[64usize, 256] {
        let specs = random_mixed_fragments(cm, n, 0xACE5 + n as u64);
        let sched = Scheduler::new(cm.clone(), SchedulerOptions::default());
        let (plan, _) = sched.plan(&specs);
        for mode in [ExecutorMode::Threads, ExecutorMode::Pool] {
            let r = serve_synthetic(cm, &plan, mode, 2000);
            t.row(vec![
                n.to_string(),
                mode_name(mode).to_string(),
                r.requests.to_string(),
                f(r.throughput_rps, 0),
                f(r.p50_ms, 2),
                f(r.p99_ms, 2),
                r.threads.to_string(),
                r.instances.to_string(),
                r.batches.to_string(),
            ]);
        }
    }
    t
}

/// Experiment "placement": GPU counts and fragmentation of the
/// planner-integrated placement vs the post-hoc FFD oracle (packing
/// the feedback-free plan after the fact) and the GSLICE baseline
/// placed post-hoc.  Small fleets so `experiment all` stays fast; the
/// 1k–10k sweep lives in `graft bench-placement`.
pub fn placement_scale(cm: &CostModel) -> Table {
    let mut t = Table::new(vec![
        "n_clients",
        "system",
        "total_share",
        "share_lb_gpus",
        "gpus",
        "fragmentation",
        "feedback_rounds",
    ]);
    let max_share = cm.config().gpu.max_share;
    for &n in &[64usize, 256] {
        let specs = random_mixed_fragments(cm, n, 0x91ACE + n as u64);
        // graft: placement integrated into planning (stamped plan)
        let sched = Scheduler::new(cm.clone(), SchedulerOptions::default());
        let (plan, stats) = sched.plan(&specs);
        t.row(vec![
            n.to_string(),
            "graft".to_string(),
            plan.total_share().to_string(),
            plan.gpus_share_lower_bound(max_share).to_string(),
            stats.gpus.to_string(),
            f(stats.fragmentation, 3),
            stats.placement_rounds.to_string(),
        ]);
        // oracle: FFD-pack the feedback-free plan after the fact
        let base = Scheduler::new(
            cm.clone(),
            SchedulerOptions {
                placement: PlacementOptions {
                    enabled: false,
                    ..Default::default()
                },
                ..Default::default()
            },
        );
        let (plan0, _) = base.plan(&specs);
        let oracle = pack(cm, &plan0, None);
        t.row(vec![
            n.to_string(),
            "graft-posthoc".to_string(),
            plan0.total_share().to_string(),
            plan0.gpus_share_lower_bound(max_share).to_string(),
            // "nan" when the oracle cannot pack at all — 0 would read
            // as beating every real placement
            oracle
                .as_ref()
                .map_or("nan".into(), |p| p.gpus.to_string()),
            oracle
                .as_ref()
                .map_or("nan".into(), |p| f(p.fragmentation(max_share), 3)),
            "0".to_string(),
        ]);
        // GSLICE: no realignment, placed post-hoc ("nan" when some
        // instance cannot fit a single GPU)
        let gp = gslice(cm, &specs, &AllocConstraints::default());
        let gplaced = place(cm, &gp, None).ok();
        t.row(vec![
            n.to_string(),
            "gslice".to_string(),
            gp.total_share().to_string(),
            gp.gpus_share_lower_bound(max_share).to_string(),
            gplaced
                .as_ref()
                .map_or("nan".into(), |p| p.gpus().to_string()),
            gplaced
                .as_ref()
                .map_or("nan".into(), |p| f(p.fragmentation(max_share), 3)),
            "0".to_string(),
        ]);
    }
    t
}

/// One measured trigger-to-trigger replan run (the `replan` scenario of
/// `graft bench-scheduler` and experiment "replan").
#[derive(Debug, Clone)]
pub struct ReplanPoint {
    pub n_clients: usize,
    pub perturb_pct: usize,
    /// First trigger on a fresh scheduler (cold caches).
    pub cold_ms: f64,
    /// Re-plan of the perturbed demands on the same scheduler.
    pub replan_ms: f64,
    /// Fresh-scheduler cold plan of the *perturbed* demands — the
    /// apples-to-apples baseline the replan's time and plan identity
    /// are checked against.
    pub cold_fresh_ms: f64,
    /// `cold_fresh_ms / replan_ms` (same demand set on both sides).
    pub speedup: f64,
    pub n_groups: usize,
    pub groups_reused: usize,
    pub merge_classes: usize,
    pub classes_remerged: usize,
    pub dp_warm_hits: u64,
    pub grid_points_cold: u64,
    pub grid_points_replan: u64,
    pub total_share: u32,
    pub gpus: usize,
    /// Grouping time of the fresh cold plan (scratch greedy at this n).
    pub group_cold_ms: f64,
    /// Grouping time of the warm replan (delta-aware path).
    pub group_replan_ms: f64,
    /// Groups the warm replan replayed byte-identically.
    pub groups_replayed: usize,
    /// Fragments the warm replan pushed through the greedy.
    pub fragments_regrouped: usize,
    /// Replanned plan covers every input client exactly once.
    pub covers: bool,
    /// Every replanned set meets its tightest member budget.
    pub slo_safe: bool,
    /// Replan share / fresh-cold share (quality vs the scratch
    /// pipeline; 1.0 means no share was given up for incrementality).
    pub share_ratio: f64,
    /// First `save_replan_context` after the warm replan (dirty state:
    /// full atomic rewrite).
    pub ctx_save_ms: f64,
    /// Immediate re-save with nothing changed — the dirty flag must
    /// skip the rewrite, so this is the fixed-cost floor of a
    /// steady-state replan loop's persistence step.
    pub ctx_resave_ms: f64,
    /// The re-save was skipped (dirty flag clean).  Self-checked by
    /// `graft bench-scheduler`.
    pub ctx_resave_skipped: bool,
}

/// Move `pct`% of the clients' partition points and budgets — the
/// trigger-based re-planning steady state (`pct` clamps to 1..=100).
/// Split points rotate through every valid value `0..layers` (and a
/// 1-layer model degenerates to a budget-only trigger instead of a
/// division by zero).
pub fn perturb_fragments(
    cm: &CostModel,
    specs: &mut [FragmentSpec],
    pct: usize,
) {
    let step = (100 / pct.clamp(1, 100)).max(1);
    for i in (0..specs.len()).step_by(step) {
        let s = &mut specs[i];
        let layers = cm.config().models[s.model].layers;
        s.p = (s.p + 1) % layers.max(1);
        s.budget_ms += 1.0;
    }
}

/// Cold-plan a mixed fleet of `n` clients, perturb `pct`% of them,
/// re-plan incrementally on the same scheduler and compare against a
/// fresh cold plan of the perturbed demands: replan time (and grouping
/// time specifically) must beat the cold pipeline, and the replanned
/// plan must match its quality (coverage, SLO safety, share ratio) —
/// exact plan identity is no longer promised now that grouping reuse is
/// heuristic.
pub fn replan_scenario(n: usize, pct: usize, seed: u64) -> ReplanPoint {
    use crate::util::bench::time_ms;
    let cfg = Config::embedded();
    let cm = CostModel::new(cfg.clone());
    let sched = Scheduler::new(cm.clone(), SchedulerOptions::default());
    let mut specs = random_mixed_fragments(&cm, n, seed);

    let (cold_ms, (_, cold_stats)) = time_ms(|| sched.plan(&specs));
    perturb_fragments(&cm, &mut specs, pct);
    let (replan_ms, (replan_plan, replan_stats)) =
        time_ms(|| sched.plan(&specs));
    // quality reference: a fresh scheduler, cold, on the same demands
    let fresh = Scheduler::new(
        CostModel::new(cfg),
        SchedulerOptions::default(),
    );
    let (cold_fresh_ms, (fresh_plan, fresh_stats)) =
        time_ms(|| fresh.plan(&specs));

    // persistence cost of the replan loop: one dirty save (full atomic
    // rewrite), then an immediate re-save that the dirty flag must skip
    let ctx_path = std::env::temp_dir().join(format!(
        "graft_bench_replan_ctx_{}_{n}_{pct}.json",
        std::process::id()
    ));
    let (ctx_save_ms, _) =
        time_ms(|| sched.save_replan_context(&ctx_path).unwrap_or(false));
    let (ctx_resave_ms, wrote_again) =
        time_ms(|| sched.save_replan_context(&ctx_path).unwrap_or(true));
    std::fs::remove_file(&ctx_path).ok();

    ReplanPoint {
        n_clients: n,
        perturb_pct: pct,
        cold_ms,
        replan_ms,
        cold_fresh_ms,
        speedup: cold_fresh_ms / replan_ms.max(1e-9),
        n_groups: replan_stats.n_groups,
        groups_reused: replan_stats.n_groups_reused,
        merge_classes: replan_stats.merge_classes,
        classes_remerged: replan_stats.classes_remerged,
        dp_warm_hits: replan_stats.dp_warm_hits,
        grid_points_cold: cold_stats.grid_points_evaluated,
        grid_points_replan: replan_stats.grid_points_evaluated,
        total_share: replan_plan.total_share(),
        gpus: replan_stats.gpus,
        group_cold_ms: fresh_stats.group_ms,
        group_replan_ms: replan_stats.group_ms,
        groups_replayed: replan_stats.groups_replayed,
        fragments_regrouped: replan_stats.fragments_regrouped,
        covers: plan_covers_demand(&replan_plan),
        slo_safe: plan_is_slo_safe(&replan_plan),
        share_ratio: replan_plan.total_share() as f64
            / (fresh_plan.total_share() as f64).max(1e-9),
        ctx_save_ms,
        ctx_resave_ms,
        ctx_resave_skipped: !wrote_again,
    }
}

/// One measured sharded-planning run (`graft bench-scheduler`'s
/// "sharded" scenario): the same cold mixed-fleet demand planned twice
/// on fresh schedulers — sequential (`planner_threads = 1`, the oracle)
/// vs parallel — with the byte-identity contract checked directly.
#[derive(Debug, Clone)]
pub struct ShardedPlanPoint {
    pub n_clients: usize,
    /// `planner_threads` of the parallel run.
    pub threads: usize,
    /// Cold plan wall time at `planner_threads = 1`.
    pub seq_ms: f64,
    /// Cold plan wall time at `planner_threads = threads`.
    pub par_ms: f64,
    /// `seq_ms / par_ms` (< 1.0 on a single-core box: shard workers
    /// only add coordination there).
    pub speedup: f64,
    /// Shards the parallel run planned (one per model with demand).
    pub planner_shards: usize,
    /// Slowest shard's wall time in the parallel run, ms.
    pub shard_max_ms: f64,
    /// Max/mean shard wall time in the parallel run.
    pub shard_imbalance: f64,
    /// The parallel plan equals the sequential plan byte-for-byte —
    /// the determinism contract, self-checked at every n.
    pub identical: bool,
    pub total_share: u32,
    pub gpus: usize,
}

/// Plan `n` mixed clients cold, sequentially and with `threads` planner
/// shards, and compare.  Fresh schedulers on both sides so neither lane
/// warms the other's caches.
pub fn sharded_plan_scenario(
    n: usize,
    threads: usize,
    seed: u64,
) -> ShardedPlanPoint {
    use crate::util::bench::time_ms;
    let cm = CostModel::new(Config::embedded());
    let specs = random_mixed_fragments(&cm, n, seed);
    let mk = |t: usize| {
        Scheduler::new(
            cm.clone(),
            SchedulerOptions { planner_threads: t, ..Default::default() },
        )
    };
    let (seq_ms, (seq_plan, _)) = time_ms(|| mk(1).plan(&specs));
    let (par_ms, (par_plan, par_stats)) =
        time_ms(|| mk(threads).plan(&specs));
    ShardedPlanPoint {
        n_clients: n,
        threads,
        seq_ms,
        par_ms,
        speedup: seq_ms / par_ms.max(1e-9),
        planner_shards: par_stats.planner_shards,
        shard_max_ms: par_stats.shard_max_ms,
        shard_imbalance: par_stats.shard_imbalance,
        identical: par_plan == seq_plan,
        total_share: par_plan.total_share(),
        gpus: par_stats.gpus,
    }
}

/// Experiment "replan": small-fleet incremental-replanning table (the
/// 1k–10k sweep lives in `graft bench-scheduler`'s replan scenario).
pub fn replan_scale(_cm: &CostModel) -> Table {
    let mut t = Table::new(vec![
        "n_clients",
        "perturb_pct",
        "cold_ms",
        "replan_ms",
        "speedup",
        "groups_reused",
        "n_groups",
        "classes_remerged",
        "merge_classes",
        "dp_warm_hits",
        "groups_replayed",
        "fragments_regrouped",
        "share_ratio",
    ]);
    for &n in &[256usize, 1024] {
        for &pct in &[1usize, 5, 20] {
            let r = replan_scenario(n, pct, 0x9EB1A + n as u64);
            t.row(vec![
                n.to_string(),
                pct.to_string(),
                f(r.cold_ms, 2),
                f(r.replan_ms, 2),
                f(r.speedup, 2),
                r.groups_reused.to_string(),
                r.n_groups.to_string(),
                r.classes_remerged.to_string(),
                r.merge_classes.to_string(),
                r.dp_warm_hits.to_string(),
                r.groups_replayed.to_string(),
                r.fragments_regrouped.to_string(),
                f(r.share_ratio, 3),
            ]);
        }
    }
    t
}

/// One measured live-reconfiguration run (`graft bench-transition` and
/// experiment "transition"): serve a planned fleet with the pooled
/// executor, perturb `pct`% of the clients' demand rates, re-plan
/// incrementally, delta-place against the deployed plan and hot-swap
/// under live traffic.
#[derive(Debug, Clone)]
pub struct TransitionPoint {
    pub n_clients: usize,
    pub perturb_pct: usize,
    /// Requests submitted across the swap.
    pub requests: usize,
    /// Responses collected (must equal `requests`: zero-drop swap).
    pub responses: usize,
    /// SLO/error drops across old + new cores (must be 0 here).
    pub dropped: u64,
    /// Closed-queue rejections across old + new cores (must be 0: the
    /// ordered drain never loses an in-flight item).
    pub rejected: u64,
    /// End-to-end reconfigure latency and its phases.
    pub swap_ms: f64,
    pub prepare_ms: f64,
    pub switch_ms: f64,
    pub drain_ms: f64,
    /// Diff summary of the applied transition.
    pub kept_instances: usize,
    pub restarted_instances: usize,
    /// Delta placement vs the full-repack oracle.
    pub migrated_delta: usize,
    pub migrated_repack: usize,
    pub gpus_delta: usize,
    pub gpus_repack: usize,
    pub fell_back: bool,
    pub plan_changed: bool,
}

/// Scale `pct`% of the clients' demand rates by 1.5× (plus a budget
/// nudge) — the live-reconfiguration trigger.  Partition points stay
/// put so in-flight payload dimensions remain valid across the swap.
pub fn perturb_rates(specs: &mut [FragmentSpec], pct: usize) {
    let step = (100 / pct.clamp(1, 100)).max(1);
    for i in (0..specs.len()).step_by(step) {
        specs[i].rate_rps *= 1.5;
        specs[i].budget_ms += 1.0;
    }
}

/// Plan → serve → perturb → incremental replan → delta-place →
/// hot-swap under load, measuring the whole transition.
pub fn transition_scenario(
    n: usize,
    pct: usize,
    total_reqs: usize,
    seed: u64,
) -> TransitionPoint {
    transition_scenario_with_faults(n, pct, total_reqs, seed, None)
}

/// [`transition_scenario`] under an optional [`FaultPlan`] (same
/// domains as [`serve_synthetic_with_faults`]): chaos during a live
/// hot-swap, reproducible per seed.
pub fn transition_scenario_with_faults(
    n: usize,
    pct: usize,
    total_reqs: usize,
    seed: u64,
    faults: Option<Arc<FaultPlan>>,
) -> TransitionPoint {
    use crate::coordinator::placement::{place_delta, stamp};
    use crate::runtime::transition::{diff_plans, LiveServer};
    use std::sync::atomic::AtomicUsize;

    let cm = CostModel::new(Config::embedded());
    let sched = Scheduler::new(cm.clone(), SchedulerOptions::default());
    let mut specs = random_mixed_fragments(&cm, n, seed);
    let (plan_a, _) = sched.plan(&specs);
    perturb_rates(&mut specs, pct);
    let (mut plan_b, _) = sched.plan(&specs);
    let pre_diff = diff_plans(&plan_a, &plan_b);
    let plan_changed = pre_diff.updated_sets
        + pre_diff.added_sets
        + pre_diff.removed_sets
        > 0;
    let delta = place_delta(&cm, &plan_a, &plan_b, None, &[])
        .expect("scheduler-placed plans stay placeable");
    stamp(&mut plan_b, &delta.placement);

    let dims: HashMap<String, Vec<usize>> = cm
        .config()
        .models
        .iter()
        .map(|m| (m.name.clone(), m.dims.clone()))
        .collect();
    let mock: Arc<dyn FragmentExecutor> = Arc::new(MockExecutor { dims });
    let executor: Arc<dyn FragmentExecutor> = match &faults {
        Some(fp) => Arc::new(FaultyExecutor::new(mock, fp.clone())),
        None => mock,
    };
    let live = LiveServer::start(
        executor,
        &cm,
        &plan_a,
        ServerOptions {
            time_scale: 0.0,
            drop_on_slo: false,
            mode: ExecutorMode::Pool,
            ..Default::default()
        },
    );
    // routed clients (identical in both plans: the perturbation moves
    // rates/budgets, never clients or partition points)
    let mut targets: Vec<(u32, u16, u16, usize)> = Vec::new();
    for set in &plan_a.sets {
        for m in &set.members {
            let dim = cm.config().models[set.model].dims[m.spec.p];
            for c in &m.spec.clients {
                targets.push((c.0, set.model as u16, m.spec.p as u16, dim));
            }
        }
    }
    let mut point = TransitionPoint {
        n_clients: n,
        perturb_pct: pct,
        requests: 0,
        responses: 0,
        dropped: 0,
        rejected: 0,
        swap_ms: 0.0,
        prepare_ms: 0.0,
        switch_ms: 0.0,
        drain_ms: 0.0,
        kept_instances: 0,
        restarted_instances: 0,
        migrated_delta: delta.migrated,
        migrated_repack: delta.repack_migrated,
        gpus_delta: delta.gpus_used,
        gpus_repack: delta.repack_gpus,
        fell_back: delta.fell_back,
        plan_changed,
    };
    if targets.is_empty() || total_reqs == 0 {
        live.shutdown();
        return point;
    }

    let producers = 2usize.min(total_reqs).max(1);
    let submitted = Arc::new(AtomicUsize::new(0));
    let (tx, rx) = mpsc::channel::<Response>();
    let report = std::thread::scope(|scope| {
        let collector = scope.spawn(move || {
            let mut got = 0usize;
            let mut dropped_resp = 0usize;
            while got < total_reqs {
                match rx.recv_timeout(Duration::from_secs(30)) {
                    Ok(r) => {
                        got += 1;
                        if r.dropped {
                            dropped_resp += 1;
                        }
                    }
                    Err(_) => break,
                }
            }
            (got, dropped_resp)
        });
        let mut prods = Vec::new();
        for pidx in 0..producers {
            let tx = tx.clone();
            let live = &live;
            let targets = &targets;
            let submitted = submitted.clone();
            let faults = faults.clone();
            prods.push(scope.spawn(move || {
                let mut i = pidx;
                while i < total_reqs {
                    if let Some(fp) = &faults {
                        apply_control_faults(&live.server(), fp);
                    }
                    let (cid, model, p, dim) = targets[i % targets.len()];
                    crate::serving::RequestSink::submit(
                        live,
                        Request {
                            client_id: cid,
                            model,
                            p,
                            seq: i as u32,
                            t_capture_ms: 0.0,
                            upstream_ms: 0.0,
                            budget_ms: 1e9,
                            payload: vec![0.5; dim],
                        },
                        tx.clone(),
                    );
                    submitted.fetch_add(1, Ordering::Relaxed);
                    i += producers;
                }
            }));
        }
        drop(tx);
        // swap once the load is truly live (a third of the way in), so
        // both cores serve real traffic during the transition
        let swap_at = (total_reqs / 3).max(1);
        while submitted.load(Ordering::Relaxed) < swap_at {
            std::thread::sleep(Duration::from_micros(100));
        }
        let report = live.reconfigure(&plan_b);
        for p in prods {
            p.join().expect("producer");
        }
        let (got, dropped_resp) = collector.join().expect("collector");
        point.requests = total_reqs;
        point.responses = got;
        point.dropped += dropped_resp as u64;
        report
    });
    let totals = live.totals();
    // the two views count the same events (every server-side drop also
    // sends a dropped response), so take the max instead of summing —
    // it still catches a drop notice the counters missed
    point.dropped = point.dropped.max(totals.dropped);
    point.rejected = totals.rejected;
    point.swap_ms = report.total_ms;
    point.prepare_ms = report.prepare_ms;
    point.switch_ms = report.switch_ms;
    point.drain_ms = report.drain_ms;
    point.kept_instances = report.transition.kept_instances;
    point.restarted_instances = report.transition.restarted_instances;
    live.shutdown();
    point
}

/// Experiment "transition": small-fleet live-reconfiguration table
/// (the 1k+ sweep lives in `graft bench-transition`).
pub fn transition_scale(_cm: &CostModel) -> Table {
    let mut t = Table::new(vec![
        "n_clients",
        "perturb_pct",
        "responses",
        "dropped",
        "rejected",
        "swap_ms",
        "kept_instances",
        "migrated_delta",
        "migrated_repack",
        "gpus_delta",
        "gpus_repack",
    ]);
    for &n in &[64usize, 256] {
        for &pct in &[5usize, 20] {
            let r = transition_scenario(n, pct, 2000, 0x7A51 + n as u64);
            t.row(vec![
                n.to_string(),
                pct.to_string(),
                format!("{}/{}", r.responses, r.requests),
                r.dropped.to_string(),
                r.rejected.to_string(),
                f(r.swap_ms, 2),
                r.kept_instances.to_string(),
                r.migrated_delta.to_string(),
                r.migrated_repack.to_string(),
                r.gpus_delta.to_string(),
                r.gpus_repack.to_string(),
            ]);
        }
    }
    t
}

/// One measured failure-recovery run ([`fault_scenario`]).
#[derive(Debug, Clone)]
pub struct FaultBenchPoint {
    pub n_clients: usize,
    /// Requests submitted across the failure and recovery.
    pub requests: usize,
    /// Responses collected — must equal `requests`: every request gets
    /// exactly one response (a result or an explicit drop notice), even
    /// the ones in flight on the failed GPU.
    pub responses: usize,
    /// Requests already submitted when the GPU failed.
    pub pre_fault_submitted: usize,
    /// The injected failure.
    pub failed_gpu: u32,
    /// Instances the failure took down.
    pub killed_instances: usize,
    /// Drop notices across old + new cores (degradation losses — all
    /// visible to clients, never silent).
    pub dropped: u64,
    /// Closed-queue rejections across cores (every one also produced a
    /// drop notice).
    pub rejected: u64,
    /// Drop notices issued between the failure and the completed
    /// emergency swap — the degraded-window SLO violations.
    pub degraded_drops: u64,
    /// Failure detection → emergency replan → hot-swap complete (ms).
    pub recovery_ms: f64,
    pub swap_ms: f64,
    pub drain_ms: f64,
    /// The controller saw the failure and emergency-replanned.
    pub emergency_fired: bool,
    /// Instances the emergency plan stamped onto the failed GPU — must
    /// be 0 (the replan routes around dead hardware).
    pub new_plan_on_failed_gpu: usize,
}

/// Plan → serve → **fail a GPU under load** → detect → emergency
/// replan (failed GPU excluded from placement) → hot-swap, measuring
/// recovery time and request accounting.  The failed GPU is picked
/// deterministically (seeded) from the deployed plan's stamps, so the
/// fault always hits live instances.
pub fn fault_scenario(
    n: usize,
    total_reqs: usize,
    seed: u64,
) -> FaultBenchPoint {
    use crate::coordinator::controller::{
        ControllerOptions, ReplanController, TickOutcome,
    };
    use crate::runtime::transition::LiveServer;
    use crate::util::rng::Rng;
    use std::sync::atomic::AtomicUsize;

    let cm = CostModel::new(Config::embedded());
    let sched =
        Arc::new(Scheduler::new(cm.clone(), SchedulerOptions::default()));
    let specs = random_mixed_fragments(&cm, n, seed);
    let (plan_a, _) = sched.plan(&specs);

    // pick the victim among the GPUs actually hosting instances
    let mut stamped: Vec<u32> =
        plan_a.stages().flat_map(|s| s.gpus.iter().copied()).collect();
    stamped.sort_unstable();
    stamped.dedup();
    let mut rng = Rng::seed_from_u64(seed ^ 0xFA17);
    let failed_gpu = if stamped.is_empty() {
        u32::MAX // unplaced plan: kills everything (degenerate)
    } else {
        stamped[rng.below(stamped.len())]
    };

    let dims: HashMap<String, Vec<usize>> = cm
        .config()
        .models
        .iter()
        .map(|m| (m.name.clone(), m.dims.clone()))
        .collect();
    let live = Arc::new(LiveServer::start(
        Arc::new(MockExecutor { dims }),
        &cm,
        &plan_a,
        ServerOptions {
            time_scale: 0.0,
            drop_on_slo: false,
            mode: ExecutorMode::Pool,
            ..Default::default()
        },
    ));
    let controller = ReplanController::new(
        sched.clone(),
        live.clone(),
        specs.clone(),
        ControllerOptions::default(),
    );

    let mut targets: Vec<(u32, u16, u16, usize)> = Vec::new();
    for set in &plan_a.sets {
        for m in &set.members {
            let dim = cm.config().models[set.model].dims[m.spec.p];
            for c in &m.spec.clients {
                targets.push((c.0, set.model as u16, m.spec.p as u16, dim));
            }
        }
    }
    let mut point = FaultBenchPoint {
        n_clients: n,
        requests: 0,
        responses: 0,
        pre_fault_submitted: 0,
        failed_gpu,
        killed_instances: 0,
        dropped: 0,
        rejected: 0,
        degraded_drops: 0,
        recovery_ms: 0.0,
        swap_ms: 0.0,
        drain_ms: 0.0,
        emergency_fired: false,
        new_plan_on_failed_gpu: 0,
    };
    if targets.is_empty() || total_reqs == 0 {
        return point;
    }

    let producers = 2usize.min(total_reqs).max(1);
    let submitted = Arc::new(AtomicUsize::new(0));
    let (tx, rx) = mpsc::channel::<Response>();
    std::thread::scope(|scope| {
        let collector = scope.spawn(move || {
            let mut got = 0usize;
            let mut dropped_resp = 0usize;
            while got < total_reqs {
                match rx.recv_timeout(Duration::from_secs(30)) {
                    Ok(r) => {
                        got += 1;
                        if r.dropped {
                            dropped_resp += 1;
                        }
                    }
                    Err(_) => break, // lost responses: report the gap
                }
            }
            (got, dropped_resp)
        });
        let mut prods = Vec::new();
        for pidx in 0..producers {
            let tx = tx.clone();
            let live = &live;
            let targets = &targets;
            let submitted = submitted.clone();
            prods.push(scope.spawn(move || {
                let mut i = pidx;
                while i < total_reqs {
                    let (cid, model, p, dim) = targets[i % targets.len()];
                    crate::serving::RequestSink::submit(
                        live.as_ref(),
                        Request {
                            client_id: cid,
                            model,
                            p,
                            seq: i as u32,
                            t_capture_ms: 0.0,
                            upstream_ms: 0.0,
                            budget_ms: 1e9,
                            payload: vec![0.5; dim],
                        },
                        tx.clone(),
                    );
                    submitted.fetch_add(1, Ordering::Relaxed);
                    i += producers;
                }
            }));
        }
        drop(tx);
        // fail the GPU once the load is truly live
        let fail_at = (total_reqs / 3).max(1);
        while submitted.load(Ordering::Relaxed) < fail_at {
            std::thread::sleep(Duration::from_micros(100));
        }
        let drops_before = live.totals().dropped;
        point.pre_fault_submitted = submitted.load(Ordering::Relaxed);
        let t_fail = Instant::now();
        point.killed_instances = live.server().fail_gpu(failed_gpu);
        // detection + emergency replan + hot-swap (one controller tick)
        match controller.tick() {
            TickOutcome::EmergencyReplanned { report, .. } => {
                point.emergency_fired = true;
                point.swap_ms = report.total_ms;
                point.drain_ms = report.drain_ms;
            }
            _ => point.emergency_fired = false,
        }
        point.recovery_ms = t_fail.elapsed().as_secs_f64() * 1e3;
        point.degraded_drops =
            live.totals().dropped.saturating_sub(drops_before);
        for p in prods {
            p.join().expect("producer");
        }
        let (got, dropped_resp) = collector.join().expect("collector");
        point.requests = total_reqs;
        point.responses = got;
        point.dropped = dropped_resp as u64;
    });
    let totals = live.totals();
    // the two views count the same losses (every server-side drop also
    // sent a dropped response); take the max, don't double-count
    point.dropped = point.dropped.max(totals.dropped);
    point.rejected = totals.rejected;
    // the emergency plan must have routed around the failed GPU
    let new_plan = live.plan();
    point.new_plan_on_failed_gpu = new_plan
        .stages()
        .map(|s| s.gpus.iter().filter(|&&g| g == failed_gpu).count())
        .sum();
    drop(controller); // releases its Arc so the unwrap below succeeds
    match Arc::try_unwrap(live) {
        Ok(l) => l.shutdown(),
        Err(l) => {
            l.server().drain();
        }
    }
    point
}

/// One leg of the predictive-vs-reactive failure comparison
/// ([`fault_compare_scenario`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct FaultLegStats {
    /// Requests submitted (steady load + degraded-window burst).
    pub requests: usize,
    /// Responses collected — must equal `requests` (no silent loss).
    pub responses: usize,
    /// Drop notices issued between the GPU death and the completed
    /// emergency swap (the degraded-window damage being compared).
    pub degraded_window_drops: u64,
    /// Instances the GPU death killed.  The predictive leg must have
    /// vacated the victim by then, so this must be 0 there.
    pub killed_at_death: usize,
    pub emergency_fired: bool,
    /// The controller proactively migrated off the suspect GPU before
    /// the failure (predictive leg only).
    pub proactive_fired: bool,
    /// Instances the proactive migration moved off the victim.
    pub migrated_before_death: usize,
    /// Instances the final plan stamped onto the dead GPU — must be 0.
    pub new_plan_on_failed_gpu: usize,
    /// Total drop notices across the whole leg.
    pub dropped: u64,
    pub rejected: u64,
}

/// Reactive-vs-predictive failure handling on the same seeded story:
/// same plan, same victim GPU, same load, same death tick — the only
/// difference is whether health warnings feed a suspect threshold that
/// migrates off the victim *before* it dies.
#[derive(Debug, Clone, Copy)]
pub struct FaultComparePoint {
    pub n_clients: usize,
    pub victim_gpu: u32,
    /// Degraded-window probe size (requests aimed at the victim's own
    /// clients right after the death).
    pub burst: usize,
    pub reactive: FaultLegStats,
    pub predictive: FaultLegStats,
}

impl FaultComparePoint {
    /// The predictive leg must strictly beat the reactive one: fewer
    /// degraded-window drops, zero instances killed at death (the
    /// victim was already vacated), no silent loss in either leg, and
    /// neither final plan lands on the dead GPU.
    pub fn predictive_ok(&self) -> bool {
        let r = &self.reactive;
        let p = &self.predictive;
        r.emergency_fired
            && r.killed_at_death > 0
            && p.proactive_fired
            && p.migrated_before_death > 0
            && p.killed_at_death == 0
            && p.degraded_window_drops < r.degraded_window_drops
            && r.responses == r.requests
            && p.responses == p.requests
            && r.new_plan_on_failed_gpu == 0
            && p.new_plan_on_failed_gpu == 0
    }
}

/// One leg: plan → serve → (predictive only: warn the victim, tick →
/// proactive migration) → kill the victim GPU → burst at the victim's
/// clients → emergency tick, with full response accounting.
fn fault_compare_leg(
    n: usize,
    total_reqs: usize,
    seed: u64,
    burst: usize,
    predictive: bool,
) -> (u32, FaultLegStats) {
    use crate::coordinator::controller::{
        ControllerOptions, ReplanController, TickOutcome,
    };
    use crate::runtime::transition::LiveServer;
    use crate::util::rng::Rng;
    use std::sync::atomic::AtomicUsize;

    let cm = CostModel::new(Config::embedded());
    let sched =
        Arc::new(Scheduler::new(cm.clone(), SchedulerOptions::default()));
    let specs = random_mixed_fragments(&cm, n, seed);
    let (plan_a, _) = sched.plan(&specs);

    // victim: a GPU hosting a member's *entry* stage whose instances
    // all live on that one GPU, with real clients — so the
    // post-death burst deterministically hits dead queues in the
    // reactive leg.  Both legs derive the identical candidate list
    // from the identical (deterministic) plan, so the seeded pick
    // agrees across legs.
    let mut candidates: Vec<(u32, Vec<(u32, u16, u16, usize)>)> = Vec::new();
    for set in &plan_a.sets {
        for m in &set.members {
            if m.spec.clients.is_empty() {
                continue;
            }
            let entry = m.align.as_ref().unwrap_or(&set.shared);
            let Some(&g0) = entry.gpus.first() else {
                continue;
            };
            if entry.gpus.iter().any(|&g| g != g0) {
                continue;
            }
            let dim = cm.config().models[set.model].dims[m.spec.p];
            let burst_targets: Vec<(u32, u16, u16, usize)> = m
                .spec
                .clients
                .iter()
                .map(|c| (c.0, set.model as u16, m.spec.p as u16, dim))
                .collect();
            candidates.push((g0, burst_targets));
        }
    }
    let mut stats = FaultLegStats::default();
    if candidates.is_empty() || total_reqs == 0 {
        return (u32::MAX, stats);
    }
    let mut rng = Rng::seed_from_u64(seed ^ 0x9E1F);
    let (victim, burst_targets) =
        candidates.swap_remove(rng.below(candidates.len()));

    let dims: HashMap<String, Vec<usize>> = cm
        .config()
        .models
        .iter()
        .map(|m| (m.name.clone(), m.dims.clone()))
        .collect();
    let live = Arc::new(LiveServer::start(
        Arc::new(MockExecutor { dims }),
        &cm,
        &plan_a,
        ServerOptions {
            time_scale: 0.0,
            drop_on_slo: false,
            mode: ExecutorMode::Pool,
            ..Default::default()
        },
    ));
    let controller = ReplanController::new(
        sched.clone(),
        live.clone(),
        specs.clone(),
        ControllerOptions {
            // isolate the failure path: drift replans can never fire
            drift_threshold: 1e12,
            min_requests: u64::MAX,
            suspect_threshold: if predictive { Some(0.6) } else { None },
            ..Default::default()
        },
    );

    let mut targets: Vec<(u32, u16, u16, usize)> = Vec::new();
    for set in &plan_a.sets {
        for m in &set.members {
            let dim = cm.config().models[set.model].dims[m.spec.p];
            for c in &m.spec.clients {
                targets.push((c.0, set.model as u16, m.spec.p as u16, dim));
            }
        }
    }
    if targets.is_empty() {
        return (victim, stats);
    }

    let expected = total_reqs + burst;
    let producers = 2usize.min(total_reqs).max(1);
    let submitted = Arc::new(AtomicUsize::new(0));
    let (tx, rx) = mpsc::channel::<Response>();
    std::thread::scope(|scope| {
        let collector = scope.spawn(move || {
            let mut got = 0usize;
            let mut dropped_resp = 0u64;
            while got < expected {
                match rx.recv_timeout(Duration::from_secs(30)) {
                    Ok(r) => {
                        got += 1;
                        if r.dropped {
                            dropped_resp += 1;
                        }
                    }
                    Err(_) => break, // lost responses: report the gap
                }
            }
            (got, dropped_resp)
        });
        let mut prods = Vec::new();
        for pidx in 0..producers {
            let tx = tx.clone();
            let live = &live;
            let targets = &targets;
            let submitted = submitted.clone();
            prods.push(scope.spawn(move || {
                let mut i = pidx;
                while i < total_reqs {
                    let (cid, model, p, dim) = targets[i % targets.len()];
                    crate::serving::RequestSink::submit(
                        live.as_ref(),
                        Request {
                            client_id: cid,
                            model,
                            p,
                            seq: i as u32,
                            t_capture_ms: 0.0,
                            upstream_ms: 0.0,
                            budget_ms: 1e9,
                            payload: vec![0.5; dim],
                        },
                        tx.clone(),
                    );
                    submitted.fetch_add(1, Ordering::Relaxed);
                    i += producers;
                }
            }));
        }

        // early-warning window: the predictive leg raises health
        // warnings against the victim, then one tick migrates off it
        let warn_at = (total_reqs / 6).max(1);
        while submitted.load(Ordering::Relaxed) < warn_at {
            std::thread::sleep(Duration::from_micros(100));
        }
        if predictive {
            // warnings decay as healthy beats flow, so at mock speed
            // the warn → tick gap alone can decay the score back under
            // the threshold; retry the warn+tick pair until the suspect
            // tick lands (guaranteed once the steady load drains, since
            // idle instances stop beating)
            for _ in 0..500 {
                for _ in 0..3 {
                    live.server().warn_gpu(victim);
                }
                if let TickOutcome::ProactiveMigration {
                    migrated_instances, ..
                } = controller.tick()
                {
                    stats.proactive_fired = true;
                    stats.migrated_before_death = migrated_instances;
                    break;
                }
                std::thread::sleep(Duration::from_millis(1));
            }
        } else {
            let _ = controller.tick();
        }

        // the failure proper
        let fail_at = (total_reqs / 3).max(2);
        while submitted.load(Ordering::Relaxed) < fail_at {
            std::thread::sleep(Duration::from_micros(100));
        }
        let drops_before = live.totals().dropped;
        stats.killed_at_death = live.server().fail_gpu(victim);
        // degraded-window probe: a burst at the victim's own clients
        // lands on dead queues in the reactive leg (visible drop
        // notices) and on relocated instances in the predictive one
        for (j, &(cid, model, p, dim)) in
            burst_targets.iter().cycle().take(burst).enumerate()
        {
            crate::serving::RequestSink::submit(
                live.as_ref(),
                Request {
                    client_id: cid,
                    model,
                    p,
                    seq: (total_reqs + j) as u32,
                    t_capture_ms: 0.0,
                    upstream_ms: 0.0,
                    budget_ms: 1e9,
                    payload: vec![0.5; dim],
                },
                tx.clone(),
            );
        }
        drop(tx);
        if let TickOutcome::EmergencyReplanned { .. } = controller.tick() {
            stats.emergency_fired = true;
        }
        stats.degraded_window_drops =
            live.totals().dropped.saturating_sub(drops_before);
        for pr in prods {
            pr.join().expect("producer");
        }
        let (got, dropped_resp) = collector.join().expect("collector");
        stats.requests = expected;
        stats.responses = got;
        stats.dropped = dropped_resp;
    });
    let totals = live.totals();
    stats.dropped = stats.dropped.max(totals.dropped);
    stats.rejected = totals.rejected;
    let new_plan = live.plan();
    stats.new_plan_on_failed_gpu = new_plan
        .stages()
        .map(|s| s.gpus.iter().filter(|&&g| g == victim).count())
        .sum();
    drop(controller); // releases its Arc so the unwrap below succeeds
    match Arc::try_unwrap(live) {
        Ok(l) => l.shutdown(),
        Err(l) => {
            l.server().drain();
        }
    }
    (victim, stats)
}

/// Run the reactive (suspect scoring disabled) and predictive legs of
/// the same seeded failure story and compare the degraded-window
/// damage.  [`FaultComparePoint::predictive_ok`] is the self-check.
pub fn fault_compare_scenario(
    n: usize,
    total_reqs: usize,
    seed: u64,
) -> FaultComparePoint {
    let burst = 32usize;
    let (victim, reactive) =
        fault_compare_leg(n, total_reqs, seed, burst, false);
    let (_, predictive) = fault_compare_leg(n, total_reqs, seed, burst, true);
    FaultComparePoint {
        n_clients: n,
        victim_gpu: victim,
        burst,
        reactive,
        predictive,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cm() -> CostModel {
        CostModel::new(Config::embedded())
    }

    #[test]
    fn fig17_graft_sustains_more_throughput() {
        let cm = cm();
        let t = fig17(&cm);
        for name in ["inc", "vgg"] {
            let get = |sys: &str| -> f64 {
                t.rows
                    .iter()
                    .find(|r| r[0] == name && r[1] == sys)
                    .unwrap()[2]
                    .parse()
                    .unwrap()
            };
            assert!(
                get("graft") >= get("gslice"),
                "{name}: graft {} < gslice {}",
                get("graft"),
                get("gslice")
            );
        }
    }

    #[test]
    fn serving_harness_completes_under_both_modes() {
        let cm = cm();
        let specs = random_mixed_fragments(&cm, 16, 7);
        let sched = Scheduler::new(cm.clone(), SchedulerOptions::default());
        let (plan, _) = sched.plan(&specs);
        if plan.sets.is_empty() {
            return; // degenerate random draw: nothing to serve
        }
        let cpus = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4);
        for mode in [ExecutorMode::Threads, ExecutorMode::Pool] {
            let r = serve_synthetic(&cm, &plan, mode, 400);
            assert_eq!(r.requests, 400, "{mode:?} lost responses");
            assert_eq!(r.served, 400, "{mode:?} served counter");
            assert_eq!(r.dropped, 0, "{mode:?} dropped counter");
            assert!(r.throughput_rps > 0.0);
            if mode == ExecutorMode::Pool {
                assert!(
                    r.threads <= cpus.max(1),
                    "pool spawned {} workers for {} cpus",
                    r.threads,
                    cpus
                );
            }
        }
    }

    #[test]
    fn placement_table_integrated_never_beats_oracle_downward() {
        let cm = cm();
        let t = placement_scale(&cm);
        for &n in &[64usize, 256] {
            let col = |sys: &str, c: usize| -> Option<usize> {
                t.rows
                    .iter()
                    .find(|r| r[0] == n.to_string() && r[1] == sys)
                    .unwrap()[c]
                    .parse()
                    .ok()
            };
            let graft = col("graft", 4).expect("integrated always places");
            assert!(graft >= col("graft", 3).unwrap(), "n={n}");
            // integrated placement ≤ post-hoc FFD of the same demand
            // ("nan" = the oracle could not pack; integrated wins then)
            if let Some(oracle) = col("graft-posthoc", 4) {
                assert!(
                    graft <= oracle,
                    "n={n}: integrated {graft} > oracle {oracle}"
                );
            }
        }
    }

    #[test]
    fn replan_scenario_reuses_and_keeps_quality() {
        let r = replan_scenario(48, 20, 7);
        // the replanned plan is a valid plan of cold-pipeline quality
        // (grouping reuse is heuristic, so byte-identity is no longer
        // the contract — coverage, SLO safety and share are)
        assert!(r.covers, "replanned plan lost clients");
        assert!(r.slo_safe, "replanned plan violates budgets");
        assert!(
            r.share_ratio <= 1.2,
            "replanned share {} too far above fresh cold",
            r.share_ratio
        );
        assert!(r.groups_reused <= r.n_groups);
        assert!(r.classes_remerged <= r.merge_classes);
        assert!(r.cold_ms > 0.0 && r.replan_ms > 0.0);
        // 20% of 48 clients moved: something must actually be dirty …
        assert!(r.classes_remerged > 0);
        assert!(r.fragments_regrouped > 0, "perturbation must regroup");
        // … and something must replay (same-model clean classes exist)
        assert!(r.merge_classes > r.classes_remerged);
        // the dirty flag must skip the no-op re-save
        assert!(r.ctx_resave_skipped, "clean re-save was not skipped");
    }

    #[test]
    fn sharded_scenario_is_identical_and_counts_shards() {
        let r = sharded_plan_scenario(64, 4, 13);
        assert!(r.identical, "parallel plan diverged from sequential");
        assert!(r.planner_shards >= 2, "mixed fleet must shard");
        assert!(r.shard_imbalance >= 1.0 - 1e-9);
        // a shard runs inside the parallel plan call
        assert!(r.shard_max_ms <= r.par_ms);
        assert!(r.seq_ms > 0.0 && r.par_ms > 0.0);
    }

    #[test]
    fn transition_scenario_zero_drop_and_delta_bounds() {
        let r = transition_scenario(24, 20, 600, 11);
        assert_eq!(r.responses, r.requests, "live swap lost responses");
        assert_eq!(r.dropped, 0);
        assert_eq!(r.rejected, 0);
        assert!(r.migrated_delta <= r.migrated_repack);
        assert!(r.gpus_delta <= r.gpus_repack);
        if r.plan_changed {
            assert!(r.restarted_instances > 0);
        }
        assert!(r.swap_ms >= r.drain_ms);
    }

    #[test]
    fn perturb_rates_touches_the_requested_share() {
        let base = random_mixed_fragments(&cm(), 100, 5);
        let mut p = base.clone();
        perturb_rates(&mut p, 10);
        let changed =
            base.iter().zip(&p).filter(|(a, b)| a != b).count();
        assert_eq!(changed, 10);
        // partition points never move (in-flight payloads stay valid)
        assert!(base.iter().zip(&p).all(|(a, b)| a.p == b.p));
    }

    #[test]
    fn perturb_touches_the_requested_share() {
        let cm = cm();
        let base = random_mixed_fragments(&cm, 100, 3);
        let mut p1 = base.clone();
        perturb_fragments(&cm, &mut p1, 1);
        let changed = |a: &[FragmentSpec], b: &[FragmentSpec]| {
            a.iter().zip(b).filter(|(x, y)| x != y).count()
        };
        assert_eq!(changed(&base, &p1), 1);
        let mut p20 = base.clone();
        perturb_fragments(&cm, &mut p20, 20);
        assert_eq!(changed(&base, &p20), 20);
    }

    #[test]
    fn fig21_graft_beats_unmerged_baselines() {
        let cm = cm();
        let t = fig21(&cm);
        assert!(!t.rows.is_empty());
        let get = |scale_pfx: &str, model: &str, sys: &str| -> f64 {
            t.rows
                .iter()
                .find(|r| r[0].starts_with(scale_pfx) && r[1] == model && r[2] == sys)
                .map(|r| r[3].parse().unwrap())
                .unwrap_or(f64::NAN)
        };
        let g = get("small", "inc", "graft");
        let s = get("small", "inc", "gslice");
        assert!(g <= s * 1.05, "graft {g} vs gslice {s}");
    }
}
