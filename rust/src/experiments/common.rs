//! Shared experiment machinery: the paper's client fleets, spec
//! snapshots, and the five systems under comparison.

use crate::coordinator::baselines::{
    gslice, gslice_plus, static_alloc, static_plus, StaticClient,
};
use crate::coordinator::optimal::{optimal_plan_multi, MAX_OPTIMAL_N};
use crate::coordinator::plan::ExecutionPlan;
use crate::coordinator::repartition::RepartitionOptions;
use crate::coordinator::scheduler::{Scheduler, SchedulerOptions};
use crate::coordinator::{ClientId, FragmentSpec};
use crate::hybrid::{BandwidthTrace, ClientSim, DeviceKind, TraceParams};
use crate::profiler::{AllocConstraints, CostModel};

/// The paper's experiment scales (§5.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    /// 4 Jetson Nanos.
    SmallHomo,
    /// 4 Nanos + 2 TX2s.
    SmallHeter,
    /// 20 emulated clients (Nano profile).
    LargeHomo,
    /// 15 Nanos + 5 TX2s.
    LargeHeter,
}

impl Scale {
    pub fn devices(&self) -> Vec<DeviceKind> {
        let (nanos, tx2s) = match self {
            Scale::SmallHomo => (4, 0),
            Scale::SmallHeter => (4, 2),
            Scale::LargeHomo => (20, 0),
            Scale::LargeHeter => (15, 5),
        };
        let mut v = vec![DeviceKind::Nano; nanos];
        v.extend(vec![DeviceKind::Tx2; tx2s]);
        v
    }

    pub fn id(&self) -> &'static str {
        match self {
            Scale::SmallHomo => "small-homo",
            Scale::SmallHeter => "small-heter",
            Scale::LargeHomo => "large-homo",
            Scale::LargeHeter => "large-heter",
        }
    }
}

/// A fleet of simulated clients for one model at one scale.
pub fn fleet(
    _cm: &CostModel,
    model: usize,
    scale: Scale,
    slo_ratio: f64,
    seed: u64,
) -> Vec<ClientSim> {
    scale
        .devices()
        .into_iter()
        .enumerate()
        .map(|(i, device)| {
            ClientSim::new(
                ClientId(i as u32),
                model,
                device,
                BandwidthTrace::generate(
                    seed.wrapping_add(i as u64 * 7919),
                    &TraceParams::default(),
                ),
                slo_ratio,
            )
        })
        .collect()
}

/// Snapshot every client's fragment demand at time `t_s` (clients whose
/// partitioning is infeasible at that instant contribute nothing).
pub fn snapshot(
    cm: &CostModel,
    clients: &[ClientSim],
    t_s: f64,
) -> Vec<FragmentSpec> {
    clients
        .iter()
        .filter_map(|c| c.state_at(cm, t_s).spec)
        .collect()
}

/// Static-baseline inputs for a fleet.
pub fn static_clients(
    cm: &CostModel,
    clients: &[ClientSim],
) -> Vec<StaticClient> {
    clients
        .iter()
        .map(|c| StaticClient {
            spec_seed: FragmentSpec::single(
                c.id,
                c.model,
                0,
                0.0,
                cm.config().models[c.model].rate_rps,
            ),
            device: c.device,
            trace: c.trace.clone(),
            slo_ratio: c.slo_ratio,
        })
        .collect()
}

/// Which systems to evaluate.
#[derive(Debug, Clone, Copy)]
pub struct SystemSet {
    pub optimal: bool,
}

/// Total GPU share of every system on a snapshot (the Fig 7 comparison).
/// Returns (system name, total share) rows.
pub fn compare_systems(
    cm: &CostModel,
    specs: &[FragmentSpec],
    statics: &[StaticClient],
    cons: AllocConstraints,
    systems: SystemSet,
) -> Vec<(&'static str, u32)> {
    let mut rows = Vec::new();

    let sched = Scheduler::new(
        cm.clone(),
        SchedulerOptions {
            repartition: RepartitionOptions {
                constraints: cons,
                ..Default::default()
            },
            merge: crate::coordinator::merging::MergeOptions {
                constraints: cons,
                ..Default::default()
            },
            ..Default::default()
        },
    );
    let (graft, _) = sched.plan(specs);
    rows.push(("graft", graft.total_share()));
    rows.push(("gslice", gslice(cm, specs, &cons).total_share()));
    rows.push(("gslice+", gslice_plus(cm, specs, &cons).total_share()));
    rows.push(("static", static_alloc(cm, statics, &cons, None).total_share()));
    rows.push(("static+", static_plus(cm, statics, &cons, None).total_share()));
    if systems.optimal && specs.len() <= MAX_OPTIMAL_N {
        let opt = optimal_plan_multi(
            cm,
            specs,
            5,
            &RepartitionOptions { constraints: cons, ..Default::default() },
        );
        rows.push(("optimal", opt.total_share()));
    }
    rows
}

/// Graft plan helper with constraints.
pub fn graft_plan(
    cm: &CostModel,
    specs: &[FragmentSpec],
    cons: AllocConstraints,
) -> ExecutionPlan {
    let sched = Scheduler::new(
        cm.clone(),
        SchedulerOptions {
            repartition: RepartitionOptions {
                constraints: cons,
                ..Default::default()
            },
            merge: crate::coordinator::merging::MergeOptions {
                constraints: cons,
                ..Default::default()
            },
            ..Default::default()
        },
    );
    sched.plan(specs).0
}

/// Mean over repetitions of a per-snapshot measurement.
pub fn mean_over_reps<F>(reps: usize, mut f: F) -> f64
where
    F: FnMut(usize) -> f64,
{
    let vals: Vec<f64> =
        (0..reps).map(|r| f(r)).filter(|v| v.is_finite()).collect();
    if vals.is_empty() {
        return f64::NAN;
    }
    vals.iter().sum::<f64>() / vals.len() as f64
}

/// Synthetic random fragments for one model (Figs 11, 13–16, 18, 19):
/// each replays a random bandwidth from the trace distribution, like the
/// paper's random-fragment experiments.
pub fn random_fragments(
    cm: &CostModel,
    model: usize,
    n: usize,
    seed: u64,
) -> Vec<FragmentSpec> {
    use crate::hybrid::choose_partition;
    use crate::util::Rng;
    let mut rng = Rng::seed_from_u64(seed);
    let m = &cm.config().models[model];
    let mut out = Vec::with_capacity(n);
    let mut id = 0u32;
    while out.len() < n {
        let device = if rng.f64() < 0.7 {
            DeviceKind::Nano
        } else {
            DeviceKind::Tx2
        };
        let bw = rng.range(
            TraceParams::default().min_mbps,
            TraceParams::default().max_mbps,
        );
        let slo = device.slo_ms(m, cm.config().slo_ratio_default);
        if let Some(p) =
            choose_partition(cm, model, device, bw, slo, None).partition()
        {
            out.push(FragmentSpec::single(
                ClientId(id),
                model,
                p.p,
                p.server_budget_ms,
                m.rate_rps,
            ));
            id += 1;
        }
    }
    out
}

/// A mixed-model demand set: `n` clients spread evenly over all models,
/// with globally unique client ids (used by the scheduler benchmarks).
pub fn random_mixed_fragments(
    cm: &CostModel,
    n: usize,
    seed: u64,
) -> Vec<FragmentSpec> {
    let n_models = cm.config().models.len();
    let mut out = Vec::with_capacity(n);
    for mi in 0..n_models {
        let share = n / n_models + usize::from(mi < n % n_models);
        if share == 0 {
            continue;
        }
        let mut frags = random_fragments(cm, mi, share, seed + mi as u64);
        // client ids unique across models
        for f in &mut frags {
            for c in &mut f.clients {
                c.0 += (mi * n) as u32;
            }
        }
        out.append(&mut frags);
    }
    out
}

pub const MODELS: [&str; 5] = ["inc", "res", "vgg", "mob", "vit"];

pub fn model_idx(cm: &CostModel, name: &str) -> usize {
    cm.model_index(name).expect("known model")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    fn cm() -> CostModel {
        CostModel::new(Config::embedded())
    }

    #[test]
    fn scales_have_right_sizes() {
        assert_eq!(Scale::SmallHomo.devices().len(), 4);
        assert_eq!(Scale::SmallHeter.devices().len(), 6);
        assert_eq!(Scale::LargeHomo.devices().len(), 20);
        assert_eq!(Scale::LargeHeter.devices().len(), 20);
    }

    #[test]
    fn snapshot_produces_specs() {
        let cm = cm();
        let f = fleet(&cm, model_idx(&cm, "inc"), Scale::SmallHomo, 0.95, 1);
        let s = snapshot(&cm, &f, 5.0);
        assert!(!s.is_empty());
        assert!(s.iter().all(|x| x.budget_ms > 0.0));
    }

    #[test]
    fn compare_systems_orders_sanely() {
        let cm = cm();
        let f = fleet(&cm, model_idx(&cm, "inc"), Scale::SmallHomo, 0.95, 2);
        let specs = snapshot(&cm, &f, 3.0);
        let st = static_clients(&cm, &f);
        let rows = compare_systems(
            &cm,
            &specs,
            &st,
            AllocConstraints::default(),
            SystemSet { optimal: true },
        );
        let get = |n: &str| {
            rows.iter().find(|(s, _)| *s == n).map(|(_, v)| *v).unwrap()
        };
        assert!(get("graft") <= get("gslice+"));
        assert!(get("gslice+") <= get("gslice"));
        assert!(get("optimal") <= get("graft"));
    }

    #[test]
    fn random_fragments_are_valid() {
        let cm = cm();
        let fr = random_fragments(&cm, model_idx(&cm, "vgg"), 20, 7);
        assert_eq!(fr.len(), 20);
        assert!(fr.iter().all(|f| f.budget_ms > 0.0 && f.rate_rps > 0.0));
    }
}
