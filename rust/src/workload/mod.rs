//! Workload generation: request arrival processes per client.
//!
//! The paper's clients issue frames at a fixed rate (30 RPS; ViT 1 RPS).
//! Cameras are near-periodic; we support periodic-with-jitter (default)
//! and Poisson arrivals for stress tests.

use crate::util::Rng;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    /// Fixed inter-arrival 1/rate with ±`jitter` relative uniform noise.
    Periodic { jitter: f64 },
    /// Exponential inter-arrivals (memoryless).
    Poisson,
}

/// Generate arrival timestamps (seconds) over `[0, horizon_s)`.
pub fn arrivals(
    rate_rps: f64,
    horizon_s: f64,
    process: ArrivalProcess,
    seed: u64,
) -> Vec<f64> {
    assert!(rate_rps > 0.0 && horizon_s > 0.0);
    let mut rng = Rng::seed_from_u64(seed);
    let mean_gap = 1.0 / rate_rps;
    let mut t = match process {
        // desynchronise clients: random phase
        ArrivalProcess::Periodic { .. } => rng.f64() * mean_gap,
        ArrivalProcess::Poisson => 0.0,
    };
    let mut out = Vec::with_capacity((rate_rps * horizon_s) as usize + 4);
    while t < horizon_s {
        if t >= 0.0 {
            out.push(t);
        }
        let gap = match process {
            ArrivalProcess::Periodic { jitter } => {
                mean_gap * (1.0 + jitter * (rng.f64() * 2.0 - 1.0))
            }
            ArrivalProcess::Poisson => rng.exponential(mean_gap),
        };
        t += gap.max(1e-9);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn periodic_rate_is_respected() {
        let a = arrivals(30.0, 10.0, ArrivalProcess::Periodic { jitter: 0.05 }, 1);
        let rate = a.len() as f64 / 10.0;
        assert!((rate - 30.0).abs() < 2.0, "rate {rate}");
        assert!(a.windows(2).all(|w| w[1] > w[0]));
    }

    #[test]
    fn poisson_rate_approximates() {
        let a = arrivals(100.0, 50.0, ArrivalProcess::Poisson, 2);
        let rate = a.len() as f64 / 50.0;
        assert!((rate - 100.0).abs() < 10.0, "rate {rate}");
    }

    #[test]
    fn deterministic_by_seed() {
        let p = ArrivalProcess::Poisson;
        assert_eq!(arrivals(10.0, 5.0, p, 7), arrivals(10.0, 5.0, p, 7));
        assert_ne!(arrivals(10.0, 5.0, p, 7), arrivals(10.0, 5.0, p, 8));
    }

    #[test]
    fn all_within_horizon() {
        let a = arrivals(30.0, 3.0, ArrivalProcess::Periodic { jitter: 0.1 }, 3);
        assert!(a.iter().all(|&t| (0.0..3.0).contains(&t)));
    }
}
