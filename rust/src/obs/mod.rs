//! Observability: per-request tracing, the unified metrics registry,
//! streaming histograms, the SLO-budget attribution report, and the
//! `/metrics` HTTP endpoint.
//!
//! The pieces compose bottom-up:
//!
//! * [`now_us`] — one process-wide monotonic microsecond clock; every
//!   span timestamp and queue metric reads it, so timelines from
//!   different threads are directly comparable.
//! * [`Histogram`] — log-bucketed streaming histogram (bounded memory,
//!   ≤1% relative error vs the exact `metrics::LatencyStats` oracle)
//!   used on the hot serving paths.
//! * [`Trace`]/[`ServerObs`] — deterministic sampled per-request span
//!   logs feeding per-model component histograms.
//! * [`MetricsRegistry`] — the single namespace every subsystem
//!   (serving, queues, health, scheduler, controller) registers
//!   collectors into; snapshots render as JSON, Prometheus text, or
//!   the one-line serve heartbeat.
//! * [`BudgetAttribution`] — observed component latencies joined with
//!   the planner's §4.3 envelope per model.
//! * [`MetricsServer`] — std-only HTTP endpoint serving registry
//!   snapshots (`graft serve --metrics-addr`, `graft obs-report`).

pub mod hist;
pub mod http;
pub mod registry;
pub mod report;
pub mod trace;

pub use hist::{HistBucket, Histogram, HistogramSnapshot};
pub use http::{scrape, MetricsServer};
pub use registry::{
    counter_sum, counter_value, gauge_value, prometheus_text, render_stats_line,
    snapshot_json, Metric, MetricValue, MetricsRegistry,
};
pub use report::{BudgetAttribution, ComponentStat, ModelAttribution};
pub use trace::{ModelLatencyObs, ServerObs, Span, SpanKind, Trace, TraceOptions};

use std::sync::OnceLock;
use std::time::Instant;

/// Monotonic microseconds since the first call in this process.  One
/// shared epoch for every subsystem so span timestamps, queue metrics
/// and histograms sit on a single comparable timeline.
pub fn now_us() -> u64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed().as_micros() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn now_us_is_monotonic() {
        let a = now_us();
        let b = now_us();
        assert!(b >= a);
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(now_us() > a);
    }
}
