//! Log-bucketed streaming histogram for hot serving paths.
//!
//! [`Histogram`] keeps a fixed array of geometrically-spaced buckets
//! (growth factor [`GAMMA`]) covering `[1e-3, 1e7]` milliseconds, so a
//! recorded value lands in the bucket whose bounds bracket it and a
//! percentile query returns the bucket's geometric midpoint — within
//! `√GAMMA − 1 < 1%` of the exact nearest-rank sample for any value in
//! the covered range.  Memory is bounded (one `u64` per bucket, ~1.6k
//! buckets) no matter how many samples stream through, and recording is
//! a single atomic increment — unlike [`crate::metrics::LatencyStats`],
//! which keeps every sample exactly and is the oracle the property
//! tests compare against.

use std::sync::atomic::{AtomicU64, Ordering};

/// Bucket growth factor.  The representative of a bucket is its
/// geometric midpoint, so the worst-case relative error of a percentile
/// is `√GAMMA − 1 ≈ 0.75%` — inside the documented 1% bound.
const GAMMA: f64 = 1.015;
/// Smallest value resolved by its own bucket (1 µs in ms units);
/// anything below lands in the underflow bucket and reports the exact
/// observed minimum.
const MIN_MS: f64 = 1e-3;
/// Largest value resolved by its own bucket (~2.8 h in ms); anything
/// above lands in the overflow bucket and reports the exact maximum.
const MAX_MS: f64 = 1e7;

fn n_interior() -> usize {
    ((MAX_MS / MIN_MS).ln() / GAMMA.ln()).ceil() as usize
}

/// Streaming log-bucketed histogram (values in milliseconds).  All
/// methods take `&self`: recording is lock-free atomic increments, so
/// a histogram can sit on the hot serving path behind an `Arc`.
#[derive(Debug)]
pub struct Histogram {
    /// `counts[0]` = underflow, `counts[1..=n]` = interior buckets
    /// (bucket `i` covers `[MIN·Γ^(i−1), MIN·Γ^i)`), `counts[n+1]` =
    /// overflow.
    counts: Vec<AtomicU64>,
    count: AtomicU64,
    /// Sum in nanoseconds-as-integer (ms × 1e3 → µs precision) so the
    /// mean needs no float CAS loop.
    sum_us: AtomicU64,
    min_bits: AtomicU64,
    max_bits: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        let n = n_interior();
        Histogram {
            counts: (0..n + 2).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            min_bits: AtomicU64::new(f64::INFINITY.to_bits()),
            max_bits: AtomicU64::new(0f64.to_bits()),
        }
    }

    fn index(v: f64) -> usize {
        let n = n_interior();
        if v < MIN_MS {
            return 0;
        }
        if v >= MAX_MS {
            return n + 1;
        }
        // floor(log_Γ(v / MIN)) puts v in the interior bucket whose
        // bounds bracket it; float rounding can misplace a value sitting
        // exactly on a boundary by one bucket, which moves the
        // representative by at most Γ^±0.5 — still within the bound
        let i = ((v / MIN_MS).ln() / GAMMA.ln()).floor() as usize;
        (i + 1).min(n)
    }

    /// Lower/upper bound of interior bucket `i` (1-indexed).
    fn bounds(i: usize) -> (f64, f64) {
        let lo = MIN_MS * GAMMA.powi(i as i32 - 1);
        (lo, lo * GAMMA)
    }

    /// Record one value (negative values clamp to 0 → underflow).
    pub fn record(&self, ms: f64) {
        if !ms.is_finite() {
            return;
        }
        let ms = ms.max(0.0);
        self.counts[Self::index(ms)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add((ms * 1e3) as u64, Ordering::Relaxed);
        self.min_bits.fetch_min(ms.to_bits(), Ordering::Relaxed);
        self.max_bits.fetch_max(ms.to_bits(), Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn is_empty(&self) -> bool {
        self.count() == 0
    }

    pub fn sum_ms(&self) -> f64 {
        self.sum_us.load(Ordering::Relaxed) as f64 / 1e3
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return f64::NAN;
        }
        self.sum_ms() / n as f64
    }

    pub fn min(&self) -> f64 {
        if self.is_empty() {
            return f64::NAN;
        }
        f64::from_bits(self.min_bits.load(Ordering::Relaxed))
    }

    pub fn max(&self) -> f64 {
        if self.is_empty() {
            return f64::NAN;
        }
        f64::from_bits(self.max_bits.load(Ordering::Relaxed))
    }

    /// Nearest-rank percentile (same definition as
    /// [`crate::metrics::LatencyStats::percentile`]): walk the buckets
    /// to the one holding the `⌈p/100·n⌉`-th smallest sample and return
    /// its geometric midpoint, clamped to the observed `[min, max]`.
    /// `p = 0` returns the exact minimum; `p = 100` the exact maximum.
    pub fn percentile(&self, p: f64) -> f64 {
        self.snapshot().percentile(p)
    }

    /// Merge another histogram's counts into this one.
    pub fn merge(&self, other: &Histogram) {
        for (a, b) in self.counts.iter().zip(other.counts.iter()) {
            a.fetch_add(b.load(Ordering::Relaxed), Ordering::Relaxed);
        }
        self.count.fetch_add(other.count(), Ordering::Relaxed);
        self.sum_us
            .fetch_add(other.sum_us.load(Ordering::Relaxed), Ordering::Relaxed);
        self.min_bits
            .fetch_min(other.min_bits.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max_bits
            .fetch_max(other.max_bits.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Consistent point-in-time copy for rendering / percentile math
    /// (only non-empty buckets are materialised).
    pub fn snapshot(&self) -> HistogramSnapshot {
        let n = n_interior();
        let mut buckets = Vec::new();
        for (i, c) in self.counts.iter().enumerate() {
            let c = c.load(Ordering::Relaxed);
            if c == 0 {
                continue;
            }
            let (upper, rep) = if i == 0 {
                (MIN_MS, self.min())
            } else if i == n + 1 {
                (f64::INFINITY, self.max())
            } else {
                let (lo, hi) = Self::bounds(i);
                (hi, (lo * hi).sqrt())
            };
            buckets.push(HistBucket { upper, rep, count: c });
        }
        HistogramSnapshot {
            buckets,
            count: self.count(),
            sum_ms: self.sum_ms(),
            min: self.min(),
            max: self.max(),
        }
    }
}

/// One non-empty bucket of a snapshot: exclusive upper bound, the
/// representative value reported for samples in it, and its count.
#[derive(Debug, Clone, Copy)]
pub struct HistBucket {
    pub upper: f64,
    pub rep: f64,
    pub count: u64,
}

/// Point-in-time view of a [`Histogram`] (see
/// [`Histogram::snapshot`]); what the metrics registry serialises.
#[derive(Debug, Clone, Default)]
pub struct HistogramSnapshot {
    pub buckets: Vec<HistBucket>,
    pub count: u64,
    pub sum_ms: f64,
    pub min: f64,
    pub max: f64,
}

impl HistogramSnapshot {
    /// Nearest-rank percentile over the bucketed counts (see
    /// [`Histogram::percentile`]).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        if p <= 0.0 {
            return self.min;
        }
        let rank = ((p / 100.0) * self.count as f64).ceil() as u64;
        let rank = rank.clamp(1, self.count);
        let mut seen = 0u64;
        for b in &self.buckets {
            seen += b.count;
            if seen >= rank {
                return b.rep.clamp(self.min, self.max);
            }
        }
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        self.sum_ms / self.count as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram_is_nan() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert!(h.percentile(50.0).is_nan());
        assert!(h.mean().is_nan());
    }

    #[test]
    fn single_value_is_exact_at_extremes() {
        let h = Histogram::new();
        h.record(42.0);
        assert_eq!(h.min(), 42.0);
        assert_eq!(h.max(), 42.0);
        assert_eq!(h.percentile(0.0), 42.0);
        assert_eq!(h.percentile(100.0), 42.0);
        let p50 = h.percentile(50.0);
        assert!((p50 - 42.0).abs() / 42.0 <= 0.01, "{p50}");
    }

    #[test]
    fn percentiles_track_exact_within_one_percent() {
        let h = Histogram::new();
        let mut exact = crate::metrics::LatencyStats::new();
        for i in 1..=10_000u64 {
            // log-spread values across 5 decades
            let v = 0.05 * 1.001f64.powi(i as i32 % 4000) * (1 + i % 7) as f64;
            h.record(v);
            exact.record(v);
        }
        for p in [1.0, 25.0, 50.0, 90.0, 95.0, 99.0, 99.9] {
            let a = h.percentile(p);
            let b = exact.percentile(p);
            assert!(
                (a - b).abs() / b <= 0.01,
                "p{p}: approx {a} vs exact {b}"
            );
        }
        assert_eq!(h.count(), 10_000);
        assert!((h.mean() - exact.mean()).abs() / exact.mean() <= 0.01);
    }

    #[test]
    fn out_of_range_values_report_observed_extremes() {
        let h = Histogram::new();
        h.record(1e-9);
        h.record(5e8);
        assert_eq!(h.percentile(0.0), 1e-9);
        assert_eq!(h.percentile(100.0), 5e8);
        // p50 of two samples = the smaller (nearest-rank lower middle)
        assert_eq!(h.percentile(50.0), 1e-9);
    }

    #[test]
    fn merge_combines_counts() {
        let a = Histogram::new();
        let b = Histogram::new();
        a.record(1.0);
        b.record(100.0);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.min(), 1.0);
        assert_eq!(a.max(), 100.0);
    }

    #[test]
    fn snapshot_buckets_are_cumulative_consistent() {
        let h = Histogram::new();
        for i in 0..1000 {
            h.record(0.5 + i as f64);
        }
        let s = h.snapshot();
        let total: u64 = s.buckets.iter().map(|b| b.count).sum();
        assert_eq!(total, s.count);
        assert!(s.buckets.windows(2).all(|w| w[0].upper < w[1].upper));
    }
}
