//! Minimal HTTP/1.1 metrics endpoint (std-only, no HTTP crate).
//!
//! [`MetricsServer`] runs the same nonblocking accept loop shape as the
//! serving TCP front (`serving::tcp::TcpFront`): a listener polled with
//! a stop flag, one short-lived handler per connection.  It serves two
//! routes off a shared [`MetricsRegistry`]:
//!
//! * `GET /metrics` — Prometheus text exposition
//! * `GET /metrics.json` — JSON snapshot (same data, same names)
//!
//! Requests are one-shot (`Connection: close`); a scrape is a fresh
//! snapshot, so the endpoint always reflects live counters.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::obs::registry::{prometheus_text, snapshot_json, MetricsRegistry};

/// A running metrics endpoint; dropping it without [`stop`] leaves the
/// accept thread running until process exit.
///
/// [`stop`]: MetricsServer::stop
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `127.0.0.1:9464`, port 0 for ephemeral) and
    /// serve the registry until [`stop`](Self::stop).
    pub fn start(addr: &str, registry: Arc<MetricsRegistry>) -> Result<MetricsServer> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding metrics endpoint {addr}"))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::Builder::new()
            .name("metrics-http".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((conn, _)) => {
                            let _ = handle_conn(conn, &registry);
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(MetricsServer { addr: local, stop, handle: Some(handle) })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn handle_conn(mut conn: TcpStream, registry: &MetricsRegistry) -> Result<()> {
    conn.set_read_timeout(Some(Duration::from_secs(5)))?;
    conn.set_write_timeout(Some(Duration::from_secs(5)))?;
    // read until end of headers (requests are tiny GETs)
    let mut buf = Vec::new();
    let mut chunk = [0u8; 1024];
    loop {
        let n = conn.read(&mut chunk)?;
        if n == 0 {
            break;
        }
        buf.extend_from_slice(&chunk[..n]);
        if buf.windows(4).any(|w| w == b"\r\n\r\n") || buf.len() > 16 << 10 {
            break;
        }
    }
    let head = String::from_utf8_lossy(&buf);
    let path = head
        .lines()
        .next()
        .and_then(|l| l.split_whitespace().nth(1))
        .unwrap_or("/");
    let (status, ctype, body) = match path {
        "/metrics" => (
            "200 OK",
            "text/plain; version=0.0.4",
            prometheus_text(&registry.snapshot()),
        ),
        "/metrics.json" => (
            "200 OK",
            "application/json",
            snapshot_json(&registry.snapshot()).to_string(),
        ),
        _ => ("404 Not Found", "text/plain", "not found\n".to_string()),
    };
    let resp = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    conn.write_all(resp.as_bytes())?;
    Ok(())
}

/// One-shot HTTP GET against a metrics endpoint; returns the body.
/// Used by `graft obs-report --addr` and the CI smoke's fallback path.
pub fn scrape(addr: &str, path: &str) -> Result<String> {
    let mut conn = TcpStream::connect(addr)
        .with_context(|| format!("connecting to metrics endpoint {addr}"))?;
    conn.set_read_timeout(Some(Duration::from_secs(5)))?;
    conn.set_write_timeout(Some(Duration::from_secs(5)))?;
    write!(conn, "GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n")?;
    let mut resp = String::new();
    conn.read_to_string(&mut resp)?;
    let Some((head, body)) = resp.split_once("\r\n\r\n") else {
        bail!("malformed HTTP response from {addr}");
    };
    let status = head.lines().next().unwrap_or("");
    if !status.contains("200") {
        bail!("metrics endpoint returned {status:?}");
    }
    Ok(body.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::registry::Metric;

    #[test]
    fn serves_prometheus_and_json() {
        let reg = Arc::new(MetricsRegistry::new());
        reg.register("t", |out| {
            out.push(Metric::counter("graft_test_total", 3));
        });
        let srv = MetricsServer::start("127.0.0.1:0", reg).unwrap();
        let addr = srv.addr().to_string();
        let text = scrape(&addr, "/metrics").unwrap();
        assert!(text.contains("graft_test_total 3"), "{text}");
        let json = scrape(&addr, "/metrics.json").unwrap();
        let parsed = crate::util::Json::parse(&json).unwrap();
        assert_eq!(parsed.as_arr().unwrap().len(), 1);
        assert!(scrape(&addr, "/nope").is_err());
        srv.stop();
    }
}
