//! SLO-budget attribution: where each model's latency budget actually
//! goes, compared against the planner's §4.3 envelope.
//!
//! The planner sizes every stage so that one batch window (one planned
//! execution time, `Alloc::latency_ms`) of formation wait plus the
//! execution itself fits the member budgets — that is the *envelope*.
//! The tracing pipeline measures where the wall-clock budget was
//! actually spent (queueing, batch formation, execution, pacing,
//! delivery).  [`BudgetAttribution`] joins the two per model: observed
//! p50/p99 per component, the planned envelope on the worst member
//! path, and a flag for the dominant component — the first place to
//! look when a model is burning budget somewhere the planner didn't
//! allocate it.

use std::collections::BTreeMap;

use crate::coordinator::plan::ExecutionPlan;
use crate::obs::trace::ServerObs;
use crate::profiler::CostModel;
use crate::util::Json;

/// Observed latency quantiles for one pipeline component.
#[derive(Debug, Clone)]
pub struct ComponentStat {
    pub name: &'static str,
    pub p50_ms: f64,
    pub p99_ms: f64,
}

/// One model's budget breakdown.
#[derive(Debug, Clone)]
pub struct ModelAttribution {
    pub model: u16,
    pub name: String,
    /// Finished traces behind these numbers.
    pub traced: u64,
    /// Tightest member budget across the model's planned sets (ms).
    pub budget_ms: f64,
    /// Planned §4.3 batch-window envelope on the worst member path
    /// (alignment window + shared window, modeled ms).
    pub envelope_queue_ms: f64,
    /// Planned execution on the worst member path (modeled ms).
    pub envelope_exec_ms: f64,
    /// Observed per-component quantiles: queue, form, exec, pace,
    /// deliver (wall-clock ms).
    pub components: Vec<ComponentStat>,
    pub e2e_p50_ms: f64,
    pub e2e_p99_ms: f64,
    /// Component with the largest observed p99 — where the budget goes.
    pub dominant: &'static str,
    /// Observed e2e p99 vs the wall-clock envelope (envelope × the
    /// serving `time_scale`).  `None` when pacing is off
    /// (`time_scale == 0`), where the modeled envelope has no
    /// wall-clock meaning.
    pub within_envelope: Option<bool>,
}

/// The full per-model report.
#[derive(Debug, Clone, Default)]
pub struct BudgetAttribution {
    pub models: Vec<ModelAttribution>,
    /// The trace retention cap was hit; histograms are still complete.
    pub truncated: bool,
}

impl BudgetAttribution {
    /// Join the plan's envelope with the observed trace histograms.
    /// `time_scale` is the serving core's pacing scale (0 = pacing
    /// off), used to convert the modeled envelope to wall-clock for
    /// the `within_envelope` verdict.
    pub fn from_obs(
        cm: &CostModel,
        plan: &ExecutionPlan,
        obs: &ServerObs,
        time_scale: f64,
    ) -> BudgetAttribution {
        // planned worst-path envelope per model index
        let mut env: BTreeMap<usize, (f64, f64, f64)> = BTreeMap::new();
        for set in &plan.sets {
            let e = env.entry(set.model).or_insert((0.0, 0.0, f64::INFINITY));
            let shared = set.shared.alloc.latency_ms;
            // worst member path: the largest alignment stage in front of
            // the shared stage (members without an alignment stage ride
            // the shared envelope alone)
            let worst_align = set
                .members
                .iter()
                .filter_map(|m| m.align.as_ref())
                .map(|a| a.alloc.latency_ms)
                .fold(0.0, f64::max);
            e.0 = e.0.max(worst_align + shared); // queue/form window
            e.1 = e.1.max(worst_align + shared); // execution
            for m in &set.members {
                e.2 = e.2.min(m.spec.budget_ms);
            }
        }

        let names = cm.config().model_names();
        let mut models = Vec::new();
        for (idx, _, lat) in obs.models() {
            let planned = env.get(&(idx as usize));
            if lat.e2e.is_empty() && planned.is_none() {
                continue;
            }
            let (env_q, env_x, budget) =
                planned.copied().unwrap_or((0.0, 0.0, f64::NAN));
            let comps: Vec<ComponentStat> = lat
                .components()
                .into_iter()
                .filter(|(n, _)| *n != "e2e")
                .map(|(n, h)| ComponentStat {
                    name: n,
                    p50_ms: h.percentile(50.0),
                    p99_ms: h.percentile(99.0),
                })
                .collect();
            let dominant = comps
                .iter()
                .filter(|c| c.p99_ms.is_finite())
                .max_by(|a, b| a.p99_ms.total_cmp(&b.p99_ms))
                .map(|c| c.name)
                .unwrap_or("none");
            let e2e_p99 = lat.e2e.percentile(99.0);
            let within_envelope = if time_scale > 0.0 && e2e_p99.is_finite() {
                Some(e2e_p99 <= (env_q + env_x) * time_scale)
            } else {
                None
            };
            models.push(ModelAttribution {
                model: idx,
                name: names
                    .get(idx as usize)
                    .map(|s| s.to_string())
                    .unwrap_or_else(|| format!("model{idx}")),
                traced: lat.e2e.count(),
                budget_ms: budget,
                envelope_queue_ms: env_q,
                envelope_exec_ms: env_x,
                components: comps,
                e2e_p50_ms: lat.e2e.percentile(50.0),
                e2e_p99_ms: e2e_p99,
                dominant,
                within_envelope,
            });
        }
        BudgetAttribution { models, truncated: obs.truncated() }
    }

    pub fn to_json(&self) -> Json {
        let num = |v: f64| if v.is_finite() { Json::Num(v) } else { Json::Null };
        let models = self
            .models
            .iter()
            .map(|m| {
                let mut o = BTreeMap::new();
                o.insert("model".to_string(), Json::Str(m.name.clone()));
                o.insert("traced".to_string(), Json::Num(m.traced as f64));
                o.insert("budget_ms".to_string(), num(m.budget_ms));
                o.insert("envelope_queue_ms".to_string(), num(m.envelope_queue_ms));
                o.insert("envelope_exec_ms".to_string(), num(m.envelope_exec_ms));
                let mut comps = BTreeMap::new();
                for c in &m.components {
                    let mut co = BTreeMap::new();
                    co.insert("p50_ms".to_string(), num(c.p50_ms));
                    co.insert("p99_ms".to_string(), num(c.p99_ms));
                    comps.insert(c.name.to_string(), Json::Obj(co));
                }
                o.insert("components".to_string(), Json::Obj(comps));
                o.insert("e2e_p50_ms".to_string(), num(m.e2e_p50_ms));
                o.insert("e2e_p99_ms".to_string(), num(m.e2e_p99_ms));
                o.insert("dominant".to_string(), Json::Str(m.dominant.into()));
                o.insert(
                    "within_envelope".to_string(),
                    match m.within_envelope {
                        Some(b) => Json::Bool(b),
                        None => Json::Null,
                    },
                );
                Json::Obj(o)
            })
            .collect();
        let mut o = BTreeMap::new();
        o.insert("models".to_string(), Json::Arr(models));
        o.insert("truncated".to_string(), Json::Bool(self.truncated));
        Json::Obj(o)
    }

    /// Human-readable table, one block per model.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        if self.models.is_empty() {
            out.push_str("budget attribution: no traced requests\n");
            return out;
        }
        for m in &self.models {
            out.push_str(&format!(
                "model {} — traced {} | budget {:.1} ms | envelope queue {:.2} ms exec {:.2} ms | e2e p50 {:.3} p99 {:.3} ms{}\n",
                m.name,
                m.traced,
                m.budget_ms,
                m.envelope_queue_ms,
                m.envelope_exec_ms,
                m.e2e_p50_ms,
                m.e2e_p99_ms,
                match m.within_envelope {
                    Some(true) => " | within envelope",
                    Some(false) => " | OVER envelope",
                    None => "",
                },
            ));
            for c in &m.components {
                let mark = if c.name == m.dominant { "  <- dominant" } else { "" };
                out.push_str(&format!(
                    "  {:>8}: p50 {:>9.3} ms  p99 {:>9.3} ms{}\n",
                    c.name, c.p50_ms, c.p99_ms, mark
                ));
            }
        }
        if self.truncated {
            out.push_str("(trace buffer truncated; histograms complete)\n");
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::coordinator::fragment::{ClientId, FragmentSpec};
    use crate::coordinator::plan::{MemberPlan, RealignedSet, StagePlan};
    use crate::obs::trace::{Span, SpanKind, Trace, TraceOptions};
    use crate::obs::now_us;
    use crate::profiler::{Alloc, FragmentId};

    fn cm() -> CostModel {
        CostModel::new(Config::embedded())
    }

    fn stage(model: usize, latency_ms: f64) -> StagePlan {
        StagePlan {
            frag: FragmentId::new(model, 2, 17),
            alloc: Alloc {
                batch: 4,
                share: 20,
                instances: 1,
                latency_ms,
                throughput_rps: 100.0,
            },
            budget_ms: 50.0,
            demand_rps: 60.0,
            gpus: vec![0],
        }
    }

    fn plan(model: usize) -> ExecutionPlan {
        let set = RealignedSet {
            model,
            point: 2,
            members: vec![
                MemberPlan {
                    spec: FragmentSpec::single(ClientId(0), model, 1, 40.0, 30.0),
                    align: Some(stage(model, 3.0)),
                },
                MemberPlan {
                    spec: FragmentSpec::single(ClientId(1), model, 2, 60.0, 30.0),
                    align: None,
                },
            ],
            shared: stage(model, 7.0),
        };
        ExecutionPlan { sets: vec![set], infeasible: vec![] }
    }

    fn traced_obs(model: u16) -> ServerObs {
        let obs = ServerObs::new(
            TraceOptions { sample_every: 1 },
            cm().config().model_names().iter().map(|s| s.to_string()).collect(),
        );
        let base = now_us();
        for i in 0..100u64 {
            let mk = |kind, dt: u64| Span { kind, t_us: base + i * 10_000 + dt };
            obs.record(Trace {
                client_id: 0,
                seq: i as u32,
                model,
                spans: vec![
                    mk(SpanKind::Enqueue, 0),
                    mk(SpanKind::ShardPop, 4_000), // queue dominates: 4 ms
                    mk(SpanKind::BatchForm, 4_500),
                    mk(SpanKind::Execute, 6_500),
                    mk(SpanKind::PaceRelease, 6_600),
                    mk(SpanKind::Deliver, 6_700),
                ],
            });
        }
        obs
    }

    #[test]
    fn envelope_uses_worst_member_path() {
        let att = BudgetAttribution::from_obs(&cm(), &plan(0), &traced_obs(0), 1.0);
        assert_eq!(att.models.len(), 1);
        let m = &att.models[0];
        assert_eq!(m.traced, 100);
        assert!((m.envelope_queue_ms - 10.0).abs() < 1e-9); // 3 + 7
        assert!((m.envelope_exec_ms - 10.0).abs() < 1e-9);
        assert_eq!(m.budget_ms, 40.0); // tightest member
        assert_eq!(m.dominant, "queue");
        // e2e p99 = 6.7 ms <= 20 ms envelope at time_scale 1
        assert_eq!(m.within_envelope, Some(true));
    }

    #[test]
    fn pacing_off_yields_no_envelope_verdict() {
        let att = BudgetAttribution::from_obs(&cm(), &plan(0), &traced_obs(0), 0.0);
        assert_eq!(att.models[0].within_envelope, None);
    }

    #[test]
    fn json_and_text_render() {
        let att = BudgetAttribution::from_obs(&cm(), &plan(0), &traced_obs(0), 1.0);
        let j = att.to_json().to_string();
        let parsed = Json::parse(&j).unwrap();
        let models = parsed.get("models").unwrap().as_arr().unwrap();
        assert_eq!(models.len(), 1);
        assert_eq!(
            models[0].get("dominant").unwrap().as_str().unwrap(),
            "queue"
        );
        assert!(models[0].get("components").unwrap().get("exec").is_ok());
        let text = att.render_text();
        assert!(text.contains("dominant"));
        assert!(text.contains("within envelope"));
    }

    #[test]
    fn untraced_unplanned_models_are_skipped() {
        let obs = ServerObs::new(
            TraceOptions { sample_every: 1 },
            cm().config().model_names().iter().map(|s| s.to_string()).collect(),
        );
        let att = BudgetAttribution::from_obs(&cm(), &plan(1), &obs, 1.0);
        // model 1 is planned (shows up with zero traces); others skipped
        assert_eq!(att.models.len(), 1);
        assert_eq!(att.models[0].traced, 0);
    }
}
