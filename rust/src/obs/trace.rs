//! Per-request tracing: span kinds, sampled traces, and the per-model
//! latency accumulators the serving core records into.
//!
//! A [`Trace`] is attached to a request's server-side context when the
//! deterministic sampler selects it (see [`TraceOptions`]) and follows
//! the request through every hop of the pipeline.  Each instrumentation
//! point appends a [`Span`] — a kind tag plus a monotonic microsecond
//! timestamp from [`crate::obs::now_us`] — so a finished trace is an
//! ordered walk: `Enqueue → ShardPop → BatchForm → Execute →
//! PaceRelease → Deliver`, repeated once per hop for multi-stage
//! (alignment → shared) requests.  Traces are recorded into
//! [`ServerObs`] only when the request is *served*; drop notices and
//! rejections discard the trace so tracing can never change responses.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

use crate::obs::hist::Histogram;
use crate::obs::now_us;
use crate::util::Json;

/// Pipeline stations a request passes through, in order within a hop.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum SpanKind {
    /// Pushed into a stage queue (`Server::submit` or the forward path
    /// of `deliver` for downstream stages).
    Enqueue,
    /// Popped off the shard/batch queue by a worker.
    ShardPop,
    /// Batch formed and SLO-filtered, about to execute.
    BatchForm,
    /// Kernel execution finished.
    Execute,
    /// Released by the pacing gate (deadline wheel park or sleep done).
    PaceRelease,
    /// Handed to the reply channel or forwarded downstream.
    Deliver,
}

impl SpanKind {
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Enqueue => "enqueue",
            SpanKind::ShardPop => "shard_pop",
            SpanKind::BatchForm => "batch_form",
            SpanKind::Execute => "execute",
            SpanKind::PaceRelease => "pace_release",
            SpanKind::Deliver => "deliver",
        }
    }

    /// All kinds, in within-hop order.
    pub const ALL: [SpanKind; 6] = [
        SpanKind::Enqueue,
        SpanKind::ShardPop,
        SpanKind::BatchForm,
        SpanKind::Execute,
        SpanKind::PaceRelease,
        SpanKind::Deliver,
    ];
}

/// One timestamped station visit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Span {
    pub kind: SpanKind,
    /// Monotonic microseconds since process start ([`now_us`]).
    pub t_us: u64,
}

/// A request's span log while it is in flight.
#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    pub client_id: u32,
    pub seq: u32,
    /// Model index (into `Config::models`).
    pub model: u16,
    pub spans: Vec<Span>,
}

impl Trace {
    pub fn new(client_id: u32, seq: u32, model: u16) -> Trace {
        Trace { client_id, seq, model, spans: Vec::with_capacity(12) }
    }

    /// Append a span stamped now.  Timestamps are monotonic by
    /// construction (single monotonic clock, spans appended in event
    /// order by the thread holding the request).
    pub fn stamp(&mut self, kind: SpanKind) {
        self.spans.push(Span { kind, t_us: now_us() });
    }

    /// End-to-end server-side latency (first to last span), ms.
    pub fn e2e_ms(&self) -> f64 {
        match (self.spans.first(), self.spans.last()) {
            (Some(a), Some(b)) => (b.t_us - a.t_us) as f64 / 1e3,
            _ => 0.0,
        }
    }

    /// Per-component durations (ms), summed across hops: time from each
    /// span to its predecessor, attributed to the *later* station —
    /// `ShardPop` time is queueing, `BatchForm` is formation wait,
    /// `Execute` is kernel time, `PaceRelease` is pacing wait,
    /// `Deliver` is handoff.  `Enqueue` opens a hop and absorbs the
    /// inter-hop forward gap on multi-stage paths (reported as queue
    /// time of the next hop's `ShardPop`, since `Deliver`→`Enqueue` is
    /// back-to-back in the forwarding worker).
    pub fn components_ms(&self) -> BTreeMap<SpanKind, f64> {
        let mut out = BTreeMap::new();
        for w in self.spans.windows(2) {
            let dt = (w[1].t_us - w[0].t_us) as f64 / 1e3;
            if w[1].kind != SpanKind::Enqueue {
                *out.entry(w[1].kind).or_insert(0.0) += dt;
            }
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let spans = self
            .spans
            .iter()
            .map(|s| {
                let mut m = BTreeMap::new();
                m.insert("kind".to_string(), Json::Str(s.kind.name().into()));
                m.insert("t_us".to_string(), Json::Num(s.t_us as f64));
                Json::Obj(m)
            })
            .collect();
        let mut m = BTreeMap::new();
        m.insert("client_id".to_string(), Json::Num(self.client_id as f64));
        m.insert("seq".to_string(), Json::Num(self.seq as f64));
        m.insert("model".to_string(), Json::Num(self.model as f64));
        m.insert("spans".to_string(), Json::Arr(spans));
        Json::Obj(m)
    }
}

/// Tracing configuration carried in `ServerOptions`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceOptions {
    /// Trace one request in `sample_every` (deterministic on
    /// `(client_id, seq)` — identical across runs and executor modes).
    /// `0` disables tracing entirely (the default).
    pub sample_every: u32,
}

impl Default for TraceOptions {
    fn default() -> Self {
        TraceOptions { sample_every: 0 }
    }
}

impl TraceOptions {
    pub fn enabled(&self) -> bool {
        self.sample_every > 0
    }

    /// Deterministic sampling decision: a pure function of the request
    /// identity, so the same requests are traced in both executor
    /// modes and across repeated runs.
    pub fn sample(&self, client_id: u32, seq: u32) -> bool {
        self.sample_every > 0
            && (((client_id as u64) << 32) | seq as u64) % self.sample_every as u64
                == 0
    }
}

/// Per-model streaming latency components fed by finished traces.
#[derive(Debug, Default)]
pub struct ModelLatencyObs {
    /// Enqueue → ShardPop (queueing), summed across hops.
    pub queue: Histogram,
    /// ShardPop → BatchForm (batch-formation wait).
    pub form: Histogram,
    /// BatchForm → Execute (kernel time).
    pub exec: Histogram,
    /// Execute → PaceRelease (pacing wait).
    pub pace: Histogram,
    /// PaceRelease → Deliver (handoff).
    pub deliver: Histogram,
    /// First span → last span.
    pub e2e: Histogram,
}

impl ModelLatencyObs {
    pub fn components(&self) -> [(&'static str, &Histogram); 6] {
        [
            ("queue", &self.queue),
            ("form", &self.form),
            ("exec", &self.exec),
            ("pace", &self.pace),
            ("deliver", &self.deliver),
            ("e2e", &self.e2e),
        ]
    }
}

/// Cap on retained finished traces; beyond this, traces still feed the
/// histograms but the span logs are dropped (`truncated` is set).
const TRACE_RETAIN_CAP: usize = 16_384;

/// The serving core's observability sink: per-model latency histograms
/// plus a bounded buffer of finished sampled traces.  Shared by every
/// worker thread of a `Server`; all recording is `&self`.
#[derive(Debug)]
pub struct ServerObs {
    pub opts: TraceOptions,
    model_names: Vec<String>,
    lat: Vec<ModelLatencyObs>,
    traces: Mutex<Vec<Trace>>,
    truncated: AtomicBool,
}

impl ServerObs {
    pub fn new(opts: TraceOptions, model_names: Vec<String>) -> ServerObs {
        let lat = (0..model_names.len()).map(|_| ModelLatencyObs::default()).collect();
        ServerObs {
            opts,
            model_names,
            lat,
            traces: Mutex::new(Vec::new()),
            truncated: AtomicBool::new(false),
        }
    }

    /// Disabled sink (no models, sampling off) — the default when
    /// tracing is not configured; `record` is a no-op.
    pub fn disabled() -> ServerObs {
        ServerObs::new(TraceOptions::default(), Vec::new())
    }

    pub fn model_names(&self) -> &[String] {
        &self.model_names
    }

    pub fn model_name(&self, model: u16) -> &str {
        self.model_names
            .get(model as usize)
            .map(|s| s.as_str())
            .unwrap_or("unknown")
    }

    /// Latency components for one model (None if out of range).
    pub fn model_lat(&self, model: u16) -> Option<&ModelLatencyObs> {
        self.lat.get(model as usize)
    }

    pub fn models(&self) -> impl Iterator<Item = (u16, &str, &ModelLatencyObs)> {
        self.lat
            .iter()
            .enumerate()
            .map(|(i, l)| (i as u16, self.model_names[i].as_str(), l))
    }

    /// Ingest a finished trace from a *served* request: fold its
    /// component durations into the per-model histograms and retain the
    /// span log (up to the cap).
    pub fn record(&self, trace: Trace) {
        let Some(lat) = self.lat.get(trace.model as usize) else {
            return;
        };
        for (kind, ms) in trace.components_ms() {
            match kind {
                SpanKind::ShardPop => lat.queue.record(ms),
                SpanKind::BatchForm => lat.form.record(ms),
                SpanKind::Execute => lat.exec.record(ms),
                SpanKind::PaceRelease => lat.pace.record(ms),
                SpanKind::Deliver => lat.deliver.record(ms),
                SpanKind::Enqueue => {}
            }
        }
        lat.e2e.record(trace.e2e_ms());
        let mut buf = self.traces.lock().unwrap_or_else(|e| e.into_inner());
        if buf.len() < TRACE_RETAIN_CAP {
            buf.push(trace);
        } else {
            self.truncated.store(true, Ordering::Relaxed);
        }
    }

    /// Number of finished traces ingested into the histograms.
    pub fn traced_count(&self) -> u64 {
        self.lat.iter().map(|l| l.e2e.count()).sum()
    }

    /// Snapshot of the retained span logs.
    pub fn traces(&self) -> Vec<Trace> {
        self.traces.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }

    pub fn truncated(&self) -> bool {
        self.truncated.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace_with(offsets: &[(SpanKind, u64)]) -> Trace {
        let base = now_us();
        Trace {
            client_id: 1,
            seq: 0,
            model: 0,
            spans: offsets
                .iter()
                .map(|&(kind, dt)| Span { kind, t_us: base + dt })
                .collect(),
        }
    }

    #[test]
    fn sampling_is_deterministic_and_off_by_default() {
        let off = TraceOptions::default();
        assert!(!off.enabled());
        assert!(!off.sample(0, 0));
        let on = TraceOptions { sample_every: 3 };
        for c in 0..5u32 {
            for s in 0..50u32 {
                assert_eq!(on.sample(c, s), on.sample(c, s));
            }
        }
        // client 0: key == seq, so every 3rd seq is sampled
        assert!(on.sample(0, 0) && on.sample(0, 3) && !on.sample(0, 1));
        let n: usize =
            (0..300u32).filter(|&s| on.sample(0, s)).count();
        assert_eq!(n, 100);
    }

    #[test]
    fn components_attribute_gaps_to_later_station() {
        use SpanKind::*;
        let t = trace_with(&[
            (Enqueue, 0),
            (ShardPop, 1_000),
            (BatchForm, 1_500),
            (Execute, 4_500),
            (PaceRelease, 5_000),
            (Deliver, 5_100),
        ]);
        let c = t.components_ms();
        assert_eq!(c[&ShardPop], 1.0);
        assert_eq!(c[&BatchForm], 0.5);
        assert_eq!(c[&Execute], 3.0);
        assert_eq!(c[&PaceRelease], 0.5);
        assert!((c[&Deliver] - 0.1).abs() < 1e-9);
        assert!((t.e2e_ms() - 5.1).abs() < 1e-9);
    }

    #[test]
    fn two_hop_trace_sums_components_across_hops() {
        use SpanKind::*;
        let t = trace_with(&[
            (Enqueue, 0),
            (ShardPop, 1_000),
            (BatchForm, 1_200),
            (Execute, 2_200),
            (PaceRelease, 2_300),
            (Deliver, 2_400),
            (Enqueue, 2_450),
            (ShardPop, 3_450),
            (BatchForm, 3_650),
            (Execute, 4_650),
            (PaceRelease, 4_750),
            (Deliver, 4_850),
        ]);
        let c = t.components_ms();
        assert_eq!(c[&ShardPop], 2.0); // 1.0 + 1.0, inter-hop gap excluded
        assert!((c[&Execute] - 2.0).abs() < 1e-9);
        assert!((t.e2e_ms() - 4.85).abs() < 1e-9);
    }

    #[test]
    fn server_obs_records_into_model_histograms() {
        use SpanKind::*;
        let obs = ServerObs::new(
            TraceOptions { sample_every: 1 },
            vec!["resnet".into(), "vgg".into()],
        );
        obs.record(trace_with(&[
            (Enqueue, 0),
            (ShardPop, 2_000),
            (BatchForm, 2_100),
            (Execute, 7_100),
            (PaceRelease, 7_200),
            (Deliver, 7_300),
        ]));
        let lat = obs.model_lat(0).unwrap();
        assert_eq!(lat.e2e.count(), 1);
        assert!((lat.queue.max() - 2.0).abs() < 1e-9);
        assert!((lat.exec.max() - 5.0).abs() < 1e-9);
        assert!(obs.model_lat(1).unwrap().e2e.is_empty());
        assert_eq!(obs.traced_count(), 1);
        assert_eq!(obs.traces().len(), 1);
        assert!(!obs.truncated());
    }

    #[test]
    fn out_of_range_model_is_ignored() {
        let obs = ServerObs::new(TraceOptions { sample_every: 1 }, vec!["m".into()]);
        let mut t = trace_with(&[(SpanKind::Enqueue, 0)]);
        t.model = 9;
        obs.record(t);
        assert_eq!(obs.traced_count(), 0);
    }
}
