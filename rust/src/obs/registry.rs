//! Unified metrics registry: one namespace for every counter, gauge and
//! histogram in the system, snapshot-able as JSON or Prometheus text.
//!
//! Components don't push values; they register a *collector* closure
//! keyed by a stable source name (`"serving"`, `"health"`,
//! `"scheduler"`, …).  A snapshot invokes every collector, so the
//! registry always reads live state — and re-registering a key (e.g.
//! after a hot swap installs a new serving core) atomically replaces
//! the old collector.  Naming scheme: `graft_<subsystem>_<what>[_total]`
//! with `_total` reserved for monotonic counters, matching Prometheus
//! conventions; every consumer (the `graft serve` stats line, bench
//! JSON counter dumps, the `/metrics` endpoint) renders from the same
//! snapshot, so a counter has exactly one name everywhere.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::obs::hist::HistogramSnapshot;
use crate::util::Json;

/// A metric value at snapshot time.
#[derive(Debug, Clone)]
pub enum MetricValue {
    /// Monotonic count (name should end in `_total`).
    Counter(u64),
    /// Point-in-time scalar.
    Gauge(f64),
    /// Bucketed distribution (rendered as Prometheus histogram).
    Histogram(HistogramSnapshot),
}

/// One named metric in a snapshot.
#[derive(Debug, Clone)]
pub struct Metric {
    pub name: String,
    /// Label pairs, e.g. `[("model", "resnet")]`; empty for scalars.
    pub labels: Vec<(String, String)>,
    pub value: MetricValue,
}

impl Metric {
    pub fn counter(name: impl Into<String>, v: u64) -> Metric {
        Metric { name: name.into(), labels: Vec::new(), value: MetricValue::Counter(v) }
    }

    pub fn gauge(name: impl Into<String>, v: f64) -> Metric {
        Metric { name: name.into(), labels: Vec::new(), value: MetricValue::Gauge(v) }
    }

    pub fn histogram(name: impl Into<String>, s: HistogramSnapshot) -> Metric {
        Metric { name: name.into(), labels: Vec::new(), value: MetricValue::Histogram(s) }
    }

    pub fn with_label(
        mut self,
        key: impl Into<String>,
        value: impl Into<String>,
    ) -> Metric {
        self.labels.push((key.into(), value.into()));
        self
    }

    fn label_key(&self) -> String {
        self.labels
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect::<Vec<_>>()
            .join(",")
    }
}

type Collector = Box<dyn Fn(&mut Vec<Metric>) + Send + Sync>;

/// The process-wide metric namespace.  Embedding code (serve loop,
/// bench harness, `obs-report`) creates one, registers collectors over
/// its live components, and snapshots on demand.
#[derive(Default)]
pub struct MetricsRegistry {
    sources: Mutex<BTreeMap<String, Collector>>,
}

impl std::fmt::Debug for MetricsRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let keys: Vec<String> = self
            .sources
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .keys()
            .cloned()
            .collect();
        f.debug_struct("MetricsRegistry").field("sources", &keys).finish()
    }
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// Register (or replace) the collector for `source`.  Collectors
    /// run on every snapshot; keep them cheap — read counters, don't
    /// compute.
    pub fn register(
        &self,
        source: impl Into<String>,
        collect: impl Fn(&mut Vec<Metric>) + Send + Sync + 'static,
    ) {
        self.sources
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .insert(source.into(), Box::new(collect));
    }

    /// Drop a source (e.g. when its component shuts down).
    pub fn unregister(&self, source: &str) {
        self.sources
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .remove(source);
    }

    /// Collect every source, sorted by (name, labels) for stable output.
    pub fn snapshot(&self) -> Vec<Metric> {
        let mut out = Vec::new();
        {
            let sources = self.sources.lock().unwrap_or_else(|e| e.into_inner());
            for collect in sources.values() {
                collect(&mut out);
            }
        }
        out.sort_by(|a, b| {
            a.name.cmp(&b.name).then_with(|| a.label_key().cmp(&b.label_key()))
        });
        out
    }
}

/// Look up a scalar counter by name in a snapshot (first label match).
pub fn counter_value(snap: &[Metric], name: &str) -> Option<u64> {
    snap.iter().find_map(|m| match (&m.value, m.name == name) {
        (MetricValue::Counter(v), true) => Some(*v),
        _ => None,
    })
}

/// Sum a counter across all label sets (e.g. per-stage queue counters).
pub fn counter_sum(snap: &[Metric], name: &str) -> u64 {
    snap.iter()
        .filter(|m| m.name == name)
        .filter_map(|m| match &m.value {
            MetricValue::Counter(v) => Some(*v),
            _ => None,
        })
        .sum()
}

/// Look up a gauge by name (first label match).
pub fn gauge_value(snap: &[Metric], name: &str) -> Option<f64> {
    snap.iter().find_map(|m| match (&m.value, m.name == name) {
        (MetricValue::Gauge(v), true) => Some(*v),
        _ => None,
    })
}

fn fmt_f64(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v.is_infinite() {
        if v > 0.0 { "+Inf".to_string() } else { "-Inf".to_string() }
    } else if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

fn prom_labels(labels: &[(String, String)], extra: Option<(&str, &str)>) -> String {
    let mut parts: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", v.replace('\\', "\\\\").replace('"', "\\\"")))
        .collect();
    if let Some((k, v)) = extra {
        parts.push(format!("{k}=\"{v}\""));
    }
    if parts.is_empty() {
        String::new()
    } else {
        format!("{{{}}}", parts.join(","))
    }
}

/// Render a snapshot in the Prometheus text exposition format
/// (`# TYPE` headers, cumulative `_bucket{le=...}` histogram series
/// with `_sum`/`_count`).
pub fn prometheus_text(snap: &[Metric]) -> String {
    let mut out = String::new();
    let mut typed: BTreeMap<&str, &'static str> = BTreeMap::new();
    for m in snap {
        let kind = match m.value {
            MetricValue::Counter(_) => "counter",
            MetricValue::Gauge(_) => "gauge",
            MetricValue::Histogram(_) => "histogram",
        };
        if typed.insert(m.name.as_str(), kind) != Some(kind) {
            out.push_str(&format!("# TYPE {} {}\n", m.name, kind));
        }
        match &m.value {
            MetricValue::Counter(v) => {
                out.push_str(&format!("{}{} {}\n", m.name, prom_labels(&m.labels, None), v));
            }
            MetricValue::Gauge(v) => {
                out.push_str(&format!(
                    "{}{} {}\n",
                    m.name,
                    prom_labels(&m.labels, None),
                    fmt_f64(*v)
                ));
            }
            MetricValue::Histogram(h) => {
                let mut cum = 0u64;
                for b in &h.buckets {
                    cum += b.count;
                    let le = if b.upper.is_infinite() {
                        "+Inf".to_string()
                    } else {
                        fmt_f64(b.upper)
                    };
                    out.push_str(&format!(
                        "{}_bucket{} {}\n",
                        m.name,
                        prom_labels(&m.labels, Some(("le", &le))),
                        cum
                    ));
                }
                if h.buckets.last().map(|b| b.upper.is_finite()).unwrap_or(true) {
                    out.push_str(&format!(
                        "{}_bucket{} {}\n",
                        m.name,
                        prom_labels(&m.labels, Some(("le", "+Inf"))),
                        h.count
                    ));
                }
                out.push_str(&format!(
                    "{}_sum{} {}\n",
                    m.name,
                    prom_labels(&m.labels, None),
                    fmt_f64(h.sum_ms)
                ));
                out.push_str(&format!(
                    "{}_count{} {}\n",
                    m.name,
                    prom_labels(&m.labels, None),
                    h.count
                ));
            }
        }
    }
    out
}

/// Render a snapshot as a JSON array of metric objects.
pub fn snapshot_json(snap: &[Metric]) -> Json {
    Json::Arr(
        snap.iter()
            .map(|m| {
                let mut o = BTreeMap::new();
                o.insert("name".to_string(), Json::Str(m.name.clone()));
                if !m.labels.is_empty() {
                    let mut l = BTreeMap::new();
                    for (k, v) in &m.labels {
                        l.insert(k.clone(), Json::Str(v.clone()));
                    }
                    o.insert("labels".to_string(), Json::Obj(l));
                }
                match &m.value {
                    MetricValue::Counter(v) => {
                        o.insert("type".to_string(), Json::Str("counter".into()));
                        o.insert("value".to_string(), Json::Num(*v as f64));
                    }
                    MetricValue::Gauge(v) => {
                        o.insert("type".to_string(), Json::Str("gauge".into()));
                        o.insert(
                            "value".to_string(),
                            if v.is_finite() { Json::Num(*v) } else { Json::Null },
                        );
                    }
                    MetricValue::Histogram(h) => {
                        o.insert("type".to_string(), Json::Str("histogram".into()));
                        o.insert("count".to_string(), Json::Num(h.count as f64));
                        o.insert(
                            "sum_ms".to_string(),
                            if h.sum_ms.is_finite() {
                                Json::Num(h.sum_ms)
                            } else {
                                Json::Null
                            },
                        );
                        for (k, p) in
                            [("p50_ms", 50.0), ("p95_ms", 95.0), ("p99_ms", 99.0)]
                        {
                            let v = h.percentile(p);
                            o.insert(
                                k.to_string(),
                                if v.is_finite() { Json::Num(v) } else { Json::Null },
                            );
                        }
                    }
                }
                Json::Obj(o)
            })
            .collect(),
    )
}

/// Render the compact one-line serving status from a snapshot — the
/// single source for the `[serve]` heartbeat line, so its figures are
/// the registry's figures by construction.
pub fn render_stats_line(snap: &[Metric]) -> String {
    let c = |n: &str| counter_value(snap, n).unwrap_or(0);
    let g = |n: &str| gauge_value(snap, n).unwrap_or(0.0);
    format!(
        "served={} dropped={} batches={} rejected={} swaps={} \
         poison_recoveries={} failure_epoch={} recovery_epoch={} \
         degraded={} dead_gpus={} suspect_gpus={} traced={}",
        c("graft_serving_served_total"),
        c("graft_serving_dropped_total"),
        c("graft_serving_batches_total"),
        counter_sum(snap, "graft_queue_rejected_total"),
        c("graft_transition_swaps_total"),
        c("graft_serving_poison_recoveries_total"),
        c("graft_health_failure_epoch_total"),
        c("graft_health_recovery_epoch_total"),
        g("graft_health_degraded_gpus") as u64,
        g("graft_health_dead_gpus") as u64,
        g("graft_health_suspect_gpus") as u64,
        c("graft_trace_requests_total"),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::hist::Histogram;

    #[test]
    fn register_snapshot_and_replace() {
        let reg = MetricsRegistry::new();
        reg.register("a", |out| out.push(Metric::counter("graft_a_total", 1)));
        reg.register("b", |out| out.push(Metric::gauge("graft_b", 2.5)));
        let snap = reg.snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(counter_value(&snap, "graft_a_total"), Some(1));
        assert_eq!(gauge_value(&snap, "graft_b"), Some(2.5));
        // replace source "a"
        reg.register("a", |out| out.push(Metric::counter("graft_a_total", 9)));
        assert_eq!(counter_value(&reg.snapshot(), "graft_a_total"), Some(9));
        reg.unregister("b");
        assert_eq!(reg.snapshot().len(), 1);
    }

    #[test]
    fn counter_sum_across_labels() {
        let snap = vec![
            Metric::counter("graft_queue_rejected_total", 3).with_label("stage", "0"),
            Metric::counter("graft_queue_rejected_total", 4).with_label("stage", "1"),
        ];
        assert_eq!(counter_sum(&snap, "graft_queue_rejected_total"), 7);
    }

    #[test]
    fn prometheus_text_renders_all_kinds() {
        let h = Histogram::new();
        h.record(1.0);
        h.record(10.0);
        let snap = vec![
            Metric::counter("graft_served_total", 5),
            Metric::gauge("graft_util", 0.5).with_label("gpu", "0"),
            Metric::histogram("graft_e2e_ms", h.snapshot()).with_label("model", "m"),
        ];
        let text = prometheus_text(&snap);
        assert!(text.contains("# TYPE graft_served_total counter"));
        assert!(text.contains("graft_served_total 5"));
        assert!(text.contains("graft_util{gpu=\"0\"} 0.5"));
        assert!(text.contains("# TYPE graft_e2e_ms histogram"));
        assert!(text.contains("graft_e2e_ms_bucket{model=\"m\",le=\"+Inf\"} 2"));
        assert!(text.contains("graft_e2e_ms_count{model=\"m\"} 2"));
        // cumulative bucket counts are nondecreasing
        let cums: Vec<u64> = text
            .lines()
            .filter(|l| l.starts_with("graft_e2e_ms_bucket"))
            .map(|l| l.rsplit(' ').next().unwrap().parse().unwrap())
            .collect();
        assert!(cums.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn snapshot_json_parses_and_carries_percentiles() {
        let h = Histogram::new();
        for i in 1..=100 {
            h.record(i as f64);
        }
        let snap = vec![
            Metric::counter("graft_served_total", 100),
            Metric::histogram("graft_e2e_ms", h.snapshot()),
        ];
        let text = snapshot_json(&snap).to_string();
        let parsed = Json::parse(&text).unwrap();
        let arr = parsed.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        let hist = &arr[1];
        assert_eq!(hist.get("type").unwrap().as_str().unwrap(), "histogram");
        let p50 = hist.get("p50_ms").unwrap().as_f64().unwrap();
        assert!((p50 - 50.0).abs() / 50.0 <= 0.01, "{p50}");
    }

    #[test]
    fn stats_line_reads_registry_names() {
        let snap = vec![
            Metric::counter("graft_serving_served_total", 12),
            Metric::counter("graft_queue_rejected_total", 1).with_label("stage", "0"),
            Metric::counter("graft_queue_rejected_total", 2).with_label("stage", "1"),
            Metric::gauge("graft_health_dead_gpus", 1.0),
        ];
        let line = render_stats_line(&snap);
        assert!(line.contains("served=12"));
        assert!(line.contains("rejected=3"));
        assert!(line.contains("dead_gpus=1"));
    }
}
