//! Failure detection state for the serving core.
//!
//! The [`HealthRegistry`] is the server's failure ledger: executor
//! heartbeats (one beat per delivered batch) and explicit death marks
//! (a panicking worker, a failed GPU, a poisoned queue shard) land
//! here, stamped with a monotonically increasing event sequence.  The
//! replan controller polls it between ticks: a GPU failure it has not
//! yet acknowledged triggers an *emergency replan* that excludes the
//! dead GPUs from placement and hot-swaps the surviving capacity in.
//!
//! Epochs partition time into health regimes: `failure_epoch` bumps on
//! every detected failure, `recovery_epoch` on every completed
//! emergency replan.  `failure_epoch > recovery_epoch` therefore means
//! "degraded: running around a failure the planner has not yet routed
//! around".

use std::collections::{BTreeSet, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::lock::lock_recover;

/// What happened to a failure-domain member.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HealthEventKind {
    /// One instance died (worker panic / kill).
    InstanceDown,
    /// A whole GPU failed; every co-located instance is down.
    GpuDown,
    /// A queue shard's lock was poisoned (and recovered).
    ShardPoisoned,
    /// An emergency replan completed; the plan no longer depends on the
    /// failed capacity.
    Recovered,
}

/// One entry in the failure ledger.
#[derive(Debug, Clone, Copy)]
pub struct HealthEvent {
    /// Monotonic sequence number (total order over events).
    pub seq: u64,
    pub kind: HealthEventKind,
    /// Stage index (meaningless for `GpuDown`/`Recovered`: 0).
    pub stage: usize,
    /// Instance index within the stage (ditto).
    pub instance: usize,
    /// GPU id (`u32::MAX` when unplaced / not applicable).
    pub gpu: u32,
}

/// Per-server failure ledger; see the module docs.
#[derive(Default)]
pub struct HealthRegistry {
    seq: AtomicU64,
    failure_epoch: AtomicU64,
    recovery_epoch: AtomicU64,
    /// Batches delivered per (stage, instance) — the liveness signal.
    beats: Mutex<HashMap<(usize, usize), u64>>,
    dead_gpus: Mutex<BTreeSet<u32>>,
    /// GPU failures not yet consumed by the controller.
    unacked_gpus: Mutex<BTreeSet<u32>>,
    dead_instances: Mutex<BTreeSet<(usize, usize)>>,
    events: Mutex<Vec<HealthEvent>>,
}

impl HealthRegistry {
    fn push_event(
        &self,
        kind: HealthEventKind,
        stage: usize,
        instance: usize,
        gpu: u32,
    ) -> u64 {
        let seq = self.seq.fetch_add(1, Ordering::SeqCst);
        lock_recover(&self.events).push(HealthEvent {
            seq,
            kind,
            stage,
            instance,
            gpu,
        });
        seq
    }

    /// Heartbeat: instance `(stage, instance)` delivered a batch.
    pub fn beat(&self, stage: usize, instance: usize) {
        *lock_recover(&self.beats).entry((stage, instance)).or_insert(0) += 1;
    }

    /// Batches delivered by `(stage, instance)` so far.
    pub fn beats(&self, stage: usize, instance: usize) -> u64 {
        lock_recover(&self.beats)
            .get(&(stage, instance))
            .copied()
            .unwrap_or(0)
    }

    /// Mark one instance dead.  Returns `false` if it was already dead
    /// (idempotent; no second event is recorded).
    pub fn mark_instance_down(
        &self,
        stage: usize,
        instance: usize,
        gpu: u32,
    ) -> bool {
        if !lock_recover(&self.dead_instances).insert((stage, instance)) {
            return false;
        }
        self.failure_epoch.fetch_add(1, Ordering::SeqCst);
        self.push_event(HealthEventKind::InstanceDown, stage, instance, gpu);
        true
    }

    /// Mark a GPU dead (the per-instance marks arrive separately from
    /// the instances being torn down).  Idempotent.
    pub fn mark_gpu_down(&self, gpu: u32) -> bool {
        if !lock_recover(&self.dead_gpus).insert(gpu) {
            return false;
        }
        lock_recover(&self.unacked_gpus).insert(gpu);
        self.failure_epoch.fetch_add(1, Ordering::SeqCst);
        self.push_event(HealthEventKind::GpuDown, 0, 0, gpu);
        true
    }

    /// Record a recovered shard poisoning (detection only — the queue
    /// already recovered the lock).
    pub fn mark_shard_poisoned(&self, stage: usize, shard: usize) {
        self.push_event(HealthEventKind::ShardPoisoned, stage, shard, u32::MAX);
    }

    /// An emergency replan routed around the failures; close the epoch.
    pub fn note_recovery(&self) {
        self.recovery_epoch.fetch_add(1, Ordering::SeqCst);
        self.push_event(HealthEventKind::Recovered, 0, 0, u32::MAX);
    }

    /// GPUs marked dead so far (sorted).
    pub fn failed_gpus(&self) -> Vec<u32> {
        lock_recover(&self.dead_gpus).iter().copied().collect()
    }

    /// Drain the GPU failures the controller has not yet seen — each
    /// failure is handed out exactly once, so one fault triggers one
    /// emergency replan.
    pub fn take_unacked_gpu_failures(&self) -> Vec<u32> {
        let mut g = lock_recover(&self.unacked_gpus);
        let out: Vec<u32> = g.iter().copied().collect();
        g.clear();
        out
    }

    pub fn is_instance_dead(&self, stage: usize, instance: usize) -> bool {
        lock_recover(&self.dead_instances).contains(&(stage, instance))
    }

    pub fn dead_instance_count(&self) -> usize {
        lock_recover(&self.dead_instances).len()
    }

    /// Failures detected since start.
    pub fn failure_epoch(&self) -> u64 {
        self.failure_epoch.load(Ordering::SeqCst)
    }

    /// Emergency replans completed since start.
    pub fn recovery_epoch(&self) -> u64 {
        self.recovery_epoch.load(Ordering::SeqCst)
    }

    /// Degraded = failures the planner has not routed around yet.
    pub fn degraded(&self) -> bool {
        self.failure_epoch() > self.recovery_epoch()
    }

    /// Snapshot of the event ledger (ordered by `seq`).
    pub fn events(&self) -> Vec<HealthEvent> {
        lock_recover(&self.events).clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idempotent_marks_and_epochs() {
        let h = HealthRegistry::default();
        assert!(!h.degraded());
        assert!(h.mark_instance_down(0, 1, 3));
        assert!(!h.mark_instance_down(0, 1, 3), "second mark is a no-op");
        assert!(h.mark_gpu_down(3));
        assert!(!h.mark_gpu_down(3));
        assert_eq!(h.failure_epoch(), 2);
        assert!(h.degraded());
        assert_eq!(h.failed_gpus(), vec![3]);
        assert_eq!(h.take_unacked_gpu_failures(), vec![3]);
        assert!(h.take_unacked_gpu_failures().is_empty(), "handed out once");
        h.note_recovery();
        assert!(!h.degraded());
        // the ledger kept everything, in order
        let kinds: Vec<_> = h.events().iter().map(|e| e.kind).collect();
        assert_eq!(
            kinds,
            vec![
                HealthEventKind::InstanceDown,
                HealthEventKind::GpuDown,
                HealthEventKind::Recovered
            ]
        );
    }

    #[test]
    fn beats_accumulate() {
        let h = HealthRegistry::default();
        assert_eq!(h.beats(1, 0), 0);
        h.beat(1, 0);
        h.beat(1, 0);
        assert_eq!(h.beats(1, 0), 2);
        assert!(!h.is_instance_dead(1, 0));
    }
}
