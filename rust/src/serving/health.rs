//! Failure detection state for the serving core.
//!
//! The [`HealthRegistry`] is the server's failure ledger: executor
//! heartbeats (one beat per delivered batch) and explicit death marks
//! (a panicking worker, a failed GPU, a poisoned queue shard) land
//! here, stamped with a monotonically increasing event sequence.  The
//! replan controller polls it between ticks: a GPU failure it has not
//! yet acknowledged triggers an *emergency replan* that excludes the
//! dead GPUs from placement and hot-swaps the surviving capacity in.
//!
//! Epochs partition time into health regimes: `failure_epoch` bumps on
//! every detected failure, `recovery_epoch` on every completed
//! emergency replan.  `failure_epoch > recovery_epoch` therefore means
//! "degraded: running around a failure the planner has not yet routed
//! around".
//!
//! On top of the binary ledger sits *predictive* health scoring: every
//! beat carries a timestamp, and per-instance / per-GPU
//! [`ScoreState`]s track the inter-arrival EWMA + variance (the same
//! estimator the batcher uses for arrival rates) plus a decaying
//! fault level fed by exec panics and explicit warnings.  The blended
//! score is deterministic given the event sequence (timestamps are
//! injectable via [`HealthRegistry::beat_at`]), rises toward 1.0 as a
//! GPU looks sicker, and decays toward 0.0 as clean beats come in.
//! The controller folds GPUs whose score crosses its
//! `suspect_threshold` into a *soft* avoid-set — prefer-not bins for
//! placement, unlike the hard `dead_gpus` exclusion.
//!
//! Capacity is not binary either: [`HealthRegistry::mark_gpu_degraded`]
//! records partial share/memory loss ([`GpuDegradation`]) that the
//! controller folds into placement as residual capacity, so a sick GPU
//! keeps serving at reduced load instead of being declared dead.

use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::lock::lock_recover;

/// What happened to a failure-domain member.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HealthEventKind {
    /// One instance died (worker panic / kill).
    InstanceDown,
    /// A whole GPU failed; every co-located instance is down.
    GpuDown,
    /// A queue shard's lock was poisoned (and recovered).
    ShardPoisoned,
    /// An emergency replan completed; the plan no longer depends on the
    /// failed capacity.
    Recovered,
    /// An executor panicked mid-batch (the instance survived or was
    /// retired separately) — feeds the predictive fault level.
    ExecPanic,
    /// An out-of-band health warning against a GPU (e.g. thermal / ECC
    /// telemetry) — feeds the predictive fault level.
    GpuWarning,
    /// A GPU lost part of its capacity without dying; the amounts live
    /// in [`HealthRegistry::gpu_degradations`].
    GpuDegraded,
    /// A previously failed/degraded GPU came back at full capacity.
    GpuRecovered,
}

/// One entry in the failure ledger.
#[derive(Debug, Clone, Copy)]
pub struct HealthEvent {
    /// Monotonic sequence number (total order over events).
    pub seq: u64,
    pub kind: HealthEventKind,
    /// Stage index (meaningless for `GpuDown`/`Recovered`: 0).
    pub stage: usize,
    /// Instance index within the stage (ditto).
    pub instance: usize,
    /// GPU id (`u32::MAX` when unplaced / not applicable).
    pub gpu: u32,
}

/// Knobs for the predictive health score.  Defaults are tuned so that
/// heartbeat jitter alone can never cross the controller's default
/// suspect threshold (0.6): the variance term is capped at
/// `var_weight` (0.4), so only fault history (panics / warnings) can
/// push a healthy-looking GPU over the line, while jitter *amplifies*
/// an already suspicious one.
#[derive(Debug, Clone, Copy)]
pub struct HealthScoreOptions {
    /// EWMA smoothing factor for heartbeat inter-arrival mean/variance.
    pub ewma_alpha: f64,
    /// Per-beat multiplicative decay of the fault level (clean beats
    /// forgive history).
    pub fault_decay: f64,
    /// Fault-level bump per executor panic.
    pub panic_weight: f64,
    /// Fault-level bump per explicit GPU warning.
    pub warn_weight: f64,
    /// Weight of the normalized inter-arrival variance in the blended
    /// score (also its cap).
    pub var_weight: f64,
    /// Coefficient-of-variation at which the variance term saturates.
    pub cv_saturation: f64,
    /// Beats required before the variance term is trusted at all.
    pub min_beats: u64,
}

impl Default for HealthScoreOptions {
    fn default() -> Self {
        Self {
            ewma_alpha: 0.2,
            fault_decay: 0.9,
            panic_weight: 0.5,
            warn_weight: 0.35,
            var_weight: 0.4,
            cv_saturation: 2.0,
            min_beats: 8,
        }
    }
}

/// Partial capacity loss on a live GPU (cumulative).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct GpuDegradation {
    /// Compute share lost (same units as `PlacementOptions::gpu_share`).
    pub share_loss: u32,
    /// Memory lost in MB.
    pub mem_loss_mb: f64,
}

/// Streaming health estimator for one instance or one GPU.
#[derive(Debug, Clone, Copy, Default)]
struct ScoreState {
    last_beat_ms: Option<f64>,
    gap_ewma_ms: f64,
    gap_var_ewma: f64,
    beats: u64,
    /// Decaying fault history in [0, 1).
    fault_level: f64,
}

impl ScoreState {
    fn on_beat(&mut self, t_ms: f64, opts: &HealthScoreOptions) {
        if let Some(last) = self.last_beat_ms {
            let gap = (t_ms - last).max(0.0);
            if self.beats <= 1 {
                // first observed gap seeds the EWMA
                self.gap_ewma_ms = gap;
                self.gap_var_ewma = 0.0;
            } else {
                let dev = gap - self.gap_ewma_ms;
                self.gap_ewma_ms += opts.ewma_alpha * dev;
                self.gap_var_ewma = (1.0 - opts.ewma_alpha) * self.gap_var_ewma
                    + opts.ewma_alpha * dev * dev;
            }
        }
        self.last_beat_ms = Some(t_ms);
        self.beats += 1;
        self.fault_level *= opts.fault_decay;
    }

    fn on_fault(&mut self, weight: f64) {
        self.fault_level += (1.0 - self.fault_level) * weight.clamp(0.0, 1.0);
    }

    /// Blended score in [0, 1]: `1 - (1 - fault) * (1 - w_v * var_norm)`
    /// where `var_norm` is the saturated coefficient of variation of
    /// beat gaps.  Monotone in both signals; equals `fault_level` until
    /// enough beats have landed to trust the variance.
    fn score(&self, opts: &HealthScoreOptions) -> f64 {
        let var_norm = if self.beats >= opts.min_beats
            && self.gap_ewma_ms > 1e-9
        {
            let cv = self.gap_var_ewma.max(0.0).sqrt() / self.gap_ewma_ms;
            (cv / opts.cv_saturation).min(1.0)
        } else {
            0.0
        };
        1.0 - (1.0 - self.fault_level) * (1.0 - opts.var_weight * var_norm)
    }
}

/// Per-server failure ledger; see the module docs.
pub struct HealthRegistry {
    seq: AtomicU64,
    failure_epoch: AtomicU64,
    recovery_epoch: AtomicU64,
    /// Wall-clock origin for self-timestamped beats.
    t0: Instant,
    opts: HealthScoreOptions,
    /// Batches delivered per (stage, instance) — the liveness signal.
    beats: Mutex<HashMap<(usize, usize), u64>>,
    dead_gpus: Mutex<BTreeSet<u32>>,
    /// GPU failures not yet consumed by the controller.
    unacked_gpus: Mutex<BTreeSet<u32>>,
    dead_instances: Mutex<BTreeSet<(usize, usize)>>,
    events: Mutex<Vec<HealthEvent>>,
    /// Predictive score state per (stage, instance).
    inst_scores: Mutex<HashMap<(usize, usize), ScoreState>>,
    /// Predictive score state per GPU.
    gpu_score_states: Mutex<HashMap<u32, ScoreState>>,
    /// Cumulative partial capacity loss per live GPU.
    degradations: Mutex<BTreeMap<u32, GpuDegradation>>,
    /// Degradations not yet consumed by the controller.
    unacked_degrades: Mutex<BTreeMap<u32, GpuDegradation>>,
    /// GPU recoveries not yet consumed by the controller.
    unacked_recoveries: Mutex<BTreeSet<u32>>,
}

impl Default for HealthRegistry {
    fn default() -> Self {
        Self {
            seq: AtomicU64::new(0),
            failure_epoch: AtomicU64::new(0),
            recovery_epoch: AtomicU64::new(0),
            t0: Instant::now(),
            opts: HealthScoreOptions::default(),
            beats: Mutex::new(HashMap::new()),
            dead_gpus: Mutex::new(BTreeSet::new()),
            unacked_gpus: Mutex::new(BTreeSet::new()),
            dead_instances: Mutex::new(BTreeSet::new()),
            events: Mutex::new(Vec::new()),
            inst_scores: Mutex::new(HashMap::new()),
            gpu_score_states: Mutex::new(HashMap::new()),
            degradations: Mutex::new(BTreeMap::new()),
            unacked_degrades: Mutex::new(BTreeMap::new()),
            unacked_recoveries: Mutex::new(BTreeSet::new()),
        }
    }
}

impl HealthRegistry {
    pub fn with_score_options(opts: HealthScoreOptions) -> Self {
        Self { opts, ..Default::default() }
    }

    pub fn score_options(&self) -> HealthScoreOptions {
        self.opts
    }

    /// Every ledger mutation allocates its seq *inside* the events
    /// lock, so the vec is dense and ordered even when writers race or
    /// a panicking holder poisoned the lock (`lock_recover` hands the
    /// next writer the recovered guard and the numbering continues).
    fn push_event(
        &self,
        kind: HealthEventKind,
        stage: usize,
        instance: usize,
        gpu: u32,
    ) -> u64 {
        let mut events = lock_recover(&self.events);
        let seq = self.seq.fetch_add(1, Ordering::SeqCst);
        events.push(HealthEvent { seq, kind, stage, instance, gpu });
        seq
    }

    /// Heartbeat: instance `(stage, instance)` delivered a batch.
    pub fn beat(&self, stage: usize, instance: usize) {
        *lock_recover(&self.beats).entry((stage, instance)).or_insert(0) += 1;
    }

    /// Milliseconds since this registry was created (the timestamp
    /// [`Self::beat_live`] stamps onto beats).
    pub fn now_ms(&self) -> f64 {
        self.t0.elapsed().as_secs_f64() * 1e3
    }

    /// Heartbeat with scoring, self-timestamped off the registry clock.
    pub fn beat_live(&self, stage: usize, instance: usize, gpu: u32) {
        self.beat_at(stage, instance, gpu, self.now_ms());
    }

    /// Heartbeat with scoring at an explicit timestamp — the
    /// deterministic entry point: replaying the same `(t_ms, fault)`
    /// sequence reproduces the same scores bit-for-bit.
    pub fn beat_at(&self, stage: usize, instance: usize, gpu: u32, t_ms: f64) {
        self.beat(stage, instance);
        lock_recover(&self.inst_scores)
            .entry((stage, instance))
            .or_default()
            .on_beat(t_ms, &self.opts);
        if gpu != u32::MAX {
            lock_recover(&self.gpu_score_states)
                .entry(gpu)
                .or_default()
                .on_beat(t_ms, &self.opts);
        }
    }

    /// An executor panicked running a batch on `(stage, instance)`;
    /// bumps both the instance's and the hosting GPU's fault level.
    pub fn record_exec_panic(&self, stage: usize, instance: usize, gpu: u32) {
        lock_recover(&self.inst_scores)
            .entry((stage, instance))
            .or_default()
            .on_fault(self.opts.panic_weight);
        if gpu != u32::MAX {
            lock_recover(&self.gpu_score_states)
                .entry(gpu)
                .or_default()
                .on_fault(self.opts.panic_weight);
        }
        self.push_event(HealthEventKind::ExecPanic, stage, instance, gpu);
    }

    /// Out-of-band warning against a GPU (telemetry, operator signal).
    pub fn record_gpu_warning(&self, gpu: u32) {
        lock_recover(&self.gpu_score_states)
            .entry(gpu)
            .or_default()
            .on_fault(self.opts.warn_weight);
        self.push_event(HealthEventKind::GpuWarning, 0, 0, gpu);
    }

    /// Predictive score for one instance (0 = healthy, 1 = certain
    /// failure); 0 when never observed.
    pub fn instance_score(&self, stage: usize, instance: usize) -> f64 {
        lock_recover(&self.inst_scores)
            .get(&(stage, instance))
            .map(|s| s.score(&self.opts))
            .unwrap_or(0.0)
    }

    /// Predictive score for one GPU; 0 when never observed.
    pub fn gpu_score(&self, gpu: u32) -> f64 {
        lock_recover(&self.gpu_score_states)
            .get(&gpu)
            .map(|s| s.score(&self.opts))
            .unwrap_or(0.0)
    }

    /// Snapshot of every observed GPU's predictive score (sorted).
    pub fn gpu_scores(&self) -> BTreeMap<u32, f64> {
        lock_recover(&self.gpu_score_states)
            .iter()
            .map(|(g, s)| (*g, s.score(&self.opts)))
            .collect()
    }

    /// Mark one instance dead.  Returns `false` if it was already dead
    /// (idempotent; no second event is recorded).
    pub fn mark_instance_down(
        &self,
        stage: usize,
        instance: usize,
        gpu: u32,
    ) -> bool {
        if !lock_recover(&self.dead_instances).insert((stage, instance)) {
            return false;
        }
        self.failure_epoch.fetch_add(1, Ordering::SeqCst);
        self.push_event(HealthEventKind::InstanceDown, stage, instance, gpu);
        true
    }

    /// Mark a GPU dead (the per-instance marks arrive separately from
    /// the instances being torn down).  Idempotent.
    pub fn mark_gpu_down(&self, gpu: u32) -> bool {
        if !lock_recover(&self.dead_gpus).insert(gpu) {
            return false;
        }
        lock_recover(&self.unacked_gpus).insert(gpu);
        self.failure_epoch.fetch_add(1, Ordering::SeqCst);
        self.push_event(HealthEventKind::GpuDown, 0, 0, gpu);
        true
    }

    /// A GPU lost part of its capacity without dying.  Losses
    /// accumulate across calls; each call re-queues the cumulative
    /// total for the controller and bumps the failure epoch (the
    /// cluster is degraded until the planner folds the loss in).
    pub fn mark_gpu_degraded(
        &self,
        gpu: u32,
        share_loss: u32,
        mem_loss_mb: f64,
    ) {
        {
            let mut all = lock_recover(&self.degradations);
            let entry = all.entry(gpu).or_default();
            entry.share_loss = entry.share_loss.saturating_add(share_loss);
            entry.mem_loss_mb += mem_loss_mb.max(0.0);
            lock_recover(&self.unacked_degrades).insert(gpu, *entry);
        }
        self.failure_epoch.fetch_add(1, Ordering::SeqCst);
        self.push_event(HealthEventKind::GpuDegraded, 0, 0, gpu);
    }

    /// Cumulative capacity loss per GPU (sorted snapshot).
    pub fn gpu_degradations(&self) -> BTreeMap<u32, GpuDegradation> {
        lock_recover(&self.degradations).clone()
    }

    /// Drain the degradations the controller has not yet folded into
    /// placement — each handed out exactly once.
    pub fn take_unacked_degrades(&self) -> Vec<(u32, GpuDegradation)> {
        let mut d = lock_recover(&self.unacked_degrades);
        let out: Vec<(u32, GpuDegradation)> =
            d.iter().map(|(g, x)| (*g, *x)).collect();
        d.clear();
        out
    }

    /// A failed or degraded GPU came back at full capacity: clear its
    /// dead/degraded/suspect state and queue the recovery for the
    /// controller (which lifts it from `dead_gpus` and replans onto
    /// it).  Always enqueues — after a hot swap the server carries a
    /// fresh registry, so the recovery must reach the controller even
    /// when this ledger never saw the original failure.  Returns
    /// whether any local state was actually cleared.
    pub fn mark_gpu_recovered(&self, gpu: u32) -> bool {
        let was_dead = lock_recover(&self.dead_gpus).remove(&gpu);
        let was_degraded =
            lock_recover(&self.degradations).remove(&gpu).is_some();
        lock_recover(&self.unacked_gpus).remove(&gpu);
        lock_recover(&self.unacked_degrades).remove(&gpu);
        lock_recover(&self.gpu_score_states).remove(&gpu);
        if lock_recover(&self.unacked_recoveries).insert(gpu) {
            self.push_event(HealthEventKind::GpuRecovered, 0, 0, gpu);
        }
        was_dead || was_degraded
    }

    /// Drain the GPU recoveries the controller has not yet seen.
    pub fn take_unacked_gpu_recoveries(&self) -> Vec<u32> {
        let mut g = lock_recover(&self.unacked_recoveries);
        let out: Vec<u32> = g.iter().copied().collect();
        g.clear();
        out
    }

    /// Record a recovered shard poisoning (detection only — the queue
    /// already recovered the lock).
    pub fn mark_shard_poisoned(&self, stage: usize, shard: usize) {
        self.push_event(HealthEventKind::ShardPoisoned, stage, shard, u32::MAX);
    }

    /// An emergency replan routed around the failures; close the epoch.
    pub fn note_recovery(&self) {
        self.recovery_epoch.fetch_add(1, Ordering::SeqCst);
        self.push_event(HealthEventKind::Recovered, 0, 0, u32::MAX);
    }

    /// Batches delivered by `(stage, instance)` so far.
    pub fn beats(&self, stage: usize, instance: usize) -> u64 {
        lock_recover(&self.beats)
            .get(&(stage, instance))
            .copied()
            .unwrap_or(0)
    }

    /// GPUs marked dead so far (sorted).
    pub fn failed_gpus(&self) -> Vec<u32> {
        lock_recover(&self.dead_gpus).iter().copied().collect()
    }

    /// Drain the GPU failures the controller has not yet seen — each
    /// failure is handed out exactly once, so one fault triggers one
    /// emergency replan.
    pub fn take_unacked_gpu_failures(&self) -> Vec<u32> {
        let mut g = lock_recover(&self.unacked_gpus);
        let out: Vec<u32> = g.iter().copied().collect();
        g.clear();
        out
    }

    pub fn is_instance_dead(&self, stage: usize, instance: usize) -> bool {
        lock_recover(&self.dead_instances).contains(&(stage, instance))
    }

    pub fn dead_instance_count(&self) -> usize {
        lock_recover(&self.dead_instances).len()
    }

    /// Failures detected since start.
    pub fn failure_epoch(&self) -> u64 {
        self.failure_epoch.load(Ordering::SeqCst)
    }

    /// Emergency replans completed since start.
    pub fn recovery_epoch(&self) -> u64 {
        self.recovery_epoch.load(Ordering::SeqCst)
    }

    /// Degraded = failures the planner has not routed around yet.
    pub fn degraded(&self) -> bool {
        self.failure_epoch() > self.recovery_epoch()
    }

    /// Snapshot of the event ledger (ordered by `seq`).
    pub fn events(&self) -> Vec<HealthEvent> {
        lock_recover(&self.events).clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idempotent_marks_and_epochs() {
        let h = HealthRegistry::default();
        assert!(!h.degraded());
        assert!(h.mark_instance_down(0, 1, 3));
        assert!(!h.mark_instance_down(0, 1, 3), "second mark is a no-op");
        assert!(h.mark_gpu_down(3));
        assert!(!h.mark_gpu_down(3));
        assert_eq!(h.failure_epoch(), 2);
        assert!(h.degraded());
        assert_eq!(h.failed_gpus(), vec![3]);
        assert_eq!(h.take_unacked_gpu_failures(), vec![3]);
        assert!(h.take_unacked_gpu_failures().is_empty(), "handed out once");
        h.note_recovery();
        assert!(!h.degraded());
        // the ledger kept everything, in order
        let kinds: Vec<_> = h.events().iter().map(|e| e.kind).collect();
        assert_eq!(
            kinds,
            vec![
                HealthEventKind::InstanceDown,
                HealthEventKind::GpuDown,
                HealthEventKind::Recovered
            ]
        );
    }

    #[test]
    fn beats_accumulate() {
        let h = HealthRegistry::default();
        assert_eq!(h.beats(1, 0), 0);
        h.beat(1, 0);
        h.beat(1, 0);
        assert_eq!(h.beats(1, 0), 2);
        assert!(!h.is_instance_dead(1, 0));
    }

    /// The same `(t_ms, fault)` sequence replayed into two registries
    /// produces bit-identical scores — chaos runs replay.
    #[test]
    fn scores_replay_deterministically() {
        let run = || {
            let h = HealthRegistry::default();
            for i in 0..20u64 {
                // jittered gaps: 10ms, 14ms, 10ms, 14ms, ...
                let t = (i * 10 + (i % 2) * 4) as f64;
                h.beat_at(0, 0, 7, t);
                if i == 5 || i == 11 {
                    h.record_exec_panic(0, 0, 7);
                }
            }
            h.record_gpu_warning(7);
            (h.gpu_score(7), h.instance_score(0, 0))
        };
        let (a_gpu, a_inst) = run();
        let (b_gpu, b_inst) = run();
        assert_eq!(a_gpu.to_bits(), b_gpu.to_bits());
        assert_eq!(a_inst.to_bits(), b_inst.to_bits());
        assert!(a_gpu > 0.0 && a_gpu <= 1.0);
    }

    /// Jitter alone stays under the default suspect threshold (0.6);
    /// fault history crosses it; clean beats decay it back.
    #[test]
    fn fault_history_crosses_threshold_and_decays() {
        let h = HealthRegistry::default();
        // pure jitter: wildly varying gaps, no faults
        let mut t = 0.0;
        for gap in [5.0, 50.0, 2.0, 80.0, 1.0, 60.0, 3.0, 90.0, 4.0] {
            t += gap;
            h.beat_at(0, 0, 2, t);
        }
        let jitter_only = h.gpu_score(2);
        assert!(
            jitter_only < 0.6,
            "variance term is capped below the suspect threshold: {jitter_only}"
        );
        // three warnings push it over
        for _ in 0..3 {
            h.record_gpu_warning(2);
        }
        assert!(h.gpu_score(2) >= 0.6, "warnings must cross the threshold");
        // a long run of clean, regular beats forgives the history
        let mut t = 1000.0;
        for _ in 0..60 {
            t += 10.0;
            h.beat_at(0, 0, 2, t);
        }
        assert!(h.gpu_score(2) < 0.6, "clean beats must decay the score");
    }

    #[test]
    fn degradation_accumulates_and_recovery_clears() {
        let h = HealthRegistry::default();
        h.mark_gpu_degraded(4, 20, 512.0);
        h.mark_gpu_degraded(4, 10, 256.0);
        let d = h.gpu_degradations();
        assert_eq!(d[&4], GpuDegradation { share_loss: 30, mem_loss_mb: 768.0 });
        assert_eq!(h.failure_epoch(), 2);
        // cumulative total handed out, exactly once
        let taken = h.take_unacked_degrades();
        assert_eq!(taken.len(), 1);
        assert_eq!(taken[0].0, 4);
        assert_eq!(taken[0].1.share_loss, 30);
        assert!(h.take_unacked_degrades().is_empty());
        // recovery clears everything and queues itself for the
        // controller exactly once
        assert!(h.mark_gpu_recovered(4));
        assert!(h.gpu_degradations().is_empty());
        assert_eq!(h.take_unacked_gpu_recoveries(), vec![4]);
        assert!(h.take_unacked_gpu_recoveries().is_empty());
        // recovering a GPU this ledger never saw still enqueues (the
        // post-swap server carries a fresh registry) but reports no
        // local state change
        assert!(!h.mark_gpu_recovered(9));
        assert_eq!(h.take_unacked_gpu_recoveries(), vec![9]);
    }

    /// Racing writers never skip or reorder ledger seqs: the vec is
    /// dense 0..n in order because the seq is allocated inside the
    /// events lock.
    #[test]
    fn ledger_seq_dense_and_ordered_under_races() {
        let h = HealthRegistry::default();
        std::thread::scope(|s| {
            for t in 0..4usize {
                let h = &h;
                s.spawn(move || {
                    for i in 0..50usize {
                        match i % 3 {
                            0 => h.record_exec_panic(t, i, t as u32),
                            1 => h.record_gpu_warning(t as u32),
                            _ => h.mark_shard_poisoned(t, i),
                        }
                    }
                });
            }
        });
        let events = h.events();
        assert_eq!(events.len(), 200);
        for (i, e) in events.iter().enumerate() {
            assert_eq!(e.seq, i as u64, "ledger seq must be dense and ordered");
        }
    }
}
