//! TCP front-end: clients send framed [`Request`]s over a socket (the
//! paper's data path uses network sockets from the mobile devices) and
//! receive framed [`Response`]s on the same connection.
//!
//! Failure handling: the reader enforces an idle read deadline (a
//! connection that stops sending mid-frame — the slow-loris pattern —
//! is evicted and counted via [`RequestSink::on_conn_evicted`]), the
//! writer enforces a write timeout, and a [`FaultPlan`] can drop or
//! stall connections at chosen frame ticks for reproducible chaos runs.
//! [`TcpClient`] carries a bounded-retry policy (exponential backoff
//! with full jitter) for both connect and send.

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::Result;

use super::faults::{FaultDomain, FaultKind, FaultPlan};
use super::messages::{read_frame, write_frame, Request, Response};
use super::server::RequestSink;
use crate::util::rng::Rng;

/// Deadlines for one server-side connection.
#[derive(Debug, Clone, Copy)]
pub struct FrontOptions {
    /// Reader deadline: a connection idle (or stalled mid-frame) this
    /// long is evicted — the slow-loris guard.  `None` = wait forever.
    pub idle_deadline: Option<Duration>,
    /// Writer deadline per response burst.  `None` = block forever.
    pub write_timeout: Option<Duration>,
}

impl Default for FrontOptions {
    fn default() -> Self {
        Self {
            idle_deadline: Some(Duration::from_secs(30)),
            write_timeout: Some(Duration::from_secs(10)),
        }
    }
}

/// A running TCP acceptor in front of any [`RequestSink`] — a plain
/// [`crate::serving::Server`] or the live-reconfigurable
/// [`crate::runtime::LiveServer`] (connections survive plan swaps: the
/// sink reroutes each submit to the current serving core).
pub struct TcpFront {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_handle: Option<JoinHandle<()>>,
}

impl TcpFront {
    /// Bind `addr` (use port 0 for ephemeral) and serve until stopped,
    /// with the default deadlines and no fault injection.
    pub fn start<S: RequestSink + ?Sized + 'static>(
        addr: &str,
        server: Arc<S>,
    ) -> Result<TcpFront> {
        Self::start_with(addr, server, FrontOptions::default(), None)
    }

    /// [`TcpFront::start`] with explicit deadlines and an optional
    /// fault plan (connection-domain events tick once per received
    /// frame).
    pub fn start_with<S: RequestSink + ?Sized + 'static>(
        addr: &str,
        server: Arc<S>,
        opts: FrontOptions,
        faults: Option<Arc<FaultPlan>>,
    ) -> Result<TcpFront> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let accept_handle = std::thread::Builder::new()
            .name("graft-accept".into())
            .spawn(move || {
                let mut conn_handles = Vec::new();
                let mut conn_id = 0u64;
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let server = server.clone();
                            let faults = faults.clone();
                            conn_id += 1;
                            let h = std::thread::Builder::new()
                                .name(format!("graft-conn-{conn_id}"))
                                .spawn(move || {
                                    let _ = handle_conn(
                                        stream, server, opts, faults,
                                    );
                                })
                                .expect("spawn connection thread");
                            conn_handles.push(h);
                        }
                        Err(e)
                            if e.kind()
                                == std::io::ErrorKind::WouldBlock =>
                        {
                            std::thread::sleep(
                                std::time::Duration::from_millis(2),
                            );
                        }
                        Err(_) => break,
                    }
                }
                for h in conn_handles {
                    let _ = h.join();
                }
            })
            .expect("spawn acceptor thread");
        Ok(TcpFront { addr: local, stop, accept_handle: Some(accept_handle) })
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

/// One connection: a reader loop submitting requests + a writer loop
/// pumping responses back (responses may arrive out of order thanks to
/// batching across stages).
fn handle_conn<S: RequestSink + ?Sized>(
    stream: TcpStream,
    server: Arc<S>,
    opts: FrontOptions,
    faults: Option<Arc<FaultPlan>>,
) -> Result<()> {
    let mut reader = stream.try_clone()?;
    reader.set_read_timeout(opts.idle_deadline)?;
    let writer = stream;
    writer.set_write_timeout(opts.write_timeout)?;
    let (tx, rx) = mpsc::channel::<Response>();

    let wh = std::thread::Builder::new()
        .name("graft-conn-writer".into())
        .spawn(move || -> Result<()> {
            let mut w = std::io::BufWriter::new(writer);
            // burst-drain: batched stages complete many responses at
            // once; write the whole burst, then flush a single time
            while let Ok(resp) = rx.recv() {
                write_frame(&mut w, &resp.encode())?;
                while let Ok(more) = rx.try_recv() {
                    write_frame(&mut w, &more.encode())?;
                }
                // a write-timeout (stalled peer) errors out of the
                // loop here, dropping `rx` senders' counterpart and
                // letting the reader tear the connection down
                w.flush()?;
            }
            Ok(())
        })
        .expect("spawn connection writer");

    loop {
        let frame = match read_frame(&mut reader) {
            Ok(f) => f,
            Err(e) => {
                // a deadline expiry surfaces as WouldBlock/TimedOut:
                // that is an eviction (slow-loris guard), not a close
                if let Some(ioe) =
                    e.downcast_ref::<std::io::Error>()
                {
                    if matches!(
                        ioe.kind(),
                        std::io::ErrorKind::WouldBlock
                            | std::io::ErrorKind::TimedOut
                    ) {
                        server.on_conn_evicted();
                    }
                }
                break; // client closed, stalled out, or errored
            }
        };
        if let Some(plan) = &faults {
            let mut dropped = false;
            for kind in plan.tick(FaultDomain::Conn) {
                match kind {
                    FaultKind::ConnDrop => dropped = true,
                    FaultKind::ConnDelay { ms } => {
                        std::thread::sleep(Duration::from_millis(ms));
                    }
                    _ => {}
                }
            }
            if dropped {
                break; // injected connection drop
            }
        }
        let req = Request::decode(&frame)?;
        server.submit(req, tx.clone());
    }
    drop(tx);
    let _ = wh.join();
    Ok(())
}

/// Bounded-retry policy: exponential backoff with full jitter
/// (`sleep ∈ [0, min(cap, base·2^attempt)]`, seeded and deterministic).
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    /// Total attempts (≥ 1); the last failure is returned.
    pub max_attempts: u32,
    /// First backoff ceiling; doubles per attempt.
    pub base: Duration,
    /// Hard ceiling on any single backoff.
    pub cap: Duration,
    /// Jitter seed, so tests replay identically.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self {
            max_attempts: 4,
            base: Duration::from_millis(20),
            cap: Duration::from_millis(500),
            seed: 0x9e3779b97f4a7c15,
        }
    }
}

impl RetryPolicy {
    /// Backoff before retry number `attempt` (0-based): full jitter in
    /// `[0, min(cap, base·2^attempt)]`.
    pub fn backoff(&self, attempt: u32, rng: &mut Rng) -> Duration {
        let ceil = self
            .base
            .saturating_mul(1u32 << attempt.min(16))
            .min(self.cap);
        ceil.mul_f64(rng.f64())
    }

    /// Run `op` up to `max_attempts` times, sleeping a jittered backoff
    /// between failures.
    pub fn retry<T, F: FnMut() -> Result<T>>(&self, mut op: F) -> Result<T> {
        let mut rng = Rng::seed_from_u64(self.seed);
        let mut attempt = 0u32;
        loop {
            match op() {
                Ok(v) => return Ok(v),
                Err(e) if attempt + 1 >= self.max_attempts.max(1) => {
                    return Err(e);
                }
                Err(_) => {
                    std::thread::sleep(self.backoff(attempt, &mut rng));
                    attempt += 1;
                }
            }
        }
    }
}

/// Blocking client helper: send requests, collect responses.  Keeps
/// its server address so a dead connection can be re-established by
/// [`TcpClient::send_with_retry`].
pub struct TcpClient {
    stream: TcpStream,
    addr: std::net::SocketAddr,
}

impl TcpClient {
    pub fn connect(addr: std::net::SocketAddr) -> Result<TcpClient> {
        Ok(TcpClient { stream: TcpStream::connect(addr)?, addr })
    }

    /// [`TcpClient::connect`] under a retry policy: transient refusals
    /// (the server mid-restart) are retried with jittered backoff.
    pub fn connect_with_retry(
        addr: std::net::SocketAddr,
        policy: &RetryPolicy,
    ) -> Result<TcpClient> {
        policy.retry(|| Self::connect(addr))
    }

    /// A second handle on the same connection (e.g. a dedicated reader
    /// thread while the original sends).
    pub fn try_clone(&self) -> Result<TcpClient> {
        Ok(TcpClient { stream: self.stream.try_clone()?, addr: self.addr })
    }

    /// Hard-close both directions (unblocks any reader clone).
    pub fn shutdown(&self) {
        let _ = self.stream.shutdown(std::net::Shutdown::Both);
    }

    pub fn send(&mut self, req: &Request) -> Result<()> {
        let mut w = std::io::BufWriter::new(self.stream.try_clone()?);
        write_frame(&mut w, &req.encode())?;
        w.flush()?;
        Ok(())
    }

    /// [`TcpClient::send`] under a retry policy: on failure the
    /// connection is re-established (same address) before the next
    /// attempt.  NOTE: retried sends are at-least-once from the
    /// server's point of view; callers that need exactly-once must
    /// deduplicate by request id.
    pub fn send_with_retry(
        &mut self,
        req: &Request,
        policy: &RetryPolicy,
    ) -> Result<()> {
        let per_req =
            ((req.client_id as u64) << 32) | req.seq as u64;
        let mut rng = Rng::seed_from_u64(policy.seed ^ per_req);
        let mut attempt = 0u32;
        loop {
            match self.send(req) {
                Ok(()) => return Ok(()),
                Err(e) if attempt + 1 >= policy.max_attempts.max(1) => {
                    return Err(e);
                }
                Err(_) => {
                    std::thread::sleep(policy.backoff(attempt, &mut rng));
                    if let Ok(fresh) = TcpClient::connect(self.addr) {
                        *self = fresh;
                    }
                    attempt += 1;
                }
            }
        }
    }

    pub fn recv(&mut self) -> Result<Response> {
        Response::decode(&read_frame(&mut self.stream)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_is_bounded_and_jittered() {
        let p = RetryPolicy {
            max_attempts: 5,
            base: Duration::from_millis(10),
            cap: Duration::from_millis(40),
            seed: 7,
        };
        let mut rng = Rng::seed_from_u64(p.seed);
        for attempt in 0..10 {
            let ceil = Duration::from_millis(10)
                .saturating_mul(1 << attempt.min(16))
                .min(p.cap);
            let b = p.backoff(attempt, &mut rng);
            assert!(b <= ceil, "attempt {attempt}: {b:?} > {ceil:?}");
        }
        // deterministic per seed
        let mut r1 = Rng::seed_from_u64(3);
        let mut r2 = Rng::seed_from_u64(3);
        assert_eq!(p.backoff(2, &mut r1), p.backoff(2, &mut r2));
    }

    #[test]
    fn retry_returns_first_success_and_last_failure() {
        let p = RetryPolicy {
            max_attempts: 3,
            base: Duration::from_millis(1),
            cap: Duration::from_millis(2),
            seed: 1,
        };
        let mut calls = 0;
        let out: Result<u32> = p.retry(|| {
            calls += 1;
            if calls < 3 {
                Err(anyhow::anyhow!("transient"))
            } else {
                Ok(42)
            }
        });
        assert_eq!(out.unwrap(), 42);
        assert_eq!(calls, 3);

        let mut calls = 0;
        let out: Result<u32> = p.retry(|| {
            calls += 1;
            Err(anyhow::anyhow!("permanent #{calls}"))
        });
        assert_eq!(calls, 3, "bounded attempts");
        assert!(out.unwrap_err().to_string().contains("#3"));
    }
}
