//! TCP front-end: clients send framed [`Request`]s over a socket (the
//! paper's data path uses network sockets from the mobile devices) and
//! receive framed [`Response`]s on the same connection.

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;

use anyhow::Result;

use super::messages::{read_frame, write_frame, Request, Response};
use super::server::RequestSink;

/// A running TCP acceptor in front of any [`RequestSink`] — a plain
/// [`crate::serving::Server`] or the live-reconfigurable
/// [`crate::runtime::LiveServer`] (connections survive plan swaps: the
/// sink reroutes each submit to the current serving core).
pub struct TcpFront {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_handle: Option<JoinHandle<()>>,
}

impl TcpFront {
    /// Bind `addr` (use port 0 for ephemeral) and serve until stopped.
    pub fn start<S: RequestSink + ?Sized + 'static>(
        addr: &str,
        server: Arc<S>,
    ) -> Result<TcpFront> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let accept_handle = std::thread::Builder::new()
            .name("graft-accept".into())
            .spawn(move || {
                let mut conn_handles = Vec::new();
                let mut conn_id = 0u64;
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let server = server.clone();
                            conn_id += 1;
                            let h = std::thread::Builder::new()
                                .name(format!("graft-conn-{conn_id}"))
                                .spawn(move || {
                                    let _ = handle_conn(stream, server);
                                })
                                .expect("spawn connection thread");
                            conn_handles.push(h);
                        }
                        Err(e)
                            if e.kind()
                                == std::io::ErrorKind::WouldBlock =>
                        {
                            std::thread::sleep(
                                std::time::Duration::from_millis(2),
                            );
                        }
                        Err(_) => break,
                    }
                }
                for h in conn_handles {
                    let _ = h.join();
                }
            })
            .expect("spawn acceptor thread");
        Ok(TcpFront { addr: local, stop, accept_handle: Some(accept_handle) })
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

/// One connection: a reader loop submitting requests + a writer loop
/// pumping responses back (responses may arrive out of order thanks to
/// batching across stages).
fn handle_conn<S: RequestSink + ?Sized>(
    stream: TcpStream,
    server: Arc<S>,
) -> Result<()> {
    let mut reader = stream.try_clone()?;
    let writer = stream;
    let (tx, rx) = mpsc::channel::<Response>();

    let wh = std::thread::Builder::new()
        .name("graft-conn-writer".into())
        .spawn(move || -> Result<()> {
            let mut w = std::io::BufWriter::new(writer);
            // burst-drain: batched stages complete many responses at
            // once; write the whole burst, then flush a single time
            while let Ok(resp) = rx.recv() {
                write_frame(&mut w, &resp.encode())?;
                while let Ok(more) = rx.try_recv() {
                    write_frame(&mut w, &more.encode())?;
                }
                w.flush()?;
            }
            Ok(())
        })
        .expect("spawn connection writer");

    loop {
        let frame = match read_frame(&mut reader) {
            Ok(f) => f,
            Err(_) => break, // client closed
        };
        let req = Request::decode(&frame)?;
        server.submit(req, tx.clone());
    }
    drop(tx);
    let _ = wh.join();
    Ok(())
}

/// Blocking client helper: send requests, collect responses.
pub struct TcpClient {
    stream: TcpStream,
}

impl TcpClient {
    pub fn connect(addr: std::net::SocketAddr) -> Result<TcpClient> {
        Ok(TcpClient { stream: TcpStream::connect(addr)? })
    }

    /// A second handle on the same connection (e.g. a dedicated reader
    /// thread while the original sends).
    pub fn try_clone(&self) -> Result<TcpClient> {
        Ok(TcpClient { stream: self.stream.try_clone()? })
    }

    /// Hard-close both directions (unblocks any reader clone).
    pub fn shutdown(&self) {
        let _ = self.stream.shutdown(std::net::Shutdown::Both);
    }

    pub fn send(&mut self, req: &Request) -> Result<()> {
        let mut w = std::io::BufWriter::new(self.stream.try_clone()?);
        write_frame(&mut w, &req.encode())?;
        w.flush()?;
        Ok(())
    }

    pub fn recv(&mut self) -> Result<Response> {
        Response::decode(&read_frame(&mut self.stream)?)
    }
}
