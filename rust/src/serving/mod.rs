//! Serving data path: request/response wire protocol, shared batch
//! queues (single-lock reference + per-instance sharded), the executor
//! materialising execution plans (thread-per-instance or pooled event
//! loop), and the TCP front-end.  Python never appears here — instances
//! run AOT artifacts through [`crate::runtime::Engine`].

pub mod batcher;
pub mod faults;
pub mod health;
pub mod messages;
pub mod server;
pub mod tcp;

pub use batcher::{BatchQueue, QueueMetrics, ShardedBatchQueue, WorkItem};
pub use faults::{
    FailureDomain, FaultDomain, FaultEvent, FaultKind, FaultPlan,
    FaultyExecutor,
};
pub use health::{
    GpuDegradation, HealthEvent, HealthEventKind, HealthRegistry,
    HealthScoreOptions,
};
pub use messages::{read_frame, write_frame, Request, Response};
pub use server::{
    ExecutorMode, FragmentExecutor, KillWorker, MockExecutor, RequestSink,
    Server, ServerCounters, ServerOptions,
};
pub use crate::obs::{ServerObs, SpanKind, TraceOptions};
pub use tcp::{FrontOptions, RetryPolicy, TcpClient, TcpFront};
