//! Serving data path: request/response wire protocol, shared batch
//! queues, the instance executor materialising execution plans, and the
//! TCP front-end.  Python never appears here — instances run AOT
//! artifacts through [`crate::runtime::Engine`].

pub mod batcher;
pub mod messages;
pub mod server;
pub mod tcp;

pub use batcher::{BatchQueue, WorkItem};
pub use messages::{read_frame, write_frame, Request, Response};
pub use server::{
    FragmentExecutor, MockExecutor, Server, ServerCounters, ServerOptions,
};
pub use tcp::{TcpClient, TcpFront};
