//! The serving executor + load balancer (paper §3 "executor").
//!
//! Materialises an [`ExecutionPlan`]: one batch queue per provisioned
//! stage, the paper's DNN instances consuming from it, alignment stages
//! chained into the shared stage (the paper pipes tensors between
//! fragments over unix sockets; we use in-process queues).  The load
//! balancer routes each client to its stage and drops requests that can
//! no longer meet their SLO (§3).
//!
//! Two executors materialise the same plan ([`ExecutorMode`]):
//!
//! * **`Threads`** — the reference path: one OS thread per planned
//!   instance blocking on a shared [`BatchQueue`] per stage.  Simple,
//!   but a 10k-client plan implies thousands of threads contending on a
//!   handful of stage mutexes.
//! * **`Pool`** (default) — an event-loop worker pool: `min(num_cpus,
//!   total_instances)` workers drive every *instance slot* of every
//!   stage.  Each stage owns a [`ShardedBatchQueue`] (one shard per
//!   instance, power-of-two-choices push routing, work-stealing pop),
//!   and pacing no longer sleeps a thread: a paced batch is parked in a
//!   deadline wheel and the worker immediately steals other ready work.
//!   When the plan carries GPU placements (`StagePlan::gpus`), slots
//!   are ordered by GPU so co-located instances share one worker's slot
//!   range, and [`ServerCounters`] tracks per-GPU busy share-time.
//!
//! Instances execute the *real* AOT-compiled fragment on PJRT, then pace
//! to the modeled MPS latency of their (batch, share) configuration —
//! CPU wall-clock says nothing about GPU shares, so pacing is what makes
//! queueing/batching dynamics faithful (`time_scale` scales modeled GPU
//! milliseconds to wall milliseconds; 0 disables pacing for tests).
//! Both modes produce the same response multiset for the same workload;
//! the concurrency test suite asserts it.

use std::collections::{BTreeMap, BinaryHeap, HashMap};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::batcher::{BatchQueue, ShardedBatchQueue, WorkItem};
use super::health::HealthRegistry;
use super::messages::{Request, Response};
use crate::coordinator::plan::ExecutionPlan;
use crate::obs::{Metric, ServerObs, SpanKind, Trace, TraceOptions};
use crate::profiler::{Alloc, CostModel, FragmentId};
use crate::runtime::{Engine, ExecOutput};
use crate::util::lock::{
    lock_counted, lock_recover, try_lock_counted, wait_timeout_recover,
};

/// Panic payload meaning "this instance is dead" (fault injection:
/// [`crate::serving::FaultyExecutor`] throws it mid-batch).  Caught at
/// the execution boundary; the doomed batch gets drop notices and the
/// instance retires — its shard reroutes to the survivors.
pub struct KillWorker;

/// Abstraction over fragment execution so the serving layer is testable
/// without artifacts (and so alternative backends can plug in).
pub trait FragmentExecutor: Send + Sync {
    fn execute(
        &self,
        model: &str,
        start: usize,
        end: usize,
        rows: &[Vec<f32>],
    ) -> Result<ExecOutput>;
}

impl FragmentExecutor for Engine {
    fn execute(
        &self,
        model: &str,
        start: usize,
        end: usize,
        rows: &[Vec<f32>],
    ) -> Result<ExecOutput> {
        self.run(model, start, end, rows)
    }
}

/// Deterministic stand-in executor for tests: output row = dim_out copies
/// of (sum of inputs) / dim_in.
pub struct MockExecutor {
    pub dims: HashMap<String, Vec<usize>>,
}

impl FragmentExecutor for MockExecutor {
    fn execute(
        &self,
        model: &str,
        _start: usize,
        end: usize,
        rows: &[Vec<f32>],
    ) -> Result<ExecOutput> {
        let dims = self
            .dims
            .get(model)
            .ok_or_else(|| anyhow!("unknown model {model}"))?;
        let dim_out = dims[end];
        let mut data = Vec::with_capacity(rows.len() * dim_out);
        for r in rows {
            let v = r.iter().sum::<f32>() / r.len() as f32;
            data.extend(std::iter::repeat(v).take(dim_out));
        }
        Ok(ExecOutput { data, batch: rows.len(), dim_out })
    }
}

/// Which serving core materialises the plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecutorMode {
    /// One OS thread per planned instance (the reference path).
    Threads,
    /// Event-loop worker pool over sharded queues + deadline wheel.
    #[default]
    Pool,
}

#[derive(Debug, Clone, Copy)]
pub struct ServerOptions {
    /// Wall-clock milliseconds per modeled GPU millisecond (1.0 = real
    /// time; 0.0 = no pacing).
    pub time_scale: f64,
    /// Drop requests that can no longer meet their SLO (paper §3).
    pub drop_on_slo: bool,
    /// Executor implementation (pooled by default).
    pub mode: ExecutorMode,
    /// Derive each stage's batch-formation window from its observed
    /// arrival rate (inter-arrival EWMA in the queue metrics) instead
    /// of the static planned window: wait only as long as the missing
    /// batch slots are expected to take to arrive, never longer than
    /// the planned window (which is the §4.3 SLO-queueing envelope, so
    /// the adaptive window always stays within the SLO headroom).  Off
    /// by default: the static window remains the reference behaviour.
    pub adaptive_window: bool,
    /// Per-request tracing (deterministic sampling; off by default).
    /// Sampled requests carry a span log through every pipeline hop;
    /// finished traces feed the server's [`ServerObs`] histograms.
    pub trace: TraceOptions,
}

impl Default for ServerOptions {
    fn default() -> Self {
        Self {
            time_scale: 1.0,
            drop_on_slo: true,
            mode: ExecutorMode::default(),
            adaptive_window: false,
            trace: TraceOptions::default(),
        }
    }
}

/// Per-request context travelling with a work item.
struct Ctx {
    client_id: u32,
    seq: u32,
    upstream_ms: f64,
    reply: mpsc::Sender<Response>,
    /// Sampled span log (None for untraced requests).  Boxed so the
    /// unsampled common case pays one pointer, not the span vector.
    trace: Option<Box<Trace>>,
}

/// A stage's queue: single-lock reference queue (Threads mode) or
/// per-instance shards (Pool mode).
enum StageQueue {
    Single(BatchQueue<Ctx>),
    Sharded(ShardedBatchQueue<Ctx>),
}

impl StageQueue {
    /// Push; a rejected item comes back (`Some`) so the caller can send
    /// its context a drop notice instead of losing it silently.
    fn push_or_return(&self, item: WorkItem<Ctx>) -> Option<WorkItem<Ctx>> {
        match self {
            StageQueue::Single(q) => q.push_or_return(item),
            StageQueue::Sharded(q) => q.push_or_return(item),
        }
    }

    /// Non-blocking pop of up to `max` items (dead-stage flushing).
    fn try_drain(&self, max: usize) -> Vec<WorkItem<Ctx>> {
        match self {
            StageQueue::Single(q) => {
                q.pop_batch_timeout(max, Duration::ZERO).unwrap_or_default()
            }
            StageQueue::Sharded(q) => q.try_pop_batch(0, max),
        }
    }

    fn len(&self) -> usize {
        match self {
            StageQueue::Single(q) => q.len(),
            StageQueue::Sharded(q) => q.len(),
        }
    }

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn close(&self) {
        match self {
            StageQueue::Single(q) => q.close(),
            StageQueue::Sharded(q) => q.close(),
        }
    }

    fn metrics(&self) -> &super::batcher::QueueMetrics {
        match self {
            StageQueue::Single(q) => q.metrics(),
            StageQueue::Sharded(q) => q.metrics(),
        }
    }

    fn rejected(&self) -> u64 {
        self.metrics().rejected()
    }
}

struct Stage {
    queue: StageQueue,
    frag: FragmentId,
    model_name: String,
    alloc: Alloc,
    /// Per-instance GPU assignment from the placed plan
    /// ([`crate::coordinator::StagePlan::gpus`]); empty for unplaced
    /// plans.
    gpus: Vec<u32>,
    /// Index of the downstream (shared) stage, if this is an alignment
    /// stage.
    next: Option<usize>,
    /// Pool mode: whether one of this stage's slots is currently in the
    /// batch-formation window.  Gates Free→Forming so a sub-batch
    /// backlog parks one FormCheck per stage, not one per instance.
    forming: AtomicBool,
    /// Items this stage has fully processed: responded, forwarded
    /// downstream, or dropped (SLO filter / executor error).  Together
    /// with the queue's `popped` metric this makes "drained" decidable
    /// — `queue empty ∧ completed == popped` means no batch of this
    /// stage is queued, executing, or parked in the pacing wheel
    /// (completion is only counted after delivery).  Live
    /// reconfiguration's graceful drain waits on exactly that.
    completed: AtomicU64,
    /// External requests the balancer routed into this stage (forwarded
    /// alignment output is *not* counted — that lands in the queue's
    /// `pushed` metric only), so the replan controller can read observed
    /// per-model arrival counts without double-counting pipeline hops.
    arrivals: AtomicU64,
    /// Per-instance death marks (worker kill / GPU failure).  A killed
    /// Threads-mode instance exits its loop; a killed Pool-mode
    /// instance's slot goes [`SlotState::Dead`] and its shard is closed.
    killed: Vec<AtomicBool>,
    /// Count of dead instances (== `killed` trues); when it reaches the
    /// instance count the stage has no consumer left and queued items
    /// are flushed with drop notices.
    dead: AtomicUsize,
}

/// Sentinel GPU id for instances of unplaced plans (sorts last, skips
/// the per-GPU counters).
const NO_GPU: u32 = u32::MAX;

impl Stage {
    /// Batch-formation window: the plan's throughput assumes batches of
    /// `alloc.batch`; greedy pop-1 under-delivers by the amortisation
    /// factor.  Waiting up to one planned execution time stays within
    /// the §4.3 worst-case-queueing envelope.
    ///
    /// With `opts.adaptive_window` the wait shrinks to the time the
    /// missing batch slots are *expected* to take at the observed
    /// arrival rate (EWMA over this stage's queue pushes), clamped to
    /// the planned window — under-provisioned bursts fire full batches
    /// just as fast, while a trickling stage stops idling a full
    /// planned window for stragglers that are not coming.
    fn window(&self, opts: ServerOptions) -> Duration {
        if opts.time_scale <= 0.0 || self.alloc.batch <= 1 {
            return Duration::ZERO;
        }
        let planned = self.alloc.latency_ms * opts.time_scale / 1e3;
        if opts.adaptive_window {
            let rate = self.queue.metrics().arrival_rate_rps();
            if rate > 0.0 {
                let fill_s = (self.alloc.batch - 1) as f64 / rate;
                return Duration::from_secs_f64(fill_s.min(planned));
            }
        }
        Duration::from_secs_f64(planned)
    }

    /// No live instance left: queued items can only be flushed.
    fn all_dead(&self) -> bool {
        self.dead.load(Ordering::SeqCst)
            >= self.alloc.instances.max(1) as usize
    }
}

/// Flush a consumer-less stage: every queued item gets a drop notice and
/// counts as completed, so the drain invariant (`empty ∧ completed ==
/// popped`) keeps holding with zero silent losses.
fn flush_dead_stage(stage: &Stage, counters: &ServerCounters) {
    loop {
        let batch = stage.queue.try_drain(64);
        if batch.is_empty() {
            return;
        }
        let n = batch.len() as u64;
        for item in batch {
            counters.dropped.fetch_add(1, Ordering::Relaxed);
            let upstream = item.ctx.upstream_ms;
            let _ = item.ctx.reply.send(Response::drop_notice(
                item.ctx.client_id,
                item.ctx.seq,
                item.accumulated_ms,
                upstream + item.accumulated_ms,
            ));
        }
        stage.completed.fetch_add(n, Ordering::SeqCst);
    }
}

/// Serving statistics counters.
#[derive(Debug, Default)]
pub struct ServerCounters {
    pub served: AtomicU64,
    pub dropped: AtomicU64,
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    /// Served requests whose server time exceeded their budget (should
    /// stay near zero: the balancer drops hopeless requests instead).
    pub budget_violations: AtomicU64,
    /// Work items refused by a closed queue (shutdown races); mirrors
    /// the per-queue `QueueMetrics::rejected` counters.
    pub rejected: AtomicU64,
    /// Poisoned-lock recoveries in the serving core (slot states, pool
    /// gates); per-queue recoveries are in `QueueMetrics::poisoned`.
    pub poisoned: AtomicU64,
    /// Stalled TCP connections evicted by the slow-loris guard.
    pub evicted: AtomicU64,
    /// Executor panics caught at the execution boundary (includes
    /// injected worker kills).
    pub exec_panics: AtomicU64,
    /// Per-GPU busy time in share-microseconds (modeled batch latency ×
    /// instance share), indexed by the placed plan's GPU ids.  Empty
    /// when the served plan carries no placement.
    pub gpu_busy_share_us: Vec<AtomicU64>,
}

impl ServerCounters {
    /// Counters sized for a plan placed on `gpus` GPUs.
    pub fn with_gpus(gpus: usize) -> Self {
        Self {
            gpu_busy_share_us: (0..gpus).map(|_| AtomicU64::new(0)).collect(),
            ..Default::default()
        }
    }

    fn record_gpu_busy(&self, gpu: u32, exec_ms: f64, share: u32) {
        if let Some(c) = self.gpu_busy_share_us.get(gpu as usize) {
            let us = (exec_ms * 1e3) as u64 * share as u64;
            c.fetch_add(us, Ordering::Relaxed);
        }
    }

    /// Per-GPU utilization over a wall window: modeled busy share-time
    /// divided by the window's share capacity (`max_share`, i.e. 100 ==
    /// one whole GPU).  Values can exceed 1.0 when pacing is off —
    /// modeled GPU time is then compressed into less wall time.
    pub fn gpu_utilization(&self, wall_ms: f64, max_share: u32) -> Vec<f64> {
        let denom = (wall_ms * 1e3 * max_share.max(1) as f64).max(1e-9);
        self.gpu_busy_share_us
            .iter()
            .map(|c| c.load(Ordering::Relaxed) as f64 / denom)
            .collect()
    }
}

/// Anything the front-ends can submit requests into: the [`Server`]
/// itself, or a live-reconfigurable wrapper around one
/// ([`crate::runtime::LiveServer`]).
pub trait RequestSink: Send + Sync {
    fn submit(&self, req: Request, reply: mpsc::Sender<Response>);

    /// A front-end evicted a stalled connection (slow-loris guard).
    /// Default: ignore; the [`Server`] counts it.
    fn on_conn_evicted(&self) {}
}

impl RequestSink for Server {
    fn submit(&self, req: Request, reply: mpsc::Sender<Response>) {
        Server::submit(self, req, reply)
    }

    fn on_conn_evicted(&self) {
        self.counters.evicted.fetch_add(1, Ordering::Relaxed);
    }
}

/// The running server.
pub struct Server {
    stages: Arc<Vec<Stage>>,
    routes: HashMap<u32, usize>,
    /// Joined by `shutdown`/`drain` (behind a mutex so both can run on
    /// a shared `&self` — live reconfiguration drains retired servers
    /// through an `Arc`).
    handles: Mutex<Vec<JoinHandle<()>>>,
    /// Executor threads spawned at start (stable after joins).
    n_threads: usize,
    pool: Option<Arc<PoolShared>>,
    pub counters: Arc<ServerCounters>,
    /// Failure ledger: instance/GPU deaths, heartbeats, epochs.
    health: Arc<HealthRegistry>,
    /// Tracing sink: sampled span logs + per-model latency histograms.
    obs: Arc<ServerObs>,
    /// The pacing scale this core runs under (0 = pacing off); the
    /// replan controller needs it to put the modeled envelope and the
    /// observed wall-clock latencies on the same axis.
    time_scale: f64,
}

impl Server {
    /// Spawn the executor for `plan` and return the running server.
    pub fn start(
        executor: Arc<dyn FragmentExecutor>,
        cm: &CostModel,
        plan: &ExecutionPlan,
        opts: ServerOptions,
    ) -> Server {
        let sharded = opts.mode == ExecutorMode::Pool;
        let (stages, routes) = build_stages(cm, plan, sharded);
        let stages = Arc::new(stages);
        let counters = Arc::new(ServerCounters::with_gpus(
            plan.placed_gpus().unwrap_or(0),
        ));
        let health = Arc::new(HealthRegistry::default());
        let obs = Arc::new(ServerObs::new(
            opts.trace,
            cm.config()
                .model_names()
                .iter()
                .map(|s| s.to_string())
                .collect(),
        ));
        match opts.mode {
            ExecutorMode::Threads => Self::start_threads(
                executor, cm, opts, stages, routes, counters, health, obs,
            ),
            ExecutorMode::Pool => Self::start_pool(
                executor, cm, opts, stages, routes, counters, health, obs,
            ),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn start_threads(
        executor: Arc<dyn FragmentExecutor>,
        cm: &CostModel,
        opts: ServerOptions,
        stages: Arc<Vec<Stage>>,
        routes: HashMap<u32, usize>,
        counters: Arc<ServerCounters>,
        health: Arc<HealthRegistry>,
        obs: Arc<ServerObs>,
    ) -> Server {
        let mut handles = Vec::new();
        for (idx, stage) in stages.iter().enumerate() {
            for inst in 0..stage.alloc.instances {
                let gpu = stage
                    .gpus
                    .get(inst as usize)
                    .copied()
                    .unwrap_or(NO_GPU);
                let stages = stages.clone();
                let executor = executor.clone();
                let cm = cm.clone();
                let counters = counters.clone();
                let health = health.clone();
                let obs = obs.clone();
                let h = std::thread::Builder::new()
                    .name(format!("graft-inst-{idx}.{inst}"))
                    // modest stacks keep thread-per-instance viable as a
                    // reference/bench baseline at large plans
                    .stack_size(1 << 20)
                    .spawn(move || {
                        let env = ExecEnv {
                            stages: stages.as_slice(),
                            executor: &*executor,
                            cm: &cm,
                            opts,
                            counters: &counters,
                            health: &health,
                            obs: &obs,
                            notify: None,
                        };
                        instance_loop(idx, inst as usize, gpu, &env);
                    })
                    .expect("spawn instance thread");
                handles.push(h);
            }
        }
        let n_threads = handles.len();
        Server {
            stages,
            routes,
            handles: Mutex::new(handles),
            n_threads,
            pool: None,
            counters,
            health,
            obs,
            time_scale: opts.time_scale,
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn start_pool(
        executor: Arc<dyn FragmentExecutor>,
        cm: &CostModel,
        opts: ServerOptions,
        stages: Arc<Vec<Stage>>,
        routes: HashMap<u32, usize>,
        counters: Arc<ServerCounters>,
        health: Arc<HealthRegistry>,
        obs: Arc<ServerObs>,
    ) -> Server {
        // GPU-affinity slot order: instances placed on the same GPU are
        // contiguous, so the even worker→cursor split below hands each
        // worker whole GPUs' worth of slots (one pacing wheel + slot
        // set per co-located group; stealing still covers everything).
        // Unplaced instances (NO_GPU) sort last in plan order.
        let mut order: Vec<(u32, usize, usize)> = Vec::new();
        for (idx, stage) in stages.iter().enumerate() {
            for shard in 0..stage.alloc.instances.max(1) as usize {
                let gpu = stage.gpus.get(shard).copied().unwrap_or(NO_GPU);
                order.push((gpu, idx, shard));
            }
        }
        order.sort_unstable();
        let slots: Vec<Slot> = order
            .into_iter()
            .map(|(gpu, stage, shard)| Slot {
                stage,
                shard,
                gpu,
                state: Mutex::new(SlotState::Free),
                doomed: AtomicBool::new(false),
            })
            .collect();
        let n_slots = slots.len();
        let workers = num_cpus().min(n_slots).max(1);
        let pool = Arc::new(PoolShared {
            stages: stages.clone(),
            slots,
            wheel: DeadlineWheel::default(),
            notifier: Notifier::default(),
            shutdown: AtomicBool::new(false),
            inflight: AtomicUsize::new(0),
        });
        let mut handles = Vec::new();
        for w in 0..workers {
            let pool = pool.clone();
            let executor = executor.clone();
            let cm = cm.clone();
            let counters = counters.clone();
            let health = health.clone();
            let obs = obs.clone();
            let cursor = if n_slots == 0 { 0 } else { w * n_slots / workers };
            let h = std::thread::Builder::new()
                .name(format!("graft-pool-{w}"))
                .spawn(move || {
                    let env = ExecEnv {
                        stages: pool.stages.as_slice(),
                        executor: &*executor,
                        cm: &cm,
                        opts,
                        counters: &counters,
                        health: &health,
                        obs: &obs,
                        notify: Some(&pool.notifier),
                    };
                    pool_worker(&pool, &env, cursor);
                })
                .expect("spawn pool worker");
            handles.push(h);
        }
        let n_threads = handles.len();
        Server {
            stages,
            routes,
            handles: Mutex::new(handles),
            n_threads,
            pool: Some(pool),
            counters,
            health,
            obs,
            time_scale: opts.time_scale,
        }
    }

    /// Submit a request; the response arrives on `reply`.  Every submit
    /// produces exactly one response: served, or an explicit drop
    /// notice (unknown client, dead stage, or a rejected push) — never
    /// a silent loss.
    pub fn submit(&self, req: Request, reply: mpsc::Sender<Response>) {
        match self.routes.get(&req.client_id) {
            Some(&idx) => {
                let stage = &self.stages[idx];
                stage.arrivals.fetch_add(1, Ordering::Relaxed);
                if stage.all_dead() {
                    // no consumer left (failed GPU / killed workers):
                    // fail fast instead of queueing into a void
                    self.counters.dropped.fetch_add(1, Ordering::Relaxed);
                    let _ = reply.send(Response::drop_notice(
                        req.client_id,
                        req.seq,
                        0.0,
                        req.upstream_ms,
                    ));
                    return;
                }
                // deterministic sampling: identical across runs and
                // executor modes, no effect on the response path
                let trace = if self.obs.opts.sample(req.client_id, req.seq) {
                    let mut t =
                        Trace::new(req.client_id, req.seq, req.model);
                    t.stamp(SpanKind::Enqueue);
                    Some(Box::new(t))
                } else {
                    None
                };
                let refused = stage.queue.push_or_return(WorkItem {
                    payload: req.payload,
                    server_arrival: Instant::now(),
                    budget_ms: req.budget_ms,
                    accumulated_ms: 0.0,
                    ctx: Ctx {
                        client_id: req.client_id,
                        seq: req.seq,
                        upstream_ms: req.upstream_ms,
                        reply,
                        trace,
                    },
                });
                match refused {
                    None => {
                        if let Some(p) = &self.pool {
                            p.notifier.notify();
                        }
                    }
                    Some(item) => {
                        // closed queue (shutdown race): reject *with* a
                        // notice — the client must never hang
                        self.counters.rejected.fetch_add(1, Ordering::Relaxed);
                        let upstream = item.ctx.upstream_ms;
                        let _ = item.ctx.reply.send(Response::drop_notice(
                            item.ctx.client_id,
                            item.ctx.seq,
                            0.0,
                            upstream,
                        ));
                    }
                }
            }
            None => {
                // unknown client: the balancer rejects outright
                let _ = reply.send(Response::drop_notice(
                    req.client_id,
                    req.seq,
                    0.0,
                    req.upstream_ms,
                ));
            }
        }
    }

    /// Whether a client currently has a route.
    pub fn has_route(&self, client_id: u32) -> bool {
        self.routes.contains_key(&client_id)
    }

    pub fn queue_depths(&self) -> Vec<usize> {
        self.stages.iter().map(|s| s.queue.len()).collect()
    }

    /// Work items rejected by closed stage queues (summed per queue).
    pub fn queue_rejections(&self) -> u64 {
        self.stages.iter().map(|s| s.queue.rejected()).sum()
    }

    /// Executor threads backing this server (instances or pool workers).
    pub fn thread_count(&self) -> usize {
        self.n_threads
    }

    /// Observed external arrivals per model (the balancer's routed
    /// submit counts, *not* inter-stage forwards), summed over each
    /// model's entry stages.  The replan controller diffs successive
    /// snapshots to get observed per-model arrival rates.
    pub fn model_arrivals(&self) -> HashMap<String, u64> {
        let mut out: HashMap<String, u64> = HashMap::new();
        for s in self.stages.iter() {
            *out.entry(s.model_name.clone()).or_insert(0) +=
                s.arrivals.load(Ordering::Relaxed);
        }
        out
    }

    /// Observed arrival rate (rps, inter-arrival EWMA) of each stage's
    /// queue, in stage order — the signal behind adaptive batch
    /// windows, exposed for tests and the controller's diagnostics.
    pub fn stage_arrival_rates(&self) -> Vec<f64> {
        self.stages
            .iter()
            .map(|s| s.queue.metrics().arrival_rate_rps())
            .collect()
    }

    /// GPUs the served plan was placed on (0 for unplaced plans — the
    /// per-GPU utilization counters are absent then).
    pub fn gpu_count(&self) -> usize {
        self.counters.gpu_busy_share_us.len()
    }

    /// The server's failure ledger (instance/GPU deaths, heartbeats).
    pub fn health(&self) -> Arc<HealthRegistry> {
        self.health.clone()
    }

    /// The server's tracing sink (sampled span logs + per-model
    /// latency histograms).
    pub fn obs(&self) -> Arc<ServerObs> {
        self.obs.clone()
    }

    /// The pacing scale this core was started with (0 = pacing off).
    pub fn time_scale(&self) -> f64 {
        self.time_scale
    }

    /// Emit this server's metrics under the canonical registry names —
    /// the ONE place serving/queue/health/trace counters are named, so
    /// the `[serve]` stats line, bench JSON dumps and the `/metrics`
    /// endpoint can never disagree.  Registered into a
    /// [`crate::obs::MetricsRegistry`] by the embedding code.
    pub fn collect_metrics(&self, out: &mut Vec<Metric>) {
        let c = |n: &str| format!("graft_serving_{n}_total");
        let load = |a: &AtomicU64| a.load(Ordering::Relaxed);
        out.push(Metric::counter(c("served"), load(&self.counters.served)));
        out.push(Metric::counter(c("dropped"), load(&self.counters.dropped)));
        out.push(Metric::counter(c("batches"), load(&self.counters.batches)));
        out.push(Metric::counter(
            c("batched_requests"),
            load(&self.counters.batched_requests),
        ));
        out.push(Metric::counter(
            c("budget_violations"),
            load(&self.counters.budget_violations),
        ));
        out.push(Metric::counter(c("rejected"), load(&self.counters.rejected)));
        out.push(Metric::counter(c("evicted"), load(&self.counters.evicted)));
        out.push(Metric::counter(
            c("exec_panics"),
            load(&self.counters.exec_panics),
        ));
        out.push(Metric::counter(
            c("poison_recoveries"),
            self.poison_recoveries(),
        ));
        for (i, s) in self.stages.iter().enumerate() {
            let m = s.queue.metrics();
            let stage = i.to_string();
            out.push(
                Metric::counter("graft_queue_pushed_total", m.pushed())
                    .with_label("stage", &stage),
            );
            out.push(
                Metric::counter("graft_queue_popped_total", m.popped())
                    .with_label("stage", &stage),
            );
            out.push(
                Metric::counter("graft_queue_rejected_total", m.rejected())
                    .with_label("stage", &stage),
            );
            out.push(
                Metric::gauge("graft_queue_depth", s.queue.len() as f64)
                    .with_label("stage", &stage),
            );
            out.push(
                Metric::gauge(
                    "graft_queue_arrival_rate_rps",
                    m.arrival_rate_rps(),
                )
                .with_label("stage", &stage),
            );
        }
        for (gpu, busy) in self.counters.gpu_busy_share_us.iter().enumerate() {
            out.push(
                Metric::counter("graft_gpu_busy_share_us_total", load(busy))
                    .with_label("gpu", gpu.to_string()),
            );
        }
        // health ledger
        out.push(Metric::counter(
            "graft_health_failure_epoch_total",
            self.health.failure_epoch(),
        ));
        out.push(Metric::counter(
            "graft_health_recovery_epoch_total",
            self.health.recovery_epoch(),
        ));
        out.push(Metric::gauge(
            "graft_health_dead_instances",
            self.health.dead_instance_count() as f64,
        ));
        out.push(Metric::gauge(
            "graft_health_dead_gpus",
            self.health.failed_gpus().len() as f64,
        ));
        out.push(Metric::gauge(
            "graft_health_degraded_gpus",
            self.health.gpu_degradations().len() as f64,
        ));
        for (gpu, score) in self.health.gpu_scores() {
            out.push(
                Metric::gauge("graft_health_gpu_score", score)
                    .with_label("gpu", gpu.to_string()),
            );
        }
        // tracing: finished sampled traces + per-model components
        out.push(Metric::counter(
            "graft_trace_requests_total",
            self.obs.traced_count(),
        ));
        for (_, name, lat) in self.obs.models() {
            if lat.e2e.is_empty() {
                continue;
            }
            for (comp, h) in lat.components() {
                out.push(
                    Metric::histogram(
                        format!("graft_trace_{comp}_ms"),
                        h.snapshot(),
                    )
                    .with_label("model", name),
                );
            }
        }
    }

    /// Instance counts per stage, in stage order (chaos targeting).
    pub fn stage_instances(&self) -> Vec<usize> {
        self.stages
            .iter()
            .map(|s| s.alloc.instances.max(1) as usize)
            .collect()
    }

    /// Poisoned-lock recoveries observed by this server: serving-core
    /// locks plus every stage queue.
    pub fn poison_recoveries(&self) -> u64 {
        self.counters.poisoned.load(Ordering::Relaxed)
            + self
                .stages
                .iter()
                .map(|s| s.queue.metrics().poisoned())
                .sum::<u64>()
    }

    /// Kill one instance: mark it dead, close its queue shard (Pool
    /// mode: the backlog reroutes to surviving shards), doom its slot,
    /// and — if it was the stage's last instance — flush the stage's
    /// remaining items with drop notices.  Returns `false` if the
    /// instance was already dead (idempotent).
    pub fn kill_instance(&self, stage_idx: usize, inst: usize) -> bool {
        let Some(stage) = self.stages.get(stage_idx) else { return false };
        let gpu = stage.gpus.get(inst).copied().unwrap_or(NO_GPU);
        if !retire_instance(
            self.stages.as_slice(),
            &self.health,
            &self.counters,
            stage_idx,
            inst,
            gpu,
        ) {
            return false;
        }
        if let Some(p) = &self.pool {
            if let Some(slot) = p
                .slots
                .iter()
                .find(|s| s.stage == stage_idx && s.shard == inst)
            {
                doom_slot(stage, slot, &self.counters);
            }
            p.notifier.force_notify();
        }
        true
    }

    /// Fail a whole GPU: every co-located instance dies at once (the
    /// ParvaGPU-style failure domain).  Returns the number of instances
    /// killed.  The health ledger records the GPU death even when no
    /// instance was placed on it.
    pub fn fail_gpu(&self, gpu: u32) -> usize {
        self.health.mark_gpu_down(gpu);
        let mut killed = 0;
        for (idx, stage) in self.stages.iter().enumerate() {
            for inst in 0..stage.alloc.instances.max(1) as usize {
                if stage.gpus.get(inst).copied().unwrap_or(NO_GPU) == gpu
                    && self.kill_instance(idx, inst)
                {
                    killed += 1;
                }
            }
        }
        killed
    }

    /// Out-of-band health warning against a GPU (telemetry / operator /
    /// injected fault): bumps the GPU's predictive fault level so the
    /// controller can proactively migrate off it before it dies.
    pub fn warn_gpu(&self, gpu: u32) {
        self.health.record_gpu_warning(gpu);
    }

    /// Partial-GPU failure: the GPU loses `share_loss` compute share and
    /// `mem_loss_mb` MB of memory but keeps serving.  The controller
    /// folds the residual capacity into the next placement.
    pub fn degrade_gpu(&self, gpu: u32, share_loss: u32, mem_loss_mb: f64) {
        self.health.mark_gpu_degraded(gpu, share_loss, mem_loss_mb);
    }

    /// A failed or degraded GPU came back at full capacity; the
    /// controller drains the recovery and lifts the GPU from its hard
    /// avoid-set.  Returns whether any ledger state was cleared.
    pub fn recover_gpu(&self, gpu: u32) -> bool {
        self.health.mark_gpu_recovered(gpu)
    }

    /// Predictive health score per observed GPU (0 healthy → 1 dying).
    pub fn gpu_health_scores(&self) -> BTreeMap<u32, f64> {
        self.health.gpu_scores()
    }

    /// Chaos hook: poison one stage queue's lock (shard `shard` in Pool
    /// mode; the single queue in Threads mode) the way a panicking
    /// consumer would.  The queue recovers on the next acquisition and
    /// counts it; the ledger records the event.
    pub fn poison_stage_queue(&self, stage_idx: usize, shard: usize) {
        match &self.stages[stage_idx].queue {
            StageQueue::Single(q) => q.poison(),
            StageQueue::Sharded(q) => q.poison_shard(shard),
        }
        self.health.mark_shard_poisoned(stage_idx, shard);
    }

    /// Close all queues and join the executor threads.  Fast but
    /// *unordered*: an alignment batch still in flight can find its
    /// downstream queue already closed and lose the items (counted in
    /// `rejected`).  Fine for end-of-process teardown; live
    /// reconfiguration uses [`Self::drain`] instead.
    pub fn shutdown(self) {
        for s in self.stages.iter() {
            s.queue.close();
        }
        if let Some(p) = &self.pool {
            p.shutdown.store(true, Ordering::SeqCst);
            p.notifier.force_notify();
        }
        for h in lock_recover(&self.handles).drain(..) {
            let _ = h.join();
        }
    }

    /// Whether a stage has nothing queued, executing, or parked in the
    /// pacing wheel: every popped item has been delivered and the queue
    /// is empty.  Reading the queue before `completed` would race (pop
    /// empties the queue before the delivery count catches up), so
    /// `completed == popped` is checked *after* emptiness — a consumer
    /// between pop and delivery still holds `completed < popped`.
    fn stage_drained(s: &Stage) -> bool {
        s.queue.is_empty()
            && s.completed.load(Ordering::SeqCst) == s.queue.metrics().popped()
    }

    /// Graceful ordered drain for live reconfiguration: stop taking new
    /// work and let every in-flight request finish — nothing is dropped
    /// and nothing is lost to a closed downstream queue.
    ///
    /// The stage DAG is two layers deep (alignment → shared), so two
    /// waves suffice: close the alignment queues, wait until each is
    /// empty with all popped items delivered (their outputs are pushed
    /// into the still-open shared queues), then close the shared queues
    /// and wait again.  Only then are the executors stopped and joined.
    /// The caller must have stopped external submissions first (the
    /// live server atomically reroutes them before draining).
    pub fn drain(&self) {
        let wave = |pred: &dyn Fn(&Stage) -> bool| {
            for s in self.stages.iter().filter(|&s| pred(s)) {
                s.queue.close();
            }
            if let Some(p) = &self.pool {
                p.notifier.force_notify();
            }
            while !self
                .stages
                .iter()
                .filter(|&s| pred(s))
                .all(Self::stage_drained)
            {
                // a stage that lost its last instance has no consumer:
                // flush it from here so the drain can never deadlock on
                // a dead stage's backlog (exact accounting holds — the
                // flush counts popped == completed with drop notices)
                for s in self.stages.iter().filter(|&s| pred(s)) {
                    if s.all_dead() && !s.queue.is_empty() {
                        flush_dead_stage(s, &self.counters);
                    }
                }
                std::thread::sleep(Duration::from_micros(200));
            }
        };
        wave(&|s: &Stage| s.next.is_some()); // alignment stages
        wave(&|s: &Stage| s.next.is_none()); // shared stages
        if let Some(p) = &self.pool {
            p.shutdown.store(true, Ordering::SeqCst);
            p.notifier.force_notify();
        }
        for h in lock_recover(&self.handles).drain(..) {
            let _ = h.join();
        }
    }
}

/// Shared instance-retirement core (Threads: the dying loop calls it on
/// itself; Pool: the worker that caught the kill, or
/// [`Server::kill_instance`]).  Idempotent via the `killed` flag.
fn retire_instance(
    stages: &[Stage],
    health: &HealthRegistry,
    counters: &ServerCounters,
    stage_idx: usize,
    inst: usize,
    gpu: u32,
) -> bool {
    let stage = &stages[stage_idx];
    if stage
        .killed
        .get(inst)
        .map_or(true, |k| k.swap(true, Ordering::SeqCst))
    {
        return false; // unknown instance or already dead
    }
    stage.dead.fetch_add(1, Ordering::SeqCst);
    health.mark_instance_down(stage_idx, inst, gpu);
    if let StageQueue::Sharded(q) = &stage.queue {
        // the dead instance's backlog reroutes to surviving shards
        q.close_shard(inst);
    }
    if stage.all_dead() {
        flush_dead_stage(stage, counters);
    }
    true
}

/// Mark a Pool-mode slot dead: never dispatched again.  A Busy slot is
/// only doomed — `free_slot` finishes the transition when its in-flight
/// batch delivers.
fn doom_slot(stage: &Stage, slot: &Slot, counters: &ServerCounters) {
    slot.doomed.store(true, Ordering::SeqCst);
    if let Some(mut st) = try_lock_counted(&slot.state, Some(&counters.poisoned))
    {
        match *st {
            SlotState::Busy | SlotState::Dead => {}
            SlotState::Forming { .. } => {
                stage.forming.store(false, Ordering::SeqCst);
                *st = SlotState::Dead;
            }
            SlotState::Free => *st = SlotState::Dead,
        }
    }
}

fn num_cpus() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Build the stage vector + client routing table for `plan`.
fn build_stages(
    cm: &CostModel,
    plan: &ExecutionPlan,
    sharded: bool,
) -> (Vec<Stage>, HashMap<u32, usize>) {
    let new_queue = |alloc: &Alloc| {
        if sharded {
            StageQueue::Sharded(ShardedBatchQueue::new(
                alloc.instances.max(1) as usize,
            ))
        } else {
            StageQueue::Single(BatchQueue::new())
        }
    };
    let killed_for = |alloc: &Alloc| {
        (0..alloc.instances.max(1))
            .map(|_| AtomicBool::new(false))
            .collect::<Vec<_>>()
    };
    let mut stages: Vec<Stage> = Vec::new();
    let mut routes = HashMap::new();
    for set in &plan.sets {
        let model_name = cm.config().models[set.model].name.clone();
        let shared_idx = stages.len();
        stages.push(Stage {
            queue: new_queue(&set.shared.alloc),
            frag: set.shared.frag,
            model_name: model_name.clone(),
            alloc: set.shared.alloc,
            gpus: set.shared.gpus.clone(),
            next: None,
            forming: AtomicBool::new(false),
            completed: AtomicU64::new(0),
            arrivals: AtomicU64::new(0),
            killed: killed_for(&set.shared.alloc),
            dead: AtomicUsize::new(0),
        });
        for m in &set.members {
            let entry = match &m.align {
                Some(a) => {
                    let idx = stages.len();
                    stages.push(Stage {
                        queue: new_queue(&a.alloc),
                        frag: a.frag,
                        model_name: model_name.clone(),
                        alloc: a.alloc,
                        gpus: a.gpus.clone(),
                        next: Some(shared_idx),
                        forming: AtomicBool::new(false),
                        completed: AtomicU64::new(0),
                        arrivals: AtomicU64::new(0),
                        killed: killed_for(&a.alloc),
                        dead: AtomicUsize::new(0),
                    });
                    idx
                }
                None => shared_idx,
            };
            for c in &m.spec.clients {
                routes.insert(c.0, entry);
            }
        }
    }
    (stages, routes)
}

/// Everything a batch needs besides the batch itself; shared by the
/// thread-per-instance loop and the pool workers so both paths run the
/// exact same SLO-drop / execute / deliver logic.
struct ExecEnv<'a> {
    stages: &'a [Stage],
    executor: &'a dyn FragmentExecutor,
    cm: &'a CostModel,
    opts: ServerOptions,
    counters: &'a ServerCounters,
    health: &'a HealthRegistry,
    obs: &'a ServerObs,
    /// Pool notifier for inter-stage forwards (None in Threads mode:
    /// the BatchQueue condvar wakes the consumer directly).
    notify: Option<&'a Notifier>,
}

/// Round a formed batch up to the nearest compiled bucket.
fn bucket_for(cm: &CostModel, n: usize) -> u32 {
    let buckets = &cm.config().gpu.batch_buckets;
    buckets
        .iter()
        .copied()
        .find(|&b| b as usize >= n)
        .unwrap_or(*buckets.last().unwrap())
}

/// SLO-drop: discard items that cannot finish in time even if executed
/// right now (paper: the balancer drops SLO misses).  Sends the drop
/// notices and returns the surviving items.
fn slo_filter(
    env: &ExecEnv<'_>,
    stage: &Stage,
    mut batch: Vec<WorkItem<Ctx>>,
) -> Vec<WorkItem<Ctx>> {
    for item in batch.iter_mut() {
        if let Some(t) = item.ctx.trace.as_deref_mut() {
            t.stamp(SpanKind::BatchForm);
        }
    }
    let exec_ms_probe = env.cm.latency_ms(
        stage.frag,
        bucket_for(env.cm, batch.len()),
        stage.alloc.share,
    );
    let mut live: Vec<WorkItem<Ctx>> = Vec::with_capacity(batch.len());
    for item in batch {
        let elapsed = item.server_arrival.elapsed().as_secs_f64() * 1e3;
        // pacing-sleep overshoot + scheduling noise margin: serve a
        // request that would finish marginally late and it becomes an
        // SLO violation instead of a clean drop
        const NOISE_MARGIN_MS: f64 = 3.0;
        // With pacing, wall-clock elapsed already contains earlier
        // stages' (paced) execution — adding accumulated_ms would
        // double-count it; without pacing, modeled time is all there is.
        let so_far = if env.opts.time_scale > 0.0 {
            scaled_elapsed(elapsed, env.opts)
        } else {
            item.accumulated_ms
        };
        let projected = so_far
            + exec_ms_probe
            + remaining_ms(stage, env.stages, exec_ms_probe)
            + NOISE_MARGIN_MS;
        if env.opts.drop_on_slo && projected > item.budget_ms {
            env.counters.dropped.fetch_add(1, Ordering::Relaxed);
            let upstream = item.ctx.upstream_ms;
            let _ = item.ctx.reply.send(Response::drop_notice(
                item.ctx.client_id,
                item.ctx.seq,
                so_far,
                upstream + so_far,
            ));
            // a drop notice is a completed outcome for drain accounting
            stage.completed.fetch_add(1, Ordering::SeqCst);
            continue;
        }
        live.push(item);
    }
    live
}

/// Run the fragment on the executor backend; returns the raw result,
/// the modeled MPS latency of this (batch, share) configuration, and
/// whether the executor demanded the instance's death ([`KillWorker`]).
/// `gpu` attributes the modeled busy time to the hosting GPU's
/// utilization counter ([`NO_GPU`] = unplaced, not attributed).
///
/// Executor panics are caught here — the panic boundary of the serving
/// core.  A panic maps onto the existing `Err` delivery path (drop
/// notices + exact completion accounting), so one bad batch or one
/// injected kill can never wedge a worker or skew the drain invariant.
fn execute_batch(
    env: &ExecEnv<'_>,
    stage: &Stage,
    stage_idx: usize,
    inst: usize,
    gpu: u32,
    live: &mut [WorkItem<Ctx>],
) -> (Result<ExecOutput>, f64, bool) {
    let rows: Vec<Vec<f32>> = live.iter().map(|i| i.payload.clone()).collect();
    let exec_ms = env.cm.latency_ms(
        stage.frag,
        bucket_for(env.cm, rows.len()),
        stage.alloc.share,
    );
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        env.executor.execute(
            &stage.model_name,
            stage.frag.start,
            stage.frag.end,
            &rows,
        )
    }));
    let (out, kill) = match caught {
        Ok(res) => (res, false),
        Err(payload) => {
            env.counters.exec_panics.fetch_add(1, Ordering::Relaxed);
            // feed the predictive fault level: panics against the same
            // instance/GPU accumulate faster than clean beats forgive
            env.health.record_exec_panic(stage_idx, inst, gpu);
            let kill = payload.is::<KillWorker>();
            (
                Err(anyhow!(
                    "executor panicked{}",
                    if kill { " (instance killed)" } else { "" }
                )),
                kill,
            )
        }
    };
    env.counters.batches.fetch_add(1, Ordering::Relaxed);
    env.counters
        .batched_requests
        .fetch_add(rows.len() as u64, Ordering::Relaxed);
    env.counters.record_gpu_busy(gpu, exec_ms, stage.alloc.share);
    for item in live.iter_mut() {
        if let Some(t) = item.ctx.trace.as_deref_mut() {
            t.stamp(SpanKind::Execute);
        }
    }
    (out, exec_ms, kill)
}

/// Deliver an executed batch: forward alignment output downstream or
/// send the final responses.  Shared by both executor modes.
fn deliver(
    env: &ExecEnv<'_>,
    stage: &Stage,
    mut live: Vec<WorkItem<Ctx>>,
    out: Result<ExecOutput>,
    exec_ms: f64,
) {
    // deliver() runs after the pacing gate in both executor modes
    // (Threads: the post-execute sleep; Pool: the wheel's BatchDone)
    for item in live.iter_mut() {
        if let Some(t) = item.ctx.trace.as_deref_mut() {
            t.stamp(SpanKind::PaceRelease);
        }
    }
    // every item of this batch reaches a final outcome below (respond,
    // forward, or drop) — count them all as completed for the drain
    // accounting once the outcomes are delivered
    let n_live = live.len() as u64;
    let out = match out {
        Ok(o) => o,
        Err(_) => {
            for item in live {
                env.counters.dropped.fetch_add(1, Ordering::Relaxed);
                let upstream = item.ctx.upstream_ms;
                let _ = item.ctx.reply.send(Response::drop_notice(
                    item.ctx.client_id,
                    item.ctx.seq,
                    0.0,
                    upstream,
                ));
            }
            stage.completed.fetch_add(n_live, Ordering::SeqCst);
            return;
        }
    };
    let mut forwarded = false;
    for (i, mut item) in live.into_iter().enumerate() {
        let row = out.data[i * out.dim_out..(i + 1) * out.dim_out].to_vec();
        let acc = item.accumulated_ms + exec_ms;
        match stage.next {
            Some(next) => {
                let ns = &env.stages[next];
                if ns.all_dead() {
                    // downstream lost its last instance: fail fast with
                    // a notice instead of queueing into a void
                    env.counters.dropped.fetch_add(1, Ordering::Relaxed);
                    let upstream = item.ctx.upstream_ms;
                    let _ = item.ctx.reply.send(Response::drop_notice(
                        item.ctx.client_id,
                        item.ctx.seq,
                        acc,
                        upstream + acc,
                    ));
                    continue;
                }
                if let Some(t) = item.ctx.trace.as_deref_mut() {
                    // the hop closes with Deliver; the next hop opens
                    // with Enqueue at the downstream push
                    t.stamp(SpanKind::Deliver);
                    t.stamp(SpanKind::Enqueue);
                }
                let refused = ns.queue.push_or_return(WorkItem {
                    payload: row,
                    server_arrival: item.server_arrival,
                    budget_ms: item.budget_ms,
                    accumulated_ms: acc,
                    ctx: item.ctx,
                });
                match refused {
                    None => forwarded = true,
                    Some(item) => {
                        // closed downstream queue (shutdown race): the
                        // item comes back so its client still gets an
                        // explicit notice — no silent loss
                        env.counters.rejected.fetch_add(1, Ordering::Relaxed);
                        env.counters.dropped.fetch_add(1, Ordering::Relaxed);
                        let upstream = item.ctx.upstream_ms;
                        let _ = item.ctx.reply.send(Response::drop_notice(
                            item.ctx.client_id,
                            item.ctx.seq,
                            acc,
                            upstream + acc,
                        ));
                    }
                }
            }
            None => {
                let elapsed =
                    item.server_arrival.elapsed().as_secs_f64() * 1e3;
                // with pacing, wall time already covers exec; without,
                // report modeled time
                let server_ms = if env.opts.time_scale > 0.0 {
                    scaled_elapsed(elapsed, env.opts)
                } else {
                    acc
                };
                env.counters.served.fetch_add(1, Ordering::Relaxed);
                if server_ms > item.budget_ms {
                    env.counters
                        .budget_violations
                        .fetch_add(1, Ordering::Relaxed);
                    if std::env::var_os("GRAFT_DEBUG_EXEC").is_some() {
                        eprintln!(
                            "[violation] client {} server {:.1} > budget {:.1} (exec {:.1}, batch {})",
                            item.ctx.client_id,
                            server_ms,
                            item.budget_ms,
                            exec_ms,
                            out.batch
                        );
                    }
                }
                let _ = item.ctx.reply.send(Response {
                    client_id: item.ctx.client_id,
                    seq: item.ctx.seq,
                    server_ms,
                    e2e_ms: item.ctx.upstream_ms + server_ms,
                    dropped: false,
                    output: row,
                });
                // only *served* requests feed the trace sink; drop and
                // reject paths discard their trace, so tracing can
                // never perturb the response stream
                if let Some(mut t) = item.ctx.trace.take() {
                    t.stamp(SpanKind::Deliver);
                    env.obs.record(*t);
                }
            }
        }
    }
    stage.completed.fetch_add(n_live, Ordering::SeqCst);
    if forwarded {
        if let Some(n) = env.notify {
            n.notify();
        }
    }
}

/// Thread-per-instance executor loop (ExecutorMode::Threads).
fn instance_loop(stage_idx: usize, inst: usize, gpu: u32, env: &ExecEnv<'_>) {
    let stage = &env.stages[stage_idx];
    let queue = match &stage.queue {
        StageQueue::Single(q) => q,
        StageQueue::Sharded(_) => {
            unreachable!("Threads mode uses the single reference queue")
        }
    };
    loop {
        // a kill (fail_gpu / kill_instance) lands between batches; the
        // timed pop below bounds how long it can go unnoticed
        if stage.killed[inst].load(Ordering::SeqCst) {
            break;
        }
        // recomputed per batch: the adaptive window tracks the live
        // arrival-rate EWMA (constant when adaptive_window is off)
        let window = stage.window(env.opts);
        let batch = if window.is_zero() {
            queue.pop_batch_timeout(
                stage.alloc.batch as usize,
                Duration::from_millis(50),
            )
        } else {
            queue.pop_batch_window(stage.alloc.batch as usize, window)
        };
        let Some(mut batch) = batch else { break };
        if batch.is_empty() {
            continue;
        }
        for item in batch.iter_mut() {
            if let Some(t) = item.ctx.trace.as_deref_mut() {
                t.stamp(SpanKind::ShardPop);
            }
        }
        let mut live = slo_filter(env, stage, batch);
        if live.is_empty() {
            continue;
        }
        let t0 = Instant::now();
        let (out, exec_ms, kill) =
            execute_batch(env, stage, stage_idx, inst, gpu, &mut live);
        // pace to the modeled MPS latency
        if env.opts.time_scale > 0.0 {
            let target = exec_ms * env.opts.time_scale / 1e3;
            let spent = t0.elapsed().as_secs_f64();
            if std::env::var_os("GRAFT_DEBUG_EXEC").is_some()
                && spent * 1e3 > exec_ms
            {
                eprintln!(
                    "[exec overrun] wall {:.1} ms vs modeled {:.1} ms (batch {})",
                    spent * 1e3,
                    exec_ms,
                    live.len()
                );
            }
            if target > spent {
                std::thread::sleep(Duration::from_secs_f64(target - spent));
            }
        }
        deliver(env, stage, live, out, exec_ms);
        env.health.beat_live(stage_idx, inst, gpu);
        if kill {
            // the batch got its drop notices above; now the thread dies
            retire_instance(
                env.stages,
                env.health,
                env.counters,
                stage_idx,
                inst,
                gpu,
            );
            break;
        }
    }
}

/// Wall-clock elapsed converted back to modeled GPU milliseconds.
fn scaled_elapsed(elapsed_wall_ms: f64, opts: ServerOptions) -> f64 {
    if opts.time_scale > 0.0 {
        elapsed_wall_ms / opts.time_scale
    } else {
        0.0
    }
}

/// Lower-bound on the time a request still needs after this stage.
fn remaining_ms(stage: &Stage, stages: &[Stage], _probe: f64) -> f64 {
    match stage.next {
        Some(next) => {
            let s = &stages[next];
            // best case: batch of 1 at the shared stage's share
            s.alloc.latency_ms.min(
                s.alloc.latency_ms / s.alloc.batch.max(1) as f64,
            )
        }
        None => 0.0,
    }
}

// ---------------------------------------------------------------------------
// Pooled executor (ExecutorMode::Pool)
// ---------------------------------------------------------------------------

/// Idle-worker wakeup: waiters register in `idle`, wakers bump `seq`
/// under `gate` — pushes on the hot path skip the lock entirely while
/// every worker is busy.
#[derive(Default)]
struct Notifier {
    idle: AtomicUsize,
    seq: AtomicU64,
    gate: Mutex<()>,
    cv: Condvar,
}

impl Notifier {
    fn epoch(&self) -> u64 {
        self.seq.load(Ordering::SeqCst)
    }

    /// Wake idle workers, if any (cheap no-op while all are busy).
    fn notify(&self) {
        if self.idle.load(Ordering::SeqCst) > 0 {
            self.force_notify();
        }
    }

    fn force_notify(&self) {
        let g = lock_recover(&self.gate);
        self.seq.fetch_add(1, Ordering::SeqCst);
        drop(g);
        self.cv.notify_all();
    }

    fn begin_idle(&self) {
        self.idle.fetch_add(1, Ordering::SeqCst);
    }

    fn end_idle(&self) {
        self.idle.fetch_sub(1, Ordering::SeqCst);
    }

    /// Sleep until the epoch moves past `seen` or `timeout` elapses.
    fn wait(&self, seen: u64, timeout: Duration) {
        let deadline = Instant::now() + timeout;
        let mut g = lock_recover(&self.gate);
        while self.seq.load(Ordering::SeqCst) == seen {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (ng, _) = wait_timeout_recover(&self.cv, g, deadline - now);
            g = ng;
        }
    }
}

/// An executed-but-paced batch parked until its modeled completion time.
struct DoneBatch {
    live: Vec<WorkItem<Ctx>>,
    out: Result<ExecOutput>,
    exec_ms: f64,
}

enum WheelKind {
    /// Pacing: the batch's modeled MPS latency elapses at the deadline;
    /// deliver then and free the instance slot.
    BatchDone { slot: usize, done: Box<DoneBatch> },
    /// Batch formation: re-check the slot once its fill window expires.
    FormCheck { slot: usize },
}

struct WheelEntry {
    deadline: Instant,
    seq: u64,
    kind: WheelKind,
}

impl PartialEq for WheelEntry {
    fn eq(&self, other: &Self) -> bool {
        self.deadline == other.deadline && self.seq == other.seq
    }
}
impl Eq for WheelEntry {}
impl PartialOrd for WheelEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for WheelEntry {
    /// Reversed on deadline: BinaryHeap is a max-heap, we want the
    /// earliest deadline on top.
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other
            .deadline
            .cmp(&self.deadline)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The pool's deadline wheel: pacing completions and batch-formation
/// timeouts, ordered by deadline.
#[derive(Default)]
struct DeadlineWheel {
    heap: Mutex<BinaryHeap<WheelEntry>>,
    seq: AtomicU64,
}

impl DeadlineWheel {
    fn insert(&self, deadline: Instant, kind: WheelKind) {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        lock_recover(&self.heap).push(WheelEntry { deadline, seq, kind });
    }

    fn pop_expired(&self, now: Instant) -> Option<WheelKind> {
        let mut h = lock_recover(&self.heap);
        if h.peek().is_some_and(|e| e.deadline <= now) {
            h.pop().map(|e| e.kind)
        } else {
            None
        }
    }

    fn next_deadline(&self) -> Option<Instant> {
        lock_recover(&self.heap).peek().map(|e| e.deadline)
    }

    fn is_empty(&self) -> bool {
        lock_recover(&self.heap).is_empty()
    }
}

#[derive(Debug, Clone, Copy)]
enum SlotState {
    /// Ready for a batch.
    Free,
    /// First item queued; waiting (until `deadline`) for the batch to
    /// reach the planned size before firing.
    Forming { deadline: Instant },
    /// Executing / pacing a batch (completion parked in the wheel).
    Busy,
    /// Instance died (worker kill / GPU failure): never dispatched
    /// again; its shard is closed and rerouted.
    Dead,
}

/// One planned DNN instance, schedulable by any pool worker.
struct Slot {
    stage: usize,
    /// Home shard in the stage's sharded queue.
    shard: usize,
    /// GPU hosting this instance ([`NO_GPU`] for unplaced plans).
    gpu: u32,
    state: Mutex<SlotState>,
    /// Death sentence for a Busy slot: `free_slot` turns it
    /// [`SlotState::Dead`] once the in-flight batch delivers.
    doomed: AtomicBool,
}

struct PoolShared {
    stages: Arc<Vec<Stage>>,
    slots: Vec<Slot>,
    wheel: DeadlineWheel,
    notifier: Notifier,
    shutdown: AtomicBool,
    /// Batches popped but not yet delivered (executing or pacing).
    inflight: AtomicUsize,
}

impl PoolShared {
    /// Nothing queued, parked, or in flight — safe to exit on shutdown.
    fn quiescent(&self) -> bool {
        self.inflight.load(Ordering::SeqCst) == 0
            && self.wheel.is_empty()
            && self.stages.iter().all(|s| s.queue.is_empty())
    }
}

/// How long an idle worker sleeps when no wheel deadline is nearer (also
/// the safety tick bounding any missed-wakeup window).
const IDLE_TICK: Duration = Duration::from_millis(50);

fn pool_worker(pool: &PoolShared, env: &ExecEnv<'_>, start: usize) {
    let n_slots = pool.slots.len();
    let mut cursor = start;
    loop {
        let mut progressed = false;
        // 1. serve expired wheel entries (paced completions first — they
        // free instance slots for new batches)
        while let Some(kind) = pool.wheel.pop_expired(Instant::now()) {
            match kind {
                WheelKind::BatchDone { slot, done } => {
                    finish_batch(pool, env, slot, *done);
                    progressed = true;
                }
                WheelKind::FormCheck { slot } => {
                    progressed |= dispatch_slot(pool, env, slot);
                }
            }
        }
        // 1.5. flush stages that lost their last instance: nothing will
        // ever pop them, so their backlog gets drop notices here — this
        // is also what lets shutdown reach quiescence after a failure
        for s in pool.stages.iter() {
            if s.all_dead() && !s.queue.is_empty() {
                flush_dead_stage(s, env.counters);
                progressed = true;
            }
        }
        // 2. dispatch one batch, scanning slots from a rotating cursor
        for i in 0..n_slots {
            let s = (cursor + i) % n_slots;
            if dispatch_slot(pool, env, s) {
                cursor = (s + 1) % n_slots;
                progressed = true;
                break;
            }
        }
        if progressed {
            continue;
        }
        // 3. idle: register as sleeping, re-check authoritatively (a
        // waker that saw idle == 0 before our registration is matched by
        // this re-scan), then park until notified or the next deadline
        pool.notifier.begin_idle();
        let seen = pool.notifier.epoch();
        let now = Instant::now();
        let rework = pool.wheel.next_deadline().is_some_and(|d| d <= now)
            || (0..n_slots).any(|s| slot_has_work(pool, s));
        if !rework {
            if pool.shutdown.load(Ordering::SeqCst) && pool.quiescent() {
                pool.notifier.end_idle();
                pool.notifier.force_notify();
                break;
            }
            let timeout = pool
                .wheel
                .next_deadline()
                .map(|d| d.saturating_duration_since(Instant::now()))
                .unwrap_or(IDLE_TICK)
                .min(IDLE_TICK)
                .max(Duration::from_micros(200));
            pool.notifier.wait(seen, timeout);
        }
        pool.notifier.end_idle();
    }
}

/// Cheap dispatchability probe used by the idle-path re-check.
fn slot_has_work(pool: &PoolShared, slot_idx: usize) -> bool {
    let slot = &pool.slots[slot_idx];
    let stage = &pool.stages[slot.stage];
    let Ok(st) = slot.state.try_lock() else {
        // contended: its holder is making progress and will notify
        // (poisoning is impossible — state transitions can't panic —
        // but dispatch_slot recovers it anyway)
        return false;
    };
    match *st {
        SlotState::Busy | SlotState::Dead => false,
        // a Free slot has no work while another slot of its stage is
        // forming a sub-batch (the former's FormCheck covers it) — else
        // idle workers would busy-spin on the swap-guarded transition
        SlotState::Free => {
            !stage.queue.is_empty()
                && (!stage.forming.load(Ordering::SeqCst)
                    || stage.queue.len()
                        >= stage.alloc.batch.max(1) as usize
                    || pool.shutdown.load(Ordering::SeqCst))
        }
        SlotState::Forming { deadline } => {
            !stage.queue.is_empty()
                && (stage.queue.len() >= stage.alloc.batch.max(1) as usize
                    || Instant::now() >= deadline
                    || pool.shutdown.load(Ordering::SeqCst))
        }
    }
}

/// Try to start (or advance the formation of) a batch on one instance
/// slot.  Returns true when it made progress.
fn dispatch_slot(
    pool: &PoolShared,
    env: &ExecEnv<'_>,
    slot_idx: usize,
) -> bool {
    let slot = &pool.slots[slot_idx];
    let stage = &pool.stages[slot.stage];
    let max_batch = stage.alloc.batch.max(1) as usize;
    let Some(mut st) =
        try_lock_counted(&slot.state, Some(&env.counters.poisoned))
    else {
        return false;
    };
    if slot.doomed.load(Ordering::SeqCst)
        && !matches!(*st, SlotState::Busy)
    {
        if matches!(*st, SlotState::Forming { .. }) {
            stage.forming.store(false, Ordering::SeqCst);
        }
        *st = SlotState::Dead;
        return false;
    }
    let now = Instant::now();
    let qlen = stage.queue.len();
    let closing = pool.shutdown.load(Ordering::SeqCst);
    let was_forming = matches!(*st, SlotState::Forming { .. });
    let fire = match *st {
        SlotState::Busy | SlotState::Dead => return false,
        SlotState::Free => {
            if qlen == 0 {
                return false;
            }
            let window = stage.window(env.opts);
            if window.is_zero() || qlen >= max_batch || closing {
                true
            } else {
                // park the batch to fill; a FormCheck wakes us at the
                // window edge (this replaces pop_batch_window's blocking
                // wait in the thread executor).  One former per stage:
                // without the gate every free instance would park its
                // own FormCheck for the same sub-batch backlog.
                if stage.forming.swap(true, Ordering::SeqCst) {
                    return false;
                }
                let deadline = now + window;
                *st = SlotState::Forming { deadline };
                drop(st);
                pool.wheel.insert(
                    deadline,
                    WheelKind::FormCheck { slot: slot_idx },
                );
                pool.notifier.notify();
                return true;
            }
        }
        SlotState::Forming { deadline } => {
            if qlen == 0 {
                // another slot stole the backlog
                *st = SlotState::Free;
                stage.forming.store(false, Ordering::SeqCst);
                return false;
            }
            qlen >= max_batch || now >= deadline || closing
        }
    };
    if !fire {
        return false;
    }
    if was_forming {
        // leaving the formation window (to Busy or back to Free below)
        stage.forming.store(false, Ordering::SeqCst);
    }
    let batch = match &stage.queue {
        StageQueue::Sharded(q) => q.try_pop_batch(slot.shard, max_batch),
        StageQueue::Single(_) => {
            unreachable!("Pool mode uses sharded queues")
        }
    };
    if batch.is_empty() {
        *st = SlotState::Free;
        return false;
    }
    *st = SlotState::Busy;
    pool.inflight.fetch_add(1, Ordering::SeqCst);
    drop(st);
    run_pool_batch(pool, env, slot_idx, batch);
    true
}

/// Execute a popped batch on the calling worker; with pacing the
/// delivery is parked in the wheel and the worker moves on.
fn run_pool_batch(
    pool: &PoolShared,
    env: &ExecEnv<'_>,
    slot_idx: usize,
    mut batch: Vec<WorkItem<Ctx>>,
) {
    let slot = &pool.slots[slot_idx];
    let stage = &pool.stages[slot.stage];
    for item in batch.iter_mut() {
        if let Some(t) = item.ctx.trace.as_deref_mut() {
            t.stamp(SpanKind::ShardPop);
        }
    }
    let mut live = slo_filter(env, stage, batch);
    if live.is_empty() {
        free_slot(pool, env, slot_idx);
        return;
    }
    let t0 = Instant::now();
    let (out, exec_ms, kill) =
        execute_batch(env, stage, slot.stage, slot.shard, slot.gpu, &mut live);
    if kill {
        // injected/real worker death: retire the instance (closing its
        // shard reroutes the backlog), doom the slot, deliver the
        // error-path notices for this batch immediately
        retire_instance(
            env.stages,
            env.health,
            env.counters,
            slot.stage,
            slot.shard,
            slot.gpu,
        );
        slot.doomed.store(true, Ordering::SeqCst);
        finish_batch(pool, env, slot_idx, DoneBatch { live, out, exec_ms });
        return;
    }
    if env.opts.time_scale > 0.0 {
        let target = t0
            + Duration::from_secs_f64(exec_ms * env.opts.time_scale / 1e3);
        if Instant::now() < target {
            pool.wheel.insert(
                target,
                WheelKind::BatchDone {
                    slot: slot_idx,
                    done: Box::new(DoneBatch { live, out, exec_ms }),
                },
            );
            pool.notifier.notify();
            return; // slot stays Busy until the wheel fires
        }
    }
    finish_batch(pool, env, slot_idx, DoneBatch { live, out, exec_ms });
}

fn finish_batch(
    pool: &PoolShared,
    env: &ExecEnv<'_>,
    slot_idx: usize,
    done: DoneBatch,
) {
    let slot = &pool.slots[slot_idx];
    let stage = &pool.stages[slot.stage];
    deliver(env, stage, done.live, done.out, done.exec_ms);
    env.health.beat_live(slot.stage, slot.shard, slot.gpu);
    free_slot(pool, env, slot_idx);
}

fn free_slot(pool: &PoolShared, env: &ExecEnv<'_>, slot_idx: usize) {
    let slot = &pool.slots[slot_idx];
    let mut st = lock_counted(&slot.state, Some(&env.counters.poisoned));
    *st = if slot.doomed.load(Ordering::SeqCst) {
        SlotState::Dead
    } else {
        SlotState::Free
    };
    drop(st);
    pool.inflight.fetch_sub(1, Ordering::SeqCst);
    pool.notifier.notify();
}
