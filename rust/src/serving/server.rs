//! The serving executor + load balancer (paper §3 "executor").
//!
//! Materialises an [`ExecutionPlan`]: one [`BatchQueue`] per provisioned
//! stage, `alloc.instances` worker threads per stage (the paper's DNN
//! instances, one process each), alignment stages chained into the
//! shared stage (the paper pipes tensors between fragments over unix
//! sockets; we use in-process queues).  The load balancer routes each
//! client to its stage and drops requests that can no longer meet their
//! SLO (§3).
//!
//! Instances execute the *real* AOT-compiled fragment on PJRT, then pace
//! to the modeled MPS latency of their (batch, share) configuration —
//! CPU wall-clock says nothing about GPU shares, so pacing is what makes
//! queueing/batching dynamics faithful (`time_scale` scales modeled GPU
//! milliseconds to wall milliseconds; 0 disables pacing for tests).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::batcher::{BatchQueue, WorkItem};
use super::messages::{Request, Response};
use crate::coordinator::plan::ExecutionPlan;
use crate::profiler::{Alloc, CostModel, FragmentId};
use crate::runtime::{Engine, ExecOutput};

/// Abstraction over fragment execution so the serving layer is testable
/// without artifacts (and so alternative backends can plug in).
pub trait FragmentExecutor: Send + Sync {
    fn execute(
        &self,
        model: &str,
        start: usize,
        end: usize,
        rows: &[Vec<f32>],
    ) -> Result<ExecOutput>;
}

impl FragmentExecutor for Engine {
    fn execute(
        &self,
        model: &str,
        start: usize,
        end: usize,
        rows: &[Vec<f32>],
    ) -> Result<ExecOutput> {
        self.run(model, start, end, rows)
    }
}

/// Deterministic stand-in executor for tests: output row = dim_out copies
/// of (sum of inputs) / dim_in.
pub struct MockExecutor {
    pub dims: HashMap<String, Vec<usize>>,
}

impl FragmentExecutor for MockExecutor {
    fn execute(
        &self,
        model: &str,
        _start: usize,
        end: usize,
        rows: &[Vec<f32>],
    ) -> Result<ExecOutput> {
        let dims = self
            .dims
            .get(model)
            .ok_or_else(|| anyhow!("unknown model {model}"))?;
        let dim_out = dims[end];
        let mut data = Vec::with_capacity(rows.len() * dim_out);
        for r in rows {
            let v = r.iter().sum::<f32>() / r.len() as f32;
            data.extend(std::iter::repeat(v).take(dim_out));
        }
        Ok(ExecOutput { data, batch: rows.len(), dim_out })
    }
}

#[derive(Debug, Clone, Copy)]
pub struct ServerOptions {
    /// Wall-clock milliseconds per modeled GPU millisecond (1.0 = real
    /// time; 0.0 = no pacing).
    pub time_scale: f64,
    /// Drop requests that can no longer meet their SLO (paper §3).
    pub drop_on_slo: bool,
}

impl Default for ServerOptions {
    fn default() -> Self {
        Self { time_scale: 1.0, drop_on_slo: true }
    }
}

/// Per-request context travelling with a work item.
struct Ctx {
    client_id: u32,
    seq: u32,
    upstream_ms: f64,
    reply: mpsc::Sender<Response>,
}

struct Stage {
    queue: BatchQueue<Ctx>,
    frag: FragmentId,
    model_name: String,
    alloc: Alloc,
    /// Index of the downstream (shared) stage, if this is an alignment
    /// stage.
    next: Option<usize>,
}

/// Serving statistics counters.
#[derive(Debug, Default)]
pub struct ServerCounters {
    pub served: AtomicU64,
    pub dropped: AtomicU64,
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    /// Served requests whose server time exceeded their budget (should
    /// stay near zero: the balancer drops hopeless requests instead).
    pub budget_violations: AtomicU64,
}

/// The running server.
pub struct Server {
    stages: Arc<Vec<Stage>>,
    routes: HashMap<u32, usize>,
    handles: Vec<JoinHandle<()>>,
    pub counters: Arc<ServerCounters>,
}

impl Server {
    /// Spawn the instances for `plan` and return the running server.
    pub fn start(
        executor: Arc<dyn FragmentExecutor>,
        cm: &CostModel,
        plan: &ExecutionPlan,
        opts: ServerOptions,
    ) -> Server {
        let mut stages: Vec<Stage> = Vec::new();
        let mut routes = HashMap::new();

        for set in &plan.sets {
            let model_name = cm.config().models[set.model].name.clone();
            let shared_idx = stages.len();
            stages.push(Stage {
                queue: BatchQueue::new(),
                frag: set.shared.frag,
                model_name: model_name.clone(),
                alloc: set.shared.alloc,
                next: None,
            });
            for m in &set.members {
                let entry = match &m.align {
                    Some(a) => {
                        let idx = stages.len();
                        stages.push(Stage {
                            queue: BatchQueue::new(),
                            frag: a.frag,
                            model_name: model_name.clone(),
                            alloc: a.alloc,
                            next: Some(shared_idx),
                        });
                        idx
                    }
                    None => shared_idx,
                };
                for c in &m.spec.clients {
                    routes.insert(c.0, entry);
                }
            }
        }

        let stages = Arc::new(stages);
        let counters = Arc::new(ServerCounters::default());
        let mut handles = Vec::new();
        for (idx, stage) in stages.iter().enumerate() {
            for _ in 0..stage.alloc.instances {
                let stages = stages.clone();
                let executor = executor.clone();
                let cm = cm.clone();
                let counters = counters.clone();
                handles.push(std::thread::spawn(move || {
                    instance_loop(idx, &stages, &*executor, &cm, opts, &counters)
                }));
            }
        }
        Server { stages, routes, handles, counters }
    }

    /// Submit a request; the response arrives on `reply`.
    pub fn submit(&self, req: Request, reply: mpsc::Sender<Response>) {
        match self.routes.get(&req.client_id) {
            Some(&idx) => {
                self.stages[idx].queue.push(WorkItem {
                    payload: req.payload,
                    server_arrival: Instant::now(),
                    budget_ms: req.budget_ms,
                    accumulated_ms: 0.0,
                    ctx: Ctx {
                        client_id: req.client_id,
                        seq: req.seq,
                        upstream_ms: req.upstream_ms,
                        reply,
                    },
                });
            }
            None => {
                // unknown client: the balancer rejects outright
                let _ = reply.send(Response {
                    client_id: req.client_id,
                    seq: req.seq,
                    server_ms: 0.0,
                    e2e_ms: req.upstream_ms,
                    dropped: true,
                    output: Vec::new(),
                });
            }
        }
    }

    /// Whether a client currently has a route.
    pub fn has_route(&self, client_id: u32) -> bool {
        self.routes.contains_key(&client_id)
    }

    pub fn queue_depths(&self) -> Vec<usize> {
        self.stages.iter().map(|s| s.queue.len()).collect()
    }

    /// Close all queues and join the instance threads.
    pub fn shutdown(mut self) {
        for s in self.stages.iter() {
            s.queue.close();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Round a formed batch up to the nearest compiled bucket.
fn bucket_for(cm: &CostModel, n: usize) -> u32 {
    let buckets = &cm.config().gpu.batch_buckets;
    buckets
        .iter()
        .copied()
        .find(|&b| b as usize >= n)
        .unwrap_or(*buckets.last().unwrap())
}

fn instance_loop(
    stage_idx: usize,
    stages: &[Stage],
    executor: &dyn FragmentExecutor,
    cm: &CostModel,
    opts: ServerOptions,
    counters: &ServerCounters,
) {
    let stage = &stages[stage_idx];
    // Batch-formation window: the plan's throughput assumes batches of
    // alloc.batch; greedy pop-1 under-delivers by the amortisation factor.
    // Waiting up to one planned execution time stays within the §4.3
    // worst-case-queueing envelope.
    let window = if opts.time_scale > 0.0 && stage.alloc.batch > 1 {
        std::time::Duration::from_secs_f64(
            stage.alloc.latency_ms * opts.time_scale / 1e3,
        )
    } else {
        std::time::Duration::ZERO
    };
    loop {
        let batch = if window.is_zero() {
            stage.queue.pop_batch(stage.alloc.batch as usize)
        } else {
            stage
                .queue
                .pop_batch_window(stage.alloc.batch as usize, window)
        };
        let Some(batch) = batch else { break };
        if batch.is_empty() {
            continue;
        }
        // SLO-drop: discard items that cannot finish in time even if
        // executed right now (paper: the balancer drops SLO misses).
        let exec_ms_probe = cm.latency_ms(
            stage.frag,
            bucket_for(cm, batch.len()),
            stage.alloc.share,
        );
        let mut live: Vec<WorkItem<Ctx>> = Vec::with_capacity(batch.len());
        for item in batch {
            let elapsed =
                item.server_arrival.elapsed().as_secs_f64() * 1e3;
            // pacing-sleep overshoot + scheduling noise margin: serve a
            // request that would finish marginally late and it becomes an
            // SLO violation instead of a clean drop
            const NOISE_MARGIN_MS: f64 = 3.0;
            // With pacing, wall-clock elapsed already contains earlier
            // stages' (paced) execution — adding accumulated_ms would
            // double-count it; without pacing, modeled time is all there is.
            let so_far = if opts.time_scale > 0.0 {
                scaled_elapsed(elapsed, opts)
            } else {
                item.accumulated_ms
            };
            let projected = so_far
                + exec_ms_probe
                + remaining_ms(stage, stages, exec_ms_probe)
                + NOISE_MARGIN_MS;
            if opts.drop_on_slo && projected > item.budget_ms {
                counters.dropped.fetch_add(1, Ordering::Relaxed);
                let _ = item.ctx.reply.send(Response {
                    client_id: item.ctx.client_id,
                    seq: item.ctx.seq,
                    server_ms: so_far,
                    e2e_ms: item.ctx.upstream_ms + so_far,
                    dropped: true,
                    output: Vec::new(),
                });
                continue;
            }
            live.push(item);
        }
        if live.is_empty() {
            continue;
        }

        let rows: Vec<Vec<f32>> =
            live.iter().map(|i| i.payload.clone()).collect();
        let exec_ms = cm.latency_ms(
            stage.frag,
            bucket_for(cm, rows.len()),
            stage.alloc.share,
        );
        let t0 = Instant::now();
        let out = executor.execute(
            &stage.model_name,
            stage.frag.start,
            stage.frag.end,
            &rows,
        );
        counters.batches.fetch_add(1, Ordering::Relaxed);
        counters
            .batched_requests
            .fetch_add(rows.len() as u64, Ordering::Relaxed);
        // pace to the modeled MPS latency
        if opts.time_scale > 0.0 {
            let target = exec_ms * opts.time_scale / 1e3;
            let spent = t0.elapsed().as_secs_f64();
            if std::env::var_os("GRAFT_DEBUG_EXEC").is_some()
                && spent * 1e3 > exec_ms
            {
                eprintln!(
                    "[exec overrun] wall {:.1} ms vs modeled {:.1} ms (batch {})",
                    spent * 1e3,
                    exec_ms,
                    rows.len()
                );
            }
            if target > spent {
                std::thread::sleep(std::time::Duration::from_secs_f64(
                    target - spent,
                ));
            }
        }
        let out = match out {
            Ok(o) => o,
            Err(_) => {
                for item in live {
                    counters.dropped.fetch_add(1, Ordering::Relaxed);
                    let _ = item.ctx.reply.send(Response {
                        client_id: item.ctx.client_id,
                        seq: item.ctx.seq,
                        server_ms: 0.0,
                        e2e_ms: item.ctx.upstream_ms,
                        dropped: true,
                        output: Vec::new(),
                    });
                }
                continue;
            }
        };

        for (i, item) in live.into_iter().enumerate() {
            let row = out.data[i * out.dim_out..(i + 1) * out.dim_out].to_vec();
            let acc = item.accumulated_ms + exec_ms;
            match stage.next {
                Some(next) => {
                    stages[next].queue.push(WorkItem {
                        payload: row,
                        server_arrival: item.server_arrival,
                        budget_ms: item.budget_ms,
                        accumulated_ms: acc,
                        ctx: item.ctx,
                    });
                }
                None => {
                    let elapsed = item
                        .server_arrival
                        .elapsed()
                        .as_secs_f64()
                        * 1e3;
                    // with pacing, wall time already covers exec; without,
                    // report modeled time
                    let server_ms = if opts.time_scale > 0.0 {
                        scaled_elapsed(elapsed, opts)
                    } else {
                        acc
                    };
                    counters.served.fetch_add(1, Ordering::Relaxed);
                    if server_ms > item.budget_ms {
                        counters
                            .budget_violations
                            .fetch_add(1, Ordering::Relaxed);
                        if std::env::var_os("GRAFT_DEBUG_EXEC").is_some() {
                            eprintln!(
                                "[violation] client {} server {:.1} > budget {:.1} (exec {:.1}, batch {})",
                                item.ctx.client_id,
                                server_ms,
                                item.budget_ms,
                                exec_ms,
                                out.batch
                            );
                        }
                    }
                    let _ = item.ctx.reply.send(Response {
                        client_id: item.ctx.client_id,
                        seq: item.ctx.seq,
                        server_ms,
                        e2e_ms: item.ctx.upstream_ms + server_ms,
                        dropped: false,
                        output: row,
                    });
                }
            }
        }
    }
}

/// Wall-clock elapsed converted back to modeled GPU milliseconds.
fn scaled_elapsed(elapsed_wall_ms: f64, opts: ServerOptions) -> f64 {
    if opts.time_scale > 0.0 {
        elapsed_wall_ms / opts.time_scale
    } else {
        0.0
    }
}

/// Lower-bound on the time a request still needs after this stage.
fn remaining_ms(stage: &Stage, stages: &[Stage], _probe: f64) -> f64 {
    match stage.next {
        Some(next) => {
            let s = &stages[next];
            // best case: batch of 1 at the shared stage's share
            s.alloc.latency_ms.min(
                s.alloc.latency_ms / s.alloc.batch.max(1) as f64,
            )
        }
        None => 0.0,
    }
}
