//! Deterministic fault injection for chaos runs.
//!
//! A [`FaultPlan`] is a seeded, sorted list of [`FaultEvent`]s — "at
//! tick N, kill a worker / fail GPU g / poison shard s / drop the
//! connection".  Ticks are counted by whoever consumes the event's
//! *domain*: executor faults tick once per batch execution (the
//! [`FaultyExecutor`] wrapper), control faults once per submitted
//! request (the serving harnesses), connection faults once per received
//! frame (the TCP front).  Everything is seeded through
//! [`crate::util::rng::Rng`], so a chaos run replays identically.

use std::panic::panic_any;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use super::server::{FragmentExecutor, KillWorker};
use crate::runtime::ExecOutput;
use crate::util::lock::lock_recover;
use crate::util::rng::Rng;

/// What to break.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Kill the instance executing the batch at the tick (the executor
    /// panics with the [`KillWorker`] marker; the serving core retires
    /// the instance and reroutes its shard).
    WorkerKill,
    /// Plain executor panic: the batch is dropped with notices, the
    /// instance survives.
    ExecPanic,
    /// Fail a GPU: every co-located instance dies at once.
    GpuFail { gpu: u32 },
    /// Poison one queue shard's lock (recovered, counted, reported).
    PoisonShard { stage: usize, shard: usize },
    /// Drop the TCP connection mid-stream.
    ConnDrop,
    /// Stall the TCP connection for `ms` before the next submit.
    ConnDelay { ms: u64 },
    /// Partial capacity loss: the GPU keeps serving but loses
    /// `share_loss` compute share and `mem_loss_mb` MB of memory
    /// (integral MB so the kind stays `Copy + Eq`).
    GpuDegrade { gpu: u32, share_loss: u32, mem_loss_mb: u32 },
    /// Out-of-band health warning against a GPU — bumps its predictive
    /// fault level without touching capacity.
    GpuWarn { gpu: u32 },
}

/// A correlated-failure group (rack / host): when chaos picks the
/// domain, *every* member GPU fails at the same tick.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FailureDomain {
    pub name: String,
    pub gpus: Vec<u32>,
}

/// Which tick counter an event is consumed against.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultDomain {
    /// Batch executions ([`FaultyExecutor`]).
    Exec,
    /// Submitted requests (serving harnesses).
    Control,
    /// Received frames (TCP front).
    Conn,
}

impl FaultKind {
    pub fn domain(&self) -> FaultDomain {
        match self {
            FaultKind::WorkerKill | FaultKind::ExecPanic => FaultDomain::Exec,
            FaultKind::GpuFail { .. }
            | FaultKind::PoisonShard { .. }
            | FaultKind::GpuDegrade { .. }
            | FaultKind::GpuWarn { .. } => FaultDomain::Control,
            FaultKind::ConnDrop | FaultKind::ConnDelay { .. } => {
                FaultDomain::Conn
            }
        }
    }
}

/// One scheduled fault.
#[derive(Debug, Clone, Copy)]
pub struct FaultEvent {
    /// Domain tick (1-based) at which the fault fires; it fires on the
    /// first tick `>= at_tick` its consumer observes.
    pub at_tick: u64,
    pub kind: FaultKind,
}

/// A reproducible chaos schedule.  Thread-safe: producers/executors on
/// any thread consume events exactly once.
pub struct FaultPlan {
    /// Sorted by `at_tick`; `taken` flags give exactly-once consumption.
    events: Mutex<Vec<(FaultEvent, bool)>>,
    /// Tick counter per domain (Exec, Control, Conn).
    ticks: [AtomicU64; 3],
    pub seed: u64,
}

impl FaultPlan {
    pub fn new(seed: u64, mut events: Vec<FaultEvent>) -> Self {
        events.sort_by_key(|e| e.at_tick);
        Self {
            events: Mutex::new(events.into_iter().map(|e| (e, false)).collect()),
            ticks: [AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)],
            seed,
        }
    }

    /// A single-GPU-failure schedule (the bench's canonical fault).
    pub fn single_gpu_failure(gpu: u32, at_tick: u64) -> Self {
        Self::new(
            0,
            vec![FaultEvent { at_tick, kind: FaultKind::GpuFail { gpu } }],
        )
    }

    /// A seeded random chaos mix over the given GPUs and (stage, shard)
    /// pairs: `n_each` events of each applicable kind, spread uniformly
    /// over `(0, ticks]`.  Deterministic per seed.
    pub fn chaos(
        seed: u64,
        ticks: u64,
        gpus: &[u32],
        shards: &[(usize, usize)],
        n_each: usize,
    ) -> Self {
        // singleton domains draw the identical rng stream, so per-GPU
        // chaos is the degenerate case of correlated chaos
        let domains: Vec<FailureDomain> = gpus
            .iter()
            .map(|g| FailureDomain { name: format!("gpu{g}"), gpus: vec![*g] })
            .collect();
        Self::chaos_with_domains(seed, ticks, &domains, shards, n_each)
    }

    /// Correlated chaos: like [`Self::chaos`], but GPU failures pick a
    /// whole [`FailureDomain`] — every member fails at the same tick,
    /// the way a rack power loss or host crash takes out co-located
    /// GPUs together.  Deterministic per seed; with singleton domains
    /// this is exactly [`Self::chaos`].
    pub fn chaos_with_domains(
        seed: u64,
        ticks: u64,
        domains: &[FailureDomain],
        shards: &[(usize, usize)],
        n_each: usize,
    ) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        let mut tick = |rng: &mut Rng| rng.below(ticks.max(1) as usize) as u64 + 1;
        let mut events = Vec::new();
        for _ in 0..n_each {
            let at = tick(&mut rng);
            events.push(FaultEvent { at_tick: at, kind: FaultKind::WorkerKill });
            let at = tick(&mut rng);
            events.push(FaultEvent { at_tick: at, kind: FaultKind::ExecPanic });
            if !domains.is_empty() {
                let domain = &domains[rng.below(domains.len())];
                let at = tick(&mut rng);
                for gpu in &domain.gpus {
                    events.push(FaultEvent {
                        at_tick: at,
                        kind: FaultKind::GpuFail { gpu: *gpu },
                    });
                }
            }
            if !shards.is_empty() {
                let (stage, shard) = shards[rng.below(shards.len())];
                let at = tick(&mut rng);
                events.push(FaultEvent {
                    at_tick: at,
                    kind: FaultKind::PoisonShard { stage, shard },
                });
            }
        }
        Self::new(seed, events)
    }

    fn domain_idx(domain: FaultDomain) -> usize {
        match domain {
            FaultDomain::Exec => 0,
            FaultDomain::Control => 1,
            FaultDomain::Conn => 2,
        }
    }

    /// Advance `domain`'s tick by one and return the faults due at or
    /// before it (each event fires exactly once, across all threads).
    pub fn tick(&self, domain: FaultDomain) -> Vec<FaultKind> {
        let t = self.ticks[Self::domain_idx(domain)]
            .fetch_add(1, Ordering::Relaxed)
            + 1;
        self.take_due(domain, t)
    }

    /// Faults of `domain` due at or before tick `t`, not yet consumed.
    pub fn take_due(&self, domain: FaultDomain, t: u64) -> Vec<FaultKind> {
        let mut g = lock_recover(&self.events);
        let mut out = Vec::new();
        for (ev, taken) in g.iter_mut() {
            if ev.at_tick > t {
                break; // sorted: nothing later is due
            }
            if !*taken && ev.kind.domain() == domain {
                *taken = true;
                out.push(ev.kind);
            }
        }
        out
    }

    /// Events injected so far (consumed), for reporting.
    pub fn injected(&self) -> Vec<FaultEvent> {
        lock_recover(&self.events)
            .iter()
            .filter(|(_, taken)| *taken)
            .map(|(e, _)| *e)
            .collect()
    }

    /// Total scheduled events.
    pub fn len(&self) -> usize {
        lock_recover(&self.events).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// [`FragmentExecutor`] wrapper that fires the plan's executor-domain
/// faults: one tick per `execute` call, panicking with [`KillWorker`]
/// (instance death) or a plain panic (batch loss) when a fault is due.
pub struct FaultyExecutor {
    inner: Arc<dyn FragmentExecutor>,
    plan: Arc<FaultPlan>,
}

impl FaultyExecutor {
    pub fn new(inner: Arc<dyn FragmentExecutor>, plan: Arc<FaultPlan>) -> Self {
        Self { inner, plan }
    }
}

impl FragmentExecutor for FaultyExecutor {
    fn execute(
        &self,
        model: &str,
        start: usize,
        end: usize,
        rows: &[Vec<f32>],
    ) -> Result<ExecOutput> {
        for kind in self.plan.tick(FaultDomain::Exec) {
            match kind {
                FaultKind::WorkerKill => panic_any(KillWorker),
                FaultKind::ExecPanic => panic!("injected executor panic"),
                _ => unreachable!("non-exec fault in exec domain"),
            }
        }
        self.inner.execute(model, start, end, rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_fire_exactly_once_in_their_domain() {
        let plan = FaultPlan::new(
            1,
            vec![
                FaultEvent { at_tick: 2, kind: FaultKind::WorkerKill },
                FaultEvent { at_tick: 2, kind: FaultKind::GpuFail { gpu: 1 } },
                FaultEvent { at_tick: 5, kind: FaultKind::ConnDrop },
            ],
        );
        assert!(plan.tick(FaultDomain::Exec).is_empty()); // tick 1
        assert_eq!(plan.tick(FaultDomain::Exec), vec![FaultKind::WorkerKill]);
        assert!(plan.tick(FaultDomain::Exec).is_empty(), "fired once");
        // the control-domain event is untouched by exec ticks and fires
        // late if its consumer is past the tick already
        assert_eq!(
            plan.take_due(FaultDomain::Control, 10),
            vec![FaultKind::GpuFail { gpu: 1 }]
        );
        assert!(plan.take_due(FaultDomain::Conn, 4).is_empty());
        assert_eq!(plan.take_due(FaultDomain::Conn, 5), vec![FaultKind::ConnDrop]);
        assert_eq!(plan.injected().len(), 3);
    }

    #[test]
    fn chaos_is_deterministic_per_seed() {
        let a = FaultPlan::chaos(9, 100, &[0, 1], &[(0, 0), (0, 1)], 3);
        let b = FaultPlan::chaos(9, 100, &[0, 1], &[(0, 0), (0, 1)], 3);
        let ea: Vec<_> =
            lock_recover(&a.events).iter().map(|(e, _)| *e).collect();
        let eb: Vec<_> =
            lock_recover(&b.events).iter().map(|(e, _)| *e).collect();
        assert_eq!(ea.len(), eb.len());
        for (x, y) in ea.iter().zip(&eb) {
            assert_eq!(x.at_tick, y.at_tick);
            assert_eq!(x.kind, y.kind);
        }
        let c = FaultPlan::chaos(10, 100, &[0, 1], &[(0, 0)], 3);
        assert_eq!(c.len(), 12);
    }

    /// A picked domain fails every member at the same tick — the
    /// correlated (rack/host) failure shape.
    #[test]
    fn domain_members_fail_together() {
        let domains = vec![
            FailureDomain { name: "rack0".into(), gpus: vec![0, 1, 2] },
            FailureDomain { name: "rack1".into(), gpus: vec![3, 4] },
        ];
        let plan = FaultPlan::chaos_with_domains(7, 50, &domains, &[], 4);
        let events: Vec<_> =
            lock_recover(&plan.events).iter().map(|(e, _)| *e).collect();
        let fails: Vec<_> = events
            .iter()
            .filter_map(|e| match e.kind {
                FaultKind::GpuFail { gpu } => Some((e.at_tick, gpu)),
                _ => None,
            })
            .collect();
        assert!(!fails.is_empty());
        // every GpuFail tick carries a complete domain, nothing partial
        let mut by_tick: std::collections::BTreeMap<u64, Vec<u32>> =
            std::collections::BTreeMap::new();
        for (t, g) in &fails {
            by_tick.entry(*t).or_default().push(*g);
        }
        for gpus in by_tick.values_mut() {
            gpus.sort_unstable();
            gpus.dedup();
            // a tick's failure set is a union of complete domains:
            // no domain appears partially
            for d in &domains {
                let present =
                    d.gpus.iter().filter(|g| gpus.contains(g)).count();
                assert!(
                    present == 0 || present == d.gpus.len(),
                    "partial domain failure at tick: {gpus:?}"
                );
            }
        }
    }

    /// Singleton domains replay the exact per-GPU chaos stream.
    #[test]
    fn singleton_domains_match_plain_chaos() {
        let plain = FaultPlan::chaos(21, 80, &[2, 5], &[(1, 0)], 3);
        let domains = vec![
            FailureDomain { name: "gpu2".into(), gpus: vec![2] },
            FailureDomain { name: "gpu5".into(), gpus: vec![5] },
        ];
        let correlated =
            FaultPlan::chaos_with_domains(21, 80, &domains, &[(1, 0)], 3);
        let ea: Vec<_> =
            lock_recover(&plain.events).iter().map(|(e, _)| *e).collect();
        let eb: Vec<_> = lock_recover(&correlated.events)
            .iter()
            .map(|(e, _)| *e)
            .collect();
        assert_eq!(ea.len(), eb.len());
        for (x, y) in ea.iter().zip(&eb) {
            assert_eq!(x.at_tick, y.at_tick);
            assert_eq!(x.kind, y.kind);
        }
    }

    /// The new capacity-loss kinds ride the control domain.
    #[test]
    fn degrade_and_warn_are_control_domain() {
        let degrade =
            FaultKind::GpuDegrade { gpu: 1, share_loss: 20, mem_loss_mb: 512 };
        assert_eq!(degrade.domain(), FaultDomain::Control);
        assert_eq!(FaultKind::GpuWarn { gpu: 1 }.domain(), FaultDomain::Control);
        let plan = FaultPlan::new(
            0,
            vec![
                FaultEvent { at_tick: 1, kind: degrade },
                FaultEvent { at_tick: 1, kind: FaultKind::GpuWarn { gpu: 1 } },
            ],
        );
        assert_eq!(plan.tick(FaultDomain::Control).len(), 2);
        assert!(plan.tick(FaultDomain::Control).is_empty(), "fired once");
    }
}
