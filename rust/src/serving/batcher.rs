//! Shared batch queues (paper §5.1: "a request ... is buffered as a
//! tensor in a queue for the corresponding fragment.  This queue is
//! shared by all the instances for each DNN fragment, which process
//! requests in batch from the queue").
//!
//! Two implementations coexist:
//!
//! * [`BatchQueue`] — the reference implementation: one mutex + condvar
//!   around a `VecDeque`.  Correct and simple, but every producer and
//!   every consumer instance serialises on the same lock, which is the
//!   serving-path bottleneck at 10k-client scale.
//! * [`ShardedBatchQueue`] — one shard per planned instance.  Producers
//!   route with power-of-two-choices (pick two shards, push to the
//!   shorter), consumers pop from their home shard and steal from the
//!   others to fill a batch.  Contention drops from O(producers) on one
//!   lock to ~2 threads per shard lock in expectation.
//!
//! Both count traffic in [`QueueMetrics`]; in particular a `push` after
//! `close()` is *rejected* (returns `false`) and counted, never silently
//! dropped.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// An item travelling through the serving pipeline.
#[derive(Debug)]
pub struct WorkItem<T> {
    pub payload: Vec<f32>,
    /// When the request entered the server.
    pub server_arrival: Instant,
    /// Server-side budget (ms) for SLO-drop decisions.
    pub budget_ms: f64,
    /// Modeled server time already accumulated in earlier stages (ms).
    pub accumulated_ms: f64,
    /// Caller context (client id, seq, response channel, ...).
    pub ctx: T,
}

/// Queue traffic counters (monotonic; read with `Ordering::Relaxed`).
#[derive(Debug, Default)]
pub struct QueueMetrics {
    /// Items accepted by `push`.
    pub pushed: AtomicU64,
    /// Items handed to consumers.
    pub popped: AtomicU64,
    /// Pushes refused because the queue was closed.
    pub rejected: AtomicU64,
}

impl QueueMetrics {
    pub fn pushed(&self) -> u64 {
        self.pushed.load(Ordering::Relaxed)
    }
    pub fn popped(&self) -> u64 {
        self.popped.load(Ordering::Relaxed)
    }
    pub fn rejected(&self) -> u64 {
        self.rejected.load(Ordering::Relaxed)
    }
}

struct Inner<T> {
    items: VecDeque<WorkItem<T>>,
    closed: bool,
}

/// MPMC batch queue: producers push single items; consumer instances pop
/// greedy batches up to their batch size.
pub struct BatchQueue<T> {
    inner: Mutex<Inner<T>>,
    cv: Condvar,
    metrics: QueueMetrics,
}

impl<T> Default for BatchQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> BatchQueue<T> {
    pub fn new() -> Self {
        Self {
            inner: Mutex::new(Inner { items: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
            metrics: QueueMetrics::default(),
        }
    }

    /// Push one item.  Returns `false` (and counts the rejection) if the
    /// queue has been closed; the item is dropped in that case.
    pub fn push(&self, item: WorkItem<T>) -> bool {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            drop(g);
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        g.items.push_back(item);
        drop(g);
        self.metrics.pushed.fetch_add(1, Ordering::Relaxed);
        self.cv.notify_one();
        true
    }

    fn count_popped(&self, n: usize) {
        if n > 0 {
            self.metrics.popped.fetch_add(n as u64, Ordering::Relaxed);
        }
    }

    /// Pop up to `max_batch` items: blocks for the first item, then
    /// drains whatever else is immediately available (greedy batching).
    /// Returns `None` once closed and drained.
    pub fn pop_batch(&self, max_batch: usize) -> Option<Vec<WorkItem<T>>> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if !g.items.is_empty() {
                let n = g.items.len().min(max_batch.max(1));
                let out: Vec<_> = g.items.drain(..n).collect();
                drop(g);
                self.count_popped(out.len());
                return Some(out);
            }
            if g.closed {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    /// Pop up to `max_batch`, blocking for the first item and then
    /// waiting up to `window` for the batch to fill (the §4.3 envelope
    /// reserves one execution time for queueing, so waiting that long to
    /// reach the *planned* batch size keeps the SLO math intact while
    /// hitting the planned throughput).
    pub fn pop_batch_window(
        &self,
        max_batch: usize,
        window: Duration,
    ) -> Option<Vec<WorkItem<T>>> {
        let mut g = self.inner.lock().unwrap();
        // phase 1: block for the first item
        loop {
            if !g.items.is_empty() {
                break;
            }
            if g.closed {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
        // phase 2: give the batch `window` to fill
        let deadline = Instant::now() + window;
        while g.items.len() < max_batch.max(1) && !g.closed {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (ng, _) = self.cv.wait_timeout(g, deadline - now).unwrap();
            g = ng;
        }
        let n = g.items.len().min(max_batch.max(1));
        let out: Vec<_> = g.items.drain(..n).collect();
        drop(g);
        self.count_popped(out.len());
        Some(out)
    }

    /// Like `pop_batch` but gives up after `timeout` (for pollers).
    pub fn pop_batch_timeout(
        &self,
        max_batch: usize,
        timeout: Duration,
    ) -> Option<Vec<WorkItem<T>>> {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        loop {
            if !g.items.is_empty() {
                let n = g.items.len().min(max_batch.max(1));
                let out: Vec<_> = g.items.drain(..n).collect();
                drop(g);
                self.count_popped(out.len());
                return Some(out);
            }
            if g.closed {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return Some(Vec::new());
            }
            let (ng, res) = self.cv.wait_timeout(g, deadline - now).unwrap();
            g = ng;
            if res.timed_out() && g.items.is_empty() {
                return Some(Vec::new());
            }
        }
    }

    /// Close the queue: consumers drain remaining items then get `None`.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn metrics(&self) -> &QueueMetrics {
        &self.metrics
    }
}

/// SplitMix64 — cheap stateless mixer for push routing.
fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

struct Shard<T> {
    items: Mutex<VecDeque<WorkItem<T>>>,
    /// Cached length so routing never takes a lock it will not use.
    len: AtomicUsize,
}

/// MPMC batch queue sharded per consumer instance.
///
/// * `push` routes with power-of-two-choices over the per-shard length
///   counters, so producers spread across shard locks instead of
///   serialising on one mutex.
/// * `try_pop_batch(home, n)` drains the consumer's home shard first and
///   then *steals* from the other shards (in ring order) until the batch
///   is full — an instance never idles while any shard has work.
/// * `pop_batch` adds blocking on top for consumers without their own
///   scheduler (tests, simple drivers); the pooled executor only uses
///   the non-blocking form and parks on its own notifier.
pub struct ShardedBatchQueue<T> {
    shards: Vec<Shard<T>>,
    total: AtomicUsize,
    closed: AtomicBool,
    ticket: AtomicU64,
    /// Blocking-pop support: waiters register in `sleepers` and wait for
    /// `epoch` to move on (pushes only take the gate when someone sleeps).
    sleepers: AtomicUsize,
    epoch: AtomicU64,
    gate: Mutex<()>,
    cv: Condvar,
    metrics: QueueMetrics,
}

impl<T> ShardedBatchQueue<T> {
    pub fn new(num_shards: usize) -> Self {
        let n = num_shards.max(1);
        Self {
            shards: (0..n)
                .map(|_| Shard {
                    items: Mutex::new(VecDeque::new()),
                    len: AtomicUsize::new(0),
                })
                .collect(),
            total: AtomicUsize::new(0),
            closed: AtomicBool::new(false),
            ticket: AtomicU64::new(0),
            sleepers: AtomicUsize::new(0),
            epoch: AtomicU64::new(0),
            gate: Mutex::new(()),
            cv: Condvar::new(),
            metrics: QueueMetrics::default(),
        }
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Total queued items (sum over shards).
    pub fn len(&self) -> usize {
        self.total.load(Ordering::SeqCst)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn shard_len(&self, shard: usize) -> usize {
        self.shards[shard].len.load(Ordering::SeqCst)
    }

    pub fn metrics(&self) -> &QueueMetrics {
        &self.metrics
    }

    fn wake_sleepers(&self) {
        if self.sleepers.load(Ordering::SeqCst) > 0 {
            let g = self.gate.lock().unwrap();
            self.epoch.fetch_add(1, Ordering::SeqCst);
            drop(g);
            self.cv.notify_all();
        }
    }

    /// Push one item (power-of-two-choices shard routing).  Returns
    /// `false` (and counts the rejection) once the queue is closed; the
    /// closed check is re-done under the shard lock, so after `close()`
    /// returns no push can slip an item in.
    pub fn push(&self, item: WorkItem<T>) -> bool {
        if self.closed.load(Ordering::SeqCst) {
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        let n = self.shards.len();
        let idx = if n == 1 {
            0
        } else {
            let h = splitmix64(self.ticket.fetch_add(1, Ordering::Relaxed));
            let a = (h as u32 as usize) % n;
            let b = ((h >> 32) as usize) % n;
            let la = self.shards[a].len.load(Ordering::Relaxed);
            let lb = self.shards[b].len.load(Ordering::Relaxed);
            if la <= lb {
                a
            } else {
                b
            }
        };
        {
            let mut g = self.shards[idx].items.lock().unwrap();
            if self.closed.load(Ordering::SeqCst) {
                drop(g);
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                return false;
            }
            g.push_back(item);
            // count while holding the shard lock: a pop (which also
            // holds it) must never see an item whose increment is still
            // pending, or len/total could transiently wrap below zero
            // and close()+drain could miss an accepted item
            self.shards[idx].len.fetch_add(1, Ordering::SeqCst);
            self.total.fetch_add(1, Ordering::SeqCst);
        }
        self.metrics.pushed.fetch_add(1, Ordering::Relaxed);
        self.wake_sleepers();
        true
    }

    /// Non-blocking batched pop with work stealing: drain `home` first,
    /// then the other shards in ring order, until `max_batch` items are
    /// collected or every shard is empty.  Returns an empty vec when
    /// there is nothing to pop.
    pub fn try_pop_batch(
        &self,
        home: usize,
        max_batch: usize,
    ) -> Vec<WorkItem<T>> {
        let n = self.shards.len();
        let cap = max_batch.max(1);
        let mut out = Vec::new();
        for k in 0..n {
            let idx = (home + k) % n;
            let shard = &self.shards[idx];
            if shard.len.load(Ordering::SeqCst) == 0 {
                continue;
            }
            let mut g = shard.items.lock().unwrap();
            while out.len() < cap {
                match g.pop_front() {
                    Some(it) => {
                        shard.len.fetch_sub(1, Ordering::SeqCst);
                        self.total.fetch_sub(1, Ordering::SeqCst);
                        out.push(it);
                    }
                    None => break,
                }
            }
            drop(g);
            if out.len() >= cap {
                break;
            }
        }
        if !out.is_empty() {
            self.metrics.popped.fetch_add(out.len() as u64, Ordering::Relaxed);
        }
        out
    }

    /// Blocking batched pop (steals like `try_pop_batch`).  Returns
    /// `None` once the queue is closed and fully drained.
    pub fn pop_batch(
        &self,
        home: usize,
        max_batch: usize,
    ) -> Option<Vec<WorkItem<T>>> {
        loop {
            self.sleepers.fetch_add(1, Ordering::SeqCst);
            let seen = self.epoch.load(Ordering::SeqCst);
            let out = self.try_pop_batch(home, max_batch);
            if !out.is_empty() {
                self.sleepers.fetch_sub(1, Ordering::SeqCst);
                return Some(out);
            }
            if self.closed.load(Ordering::SeqCst)
                && self.total.load(Ordering::SeqCst) == 0
            {
                self.sleepers.fetch_sub(1, Ordering::SeqCst);
                return None;
            }
            {
                let mut g = self.gate.lock().unwrap();
                while self.epoch.load(Ordering::SeqCst) == seen {
                    let (ng, res) = self
                        .cv
                        .wait_timeout(g, Duration::from_millis(50))
                        .unwrap();
                    g = ng;
                    if res.timed_out() {
                        // safety tick: re-scan even without a wakeup so a
                        // raced drain/close can never strand this waiter
                        break;
                    }
                }
            }
            self.sleepers.fetch_sub(1, Ordering::SeqCst);
        }
    }

    /// Close the queue: later pushes are rejected, consumers drain the
    /// remaining items and then get `None`.  Serialises with in-flight
    /// pushes (every shard lock is taken once), so after `close()`
    /// returns the item set is final.
    pub fn close(&self) {
        self.closed.store(true, Ordering::SeqCst);
        for s in &self.shards {
            drop(s.items.lock().unwrap());
        }
        let g = self.gate.lock().unwrap();
        self.epoch.fetch_add(1, Ordering::SeqCst);
        drop(g);
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn item(v: f32) -> WorkItem<u32> {
        WorkItem {
            payload: vec![v],
            server_arrival: Instant::now(),
            budget_ms: 100.0,
            accumulated_ms: 0.0,
            ctx: v as u32,
        }
    }

    #[test]
    fn greedy_batching() {
        let q = BatchQueue::new();
        for i in 0..5 {
            assert!(q.push(item(i as f32)));
        }
        let b = q.pop_batch(4).unwrap();
        assert_eq!(b.len(), 4);
        let b = q.pop_batch(4).unwrap();
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn close_drains_then_none_and_counts_rejections() {
        let q = BatchQueue::new();
        assert!(q.push(item(1.0)));
        q.close();
        assert_eq!(q.pop_batch(8).unwrap().len(), 1);
        assert!(q.pop_batch(8).is_none());
        // pushes after close are rejected, not silently dropped
        assert!(!q.push(item(2.0)));
        assert!(q.pop_batch(8).is_none());
        assert_eq!(q.metrics().pushed(), 1);
        assert_eq!(q.metrics().popped(), 1);
        assert_eq!(q.metrics().rejected(), 1);
    }

    #[test]
    fn blocking_pop_wakes_on_push() {
        let q = Arc::new(BatchQueue::new());
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.pop_batch(2).unwrap().len());
        std::thread::sleep(Duration::from_millis(20));
        q.push(item(1.0));
        assert_eq!(h.join().unwrap(), 1);
    }

    #[test]
    fn timeout_pop_returns_empty() {
        let q: BatchQueue<u32> = BatchQueue::new();
        let b = q
            .pop_batch_timeout(4, Duration::from_millis(10))
            .unwrap();
        assert!(b.is_empty());
    }

    #[test]
    fn multiple_consumers_share_work() {
        let q = Arc::new(BatchQueue::new());
        for i in 0..64 {
            q.push(item(i as f32));
        }
        q.close();
        let mut handles = Vec::new();
        for _ in 0..4 {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                let mut n = 0;
                while let Some(b) = q.pop_batch(4) {
                    n += b.len();
                }
                n
            }));
        }
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 64);
    }

    #[test]
    fn sharded_pops_each_item_exactly_once() {
        let q: ShardedBatchQueue<u32> = ShardedBatchQueue::new(8);
        for i in 0..100 {
            assert!(q.push(item(i as f32)));
        }
        assert_eq!(q.len(), 100);
        let mut got = Vec::new();
        loop {
            let b = q.try_pop_batch(3, 7);
            if b.is_empty() {
                break;
            }
            assert!(b.len() <= 7);
            got.extend(b.into_iter().map(|w| w.ctx));
        }
        got.sort_unstable();
        assert_eq!(got, (0..100).collect::<Vec<u32>>());
        assert!(q.is_empty());
        assert_eq!(q.metrics().pushed(), 100);
        assert_eq!(q.metrics().popped(), 100);
    }

    #[test]
    fn sharded_steals_to_fill_a_batch() {
        // p2c routing spreads 32 items over 8 shards; a single pop from
        // home shard 0 must steal across all of them
        let q: ShardedBatchQueue<u32> = ShardedBatchQueue::new(8);
        for i in 0..32 {
            q.push(item(i as f32));
        }
        let b = q.try_pop_batch(0, 32);
        assert_eq!(b.len(), 32);
        assert!(q.is_empty());
    }

    #[test]
    fn sharded_close_rejects_and_counts() {
        let q: ShardedBatchQueue<u32> = ShardedBatchQueue::new(4);
        assert!(q.push(item(1.0)));
        q.close();
        assert!(!q.push(item(2.0)));
        assert_eq!(q.metrics().rejected(), 1);
        // drains remaining, then None
        assert_eq!(q.pop_batch(0, 8).unwrap().len(), 1);
        assert!(q.pop_batch(0, 8).is_none());
        assert_eq!(q.metrics().pushed(), 1);
        assert_eq!(q.metrics().popped(), 1);
    }

    #[test]
    fn sharded_blocking_pop_wakes_on_push() {
        let q = Arc::new(ShardedBatchQueue::new(4));
        let q2 = q.clone();
        let h =
            std::thread::spawn(move || q2.pop_batch(1, 2).unwrap().len());
        std::thread::sleep(Duration::from_millis(20));
        q.push(item(1.0));
        assert_eq!(h.join().unwrap(), 1);
    }

    #[test]
    fn sharded_routing_balances_shards() {
        let q: ShardedBatchQueue<u32> = ShardedBatchQueue::new(4);
        for i in 0..400 {
            q.push(item(i as f32));
        }
        // power-of-two-choices keeps the max/min spread tight
        let lens: Vec<usize> = (0..4).map(|s| q.shard_len(s)).collect();
        let (min, max) =
            (lens.iter().min().unwrap(), lens.iter().max().unwrap());
        assert!(max - min <= 20, "unbalanced shards: {lens:?}");
        assert_eq!(lens.iter().sum::<usize>(), 400);
    }
}
