//! Shared batch queues (paper §5.1: "a request ... is buffered as a
//! tensor in a queue for the corresponding fragment.  This queue is
//! shared by all the instances for each DNN fragment, which process
//! requests in batch from the queue").

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// An item travelling through the serving pipeline.
#[derive(Debug)]
pub struct WorkItem<T> {
    pub payload: Vec<f32>,
    /// When the request entered the server.
    pub server_arrival: Instant,
    /// Server-side budget (ms) for SLO-drop decisions.
    pub budget_ms: f64,
    /// Modeled server time already accumulated in earlier stages (ms).
    pub accumulated_ms: f64,
    /// Caller context (client id, seq, response channel, ...).
    pub ctx: T,
}

struct Inner<T> {
    items: VecDeque<WorkItem<T>>,
    closed: bool,
}

/// MPMC batch queue: producers push single items; consumer instances pop
/// greedy batches up to their batch size.
pub struct BatchQueue<T> {
    inner: Mutex<Inner<T>>,
    cv: Condvar,
}

impl<T> Default for BatchQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> BatchQueue<T> {
    pub fn new() -> Self {
        Self {
            inner: Mutex::new(Inner { items: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
        }
    }

    pub fn push(&self, item: WorkItem<T>) {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return; // shutting down: drop silently
        }
        g.items.push_back(item);
        drop(g);
        self.cv.notify_one();
    }

    /// Pop up to `max_batch` items: blocks for the first item, then
    /// drains whatever else is immediately available (greedy batching).
    /// Returns `None` once closed and drained.
    pub fn pop_batch(&self, max_batch: usize) -> Option<Vec<WorkItem<T>>> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if !g.items.is_empty() {
                let n = g.items.len().min(max_batch.max(1));
                return Some(g.items.drain(..n).collect());
            }
            if g.closed {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    /// Pop up to `max_batch`, blocking for the first item and then
    /// waiting up to `window` for the batch to fill (the §4.3 envelope
    /// reserves one execution time for queueing, so waiting that long to
    /// reach the *planned* batch size keeps the SLO math intact while
    /// hitting the planned throughput).
    pub fn pop_batch_window(
        &self,
        max_batch: usize,
        window: Duration,
    ) -> Option<Vec<WorkItem<T>>> {
        let mut g = self.inner.lock().unwrap();
        // phase 1: block for the first item
        loop {
            if !g.items.is_empty() {
                break;
            }
            if g.closed {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
        // phase 2: give the batch `window` to fill
        let deadline = Instant::now() + window;
        while g.items.len() < max_batch.max(1) && !g.closed {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (ng, _) = self.cv.wait_timeout(g, deadline - now).unwrap();
            g = ng;
        }
        let n = g.items.len().min(max_batch.max(1));
        Some(g.items.drain(..n).collect())
    }

    /// Like `pop_batch` but gives up after `timeout` (for pollers).
    pub fn pop_batch_timeout(
        &self,
        max_batch: usize,
        timeout: Duration,
    ) -> Option<Vec<WorkItem<T>>> {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        loop {
            if !g.items.is_empty() {
                let n = g.items.len().min(max_batch.max(1));
                return Some(g.items.drain(..n).collect());
            }
            if g.closed {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return Some(Vec::new());
            }
            let (ng, res) = self.cv.wait_timeout(g, deadline - now).unwrap();
            g = ng;
            if res.timed_out() && g.items.is_empty() {
                return Some(Vec::new());
            }
        }
    }

    /// Close the queue: consumers drain remaining items then get `None`.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn item(v: f32) -> WorkItem<u32> {
        WorkItem {
            payload: vec![v],
            server_arrival: Instant::now(),
            budget_ms: 100.0,
            accumulated_ms: 0.0,
            ctx: v as u32,
        }
    }

    #[test]
    fn greedy_batching() {
        let q = BatchQueue::new();
        for i in 0..5 {
            q.push(item(i as f32));
        }
        let b = q.pop_batch(4).unwrap();
        assert_eq!(b.len(), 4);
        let b = q.pop_batch(4).unwrap();
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn close_drains_then_none() {
        let q = BatchQueue::new();
        q.push(item(1.0));
        q.close();
        assert_eq!(q.pop_batch(8).unwrap().len(), 1);
        assert!(q.pop_batch(8).is_none());
        // pushes after close are dropped
        q.push(item(2.0));
        assert!(q.pop_batch(8).is_none());
    }

    #[test]
    fn blocking_pop_wakes_on_push() {
        let q = Arc::new(BatchQueue::new());
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.pop_batch(2).unwrap().len());
        std::thread::sleep(Duration::from_millis(20));
        q.push(item(1.0));
        assert_eq!(h.join().unwrap(), 1);
    }

    #[test]
    fn timeout_pop_returns_empty() {
        let q: BatchQueue<u32> = BatchQueue::new();
        let b = q
            .pop_batch_timeout(4, Duration::from_millis(10))
            .unwrap();
        assert!(b.is_empty());
    }

    #[test]
    fn multiple_consumers_share_work() {
        let q = Arc::new(BatchQueue::new());
        for i in 0..64 {
            q.push(item(i as f32));
        }
        q.close();
        let mut handles = Vec::new();
        for _ in 0..4 {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                let mut n = 0;
                while let Some(b) = q.pop_batch(4) {
                    n += b.len();
                }
                n
            }));
        }
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 64);
    }
}
