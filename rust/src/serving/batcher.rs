//! Shared batch queues (paper §5.1: "a request ... is buffered as a
//! tensor in a queue for the corresponding fragment.  This queue is
//! shared by all the instances for each DNN fragment, which process
//! requests in batch from the queue").
//!
//! Two implementations coexist:
//!
//! * [`BatchQueue`] — the reference implementation: one mutex + condvar
//!   around a `VecDeque`.  Correct and simple, but every producer and
//!   every consumer instance serialises on the same lock, which is the
//!   serving-path bottleneck at 10k-client scale.
//! * [`ShardedBatchQueue`] — one shard per planned instance.  Producers
//!   route with power-of-two-choices (pick two shards, push to the
//!   shorter), consumers pop from their home shard and steal from the
//!   others to fill a batch.  Contention drops from O(producers) on one
//!   lock to ~2 threads per shard lock in expectation.
//!
//! Both count traffic in [`QueueMetrics`]; in particular a `push` after
//! `close()` is *rejected* (returns `false`) and counted, never silently
//! dropped.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use crate::util::lock::{lock_counted, wait_recover, wait_timeout_recover};

/// Monotonic stamp for the lock-free arrival-rate EWMA, on the same
/// process-wide epoch as span timestamps ([`crate::obs::now_us`]).
fn epoch_us() -> u64 {
    // +1 so a stamp of 0 can mean "no arrival recorded yet"
    crate::obs::now_us() + 1
}

/// An item travelling through the serving pipeline.
#[derive(Debug)]
pub struct WorkItem<T> {
    pub payload: Vec<f32>,
    /// When the request entered the server.
    pub server_arrival: Instant,
    /// Server-side budget (ms) for SLO-drop decisions.
    pub budget_ms: f64,
    /// Modeled server time already accumulated in earlier stages (ms).
    pub accumulated_ms: f64,
    /// Caller context (client id, seq, response channel, ...).
    pub ctx: T,
}

/// Queue traffic counters (monotonic; read with `Ordering::Relaxed`).
#[derive(Debug, Default)]
pub struct QueueMetrics {
    /// Items accepted by `push`.
    pub pushed: AtomicU64,
    /// Items handed to consumers.
    pub popped: AtomicU64,
    /// Pushes refused because the queue was closed.
    pub rejected: AtomicU64,
    /// Poisoned-lock recoveries on this queue (a consumer panicked while
    /// holding a queue lock; the queue carried on).
    pub poisoned: AtomicU64,
    /// Micro-timestamp ([`epoch_us`]) of the last accepted push (0 =
    /// none yet).
    last_arrival_us: AtomicU64,
    /// EWMA of the inter-arrival gap in microseconds, stored as f64
    /// bits (0 = fewer than two arrivals).  Feeds the adaptive
    /// batch-formation window.
    ewma_gap_us: AtomicU64,
}

/// EWMA smoothing factor for inter-arrival gaps: ~20 arrivals of
/// memory, enough to ride out batch bursts without lagging a real
/// demand shift by more than a second at serving rates.
const ARRIVAL_EWMA_ALPHA: f64 = 0.05;

impl QueueMetrics {
    pub fn pushed(&self) -> u64 {
        self.pushed.load(Ordering::Relaxed)
    }
    pub fn popped(&self) -> u64 {
        self.popped.load(Ordering::Relaxed)
    }
    pub fn rejected(&self) -> u64 {
        self.rejected.load(Ordering::Relaxed)
    }
    pub fn poisoned(&self) -> u64 {
        self.poisoned.load(Ordering::Relaxed)
    }

    /// Fold one accepted arrival into the inter-arrival EWMA.  Racy by
    /// design (plain load/store, no CAS loop): a lost update skews the
    /// estimate by one gap, which the EWMA absorbs — the rate feeds a
    /// batching heuristic, not an invariant.
    fn note_arrival(&self) {
        let now = epoch_us();
        let prev = self.last_arrival_us.swap(now, Ordering::Relaxed);
        if prev == 0 || now <= prev {
            return;
        }
        let gap = (now - prev) as f64;
        let old = f64::from_bits(self.ewma_gap_us.load(Ordering::Relaxed));
        let new = if old <= 0.0 {
            gap
        } else {
            (1.0 - ARRIVAL_EWMA_ALPHA) * old + ARRIVAL_EWMA_ALPHA * gap
        };
        self.ewma_gap_us.store(new.to_bits(), Ordering::Relaxed);
    }

    /// Observed arrival rate (requests/s) from the inter-arrival EWMA;
    /// 0.0 until at least two arrivals have been recorded.
    pub fn arrival_rate_rps(&self) -> f64 {
        let gap = f64::from_bits(self.ewma_gap_us.load(Ordering::Relaxed));
        if gap <= 0.0 {
            0.0
        } else {
            1e6 / gap
        }
    }
}

struct Inner<T> {
    items: VecDeque<WorkItem<T>>,
    closed: bool,
}

/// MPMC batch queue: producers push single items; consumer instances pop
/// greedy batches up to their batch size.
pub struct BatchQueue<T> {
    inner: Mutex<Inner<T>>,
    cv: Condvar,
    metrics: QueueMetrics,
}

impl<T> Default for BatchQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> BatchQueue<T> {
    pub fn new() -> Self {
        Self {
            inner: Mutex::new(Inner { items: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
            metrics: QueueMetrics::default(),
        }
    }

    /// Acquire the queue lock, recovering (and counting) poisoning: a
    /// consumer that panics while holding the lock must not take every
    /// other producer/consumer down with it.
    fn lock(&self) -> MutexGuard<'_, Inner<T>> {
        lock_counted(&self.inner, Some(&self.metrics.poisoned))
    }

    /// Push one item.  Returns `false` (and counts the rejection) if the
    /// queue has been closed; the item is dropped in that case.
    pub fn push(&self, item: WorkItem<T>) -> bool {
        self.push_or_return(item).is_none()
    }

    /// Like [`Self::push`], but a rejected item is handed back (`Some`)
    /// instead of dropped, so the caller can deliver a drop notice to
    /// its context rather than losing it silently.
    pub fn push_or_return(&self, item: WorkItem<T>) -> Option<WorkItem<T>> {
        let mut g = self.lock();
        if g.closed {
            drop(g);
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return Some(item);
        }
        g.items.push_back(item);
        drop(g);
        self.metrics.pushed.fetch_add(1, Ordering::Relaxed);
        self.metrics.note_arrival();
        self.cv.notify_one();
        None
    }

    /// Count handed-out items *while still holding the queue lock*:
    /// `len()` also takes the lock, so any observer that reads the
    /// queue as drained is guaranteed to see the matching `popped`
    /// count — the property the graceful-drain check
    /// (`empty ∧ completed == popped`) relies on.
    fn count_popped(&self, n: usize) {
        if n > 0 {
            self.metrics.popped.fetch_add(n as u64, Ordering::SeqCst);
        }
    }

    /// Pop up to `max_batch` items: blocks for the first item, then
    /// drains whatever else is immediately available (greedy batching).
    /// Returns `None` once closed and drained.
    pub fn pop_batch(&self, max_batch: usize) -> Option<Vec<WorkItem<T>>> {
        let mut g = self.lock();
        loop {
            if !g.items.is_empty() {
                let n = g.items.len().min(max_batch.max(1));
                let out: Vec<_> = g.items.drain(..n).collect();
                self.count_popped(out.len());
                drop(g);
                return Some(out);
            }
            if g.closed {
                return None;
            }
            g = wait_recover(&self.cv, g);
        }
    }

    /// Pop up to `max_batch`, blocking for the first item and then
    /// waiting up to `window` for the batch to fill (the §4.3 envelope
    /// reserves one execution time for queueing, so waiting that long to
    /// reach the *planned* batch size keeps the SLO math intact while
    /// hitting the planned throughput).
    pub fn pop_batch_window(
        &self,
        max_batch: usize,
        window: Duration,
    ) -> Option<Vec<WorkItem<T>>> {
        let mut g = self.lock();
        // phase 1: block for the first item
        loop {
            if !g.items.is_empty() {
                break;
            }
            if g.closed {
                return None;
            }
            g = wait_recover(&self.cv, g);
        }
        // phase 2: give the batch `window` to fill
        let deadline = Instant::now() + window;
        while g.items.len() < max_batch.max(1) && !g.closed {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (ng, _) =
                wait_timeout_recover(&self.cv, g, deadline - now);
            g = ng;
        }
        let n = g.items.len().min(max_batch.max(1));
        let out: Vec<_> = g.items.drain(..n).collect();
        self.count_popped(out.len());
        drop(g);
        Some(out)
    }

    /// Like `pop_batch` but gives up after `timeout` (for pollers).
    pub fn pop_batch_timeout(
        &self,
        max_batch: usize,
        timeout: Duration,
    ) -> Option<Vec<WorkItem<T>>> {
        let deadline = Instant::now() + timeout;
        let mut g = self.lock();
        loop {
            if !g.items.is_empty() {
                let n = g.items.len().min(max_batch.max(1));
                let out: Vec<_> = g.items.drain(..n).collect();
                self.count_popped(out.len());
                drop(g);
                return Some(out);
            }
            if g.closed {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return Some(Vec::new());
            }
            let (ng, timed_out) =
                wait_timeout_recover(&self.cv, g, deadline - now);
            g = ng;
            if timed_out && g.items.is_empty() {
                return Some(Vec::new());
            }
        }
    }

    /// Close the queue: consumers drain remaining items then get `None`.
    pub fn close(&self) {
        self.lock().closed = true;
        self.cv.notify_all();
    }

    /// Chaos hook: poison the queue mutex the way a panicking consumer
    /// would (panic while holding the lock, caught at this boundary).
    /// Subsequent operations must recover and count the recovery.
    pub fn poison(&self) {
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = self.lock();
            panic!("injected queue poison");
        }));
    }

    pub fn len(&self) -> usize {
        self.lock().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn metrics(&self) -> &QueueMetrics {
        &self.metrics
    }
}

/// SplitMix64 — cheap stateless mixer for push routing.
fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

struct Shard<T> {
    items: Mutex<VecDeque<WorkItem<T>>>,
    /// Cached length so routing never takes a lock it will not use.
    len: AtomicUsize,
    /// Per-shard close flag (live reconfiguration: a retiring instance's
    /// shard stops accepting work while the rest of the queue stays
    /// open).  Consumers still drain a closed shard.
    closed: AtomicBool,
}

/// MPMC batch queue sharded per consumer instance.
///
/// * `push` routes with power-of-two-choices over the per-shard length
///   counters, so producers spread across shard locks instead of
///   serialising on one mutex.
/// * `try_pop_batch(home, n)` drains the consumer's home shard first and
///   then *steals* from the other shards (in ring order) until the batch
///   is full — an instance never idles while any shard has work.
/// * `pop_batch` adds blocking on top for consumers without their own
///   scheduler (tests, simple drivers); the pooled executor only uses
///   the non-blocking form and parks on its own notifier.
pub struct ShardedBatchQueue<T> {
    shards: Vec<Shard<T>>,
    total: AtomicUsize,
    closed: AtomicBool,
    ticket: AtomicU64,
    /// Blocking-pop support: waiters register in `sleepers` and wait for
    /// `epoch` to move on (pushes only take the gate when someone sleeps).
    sleepers: AtomicUsize,
    epoch: AtomicU64,
    gate: Mutex<()>,
    cv: Condvar,
    metrics: QueueMetrics,
}

impl<T> ShardedBatchQueue<T> {
    pub fn new(num_shards: usize) -> Self {
        let n = num_shards.max(1);
        Self {
            shards: (0..n)
                .map(|_| Shard {
                    items: Mutex::new(VecDeque::new()),
                    len: AtomicUsize::new(0),
                    closed: AtomicBool::new(false),
                })
                .collect(),
            total: AtomicUsize::new(0),
            closed: AtomicBool::new(false),
            ticket: AtomicU64::new(0),
            sleepers: AtomicUsize::new(0),
            epoch: AtomicU64::new(0),
            gate: Mutex::new(()),
            cv: Condvar::new(),
            metrics: QueueMetrics::default(),
        }
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Total queued items (sum over shards).
    pub fn len(&self) -> usize {
        self.total.load(Ordering::SeqCst)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn shard_len(&self, shard: usize) -> usize {
        self.shards[shard].len.load(Ordering::SeqCst)
    }

    pub fn metrics(&self) -> &QueueMetrics {
        &self.metrics
    }

    /// Acquire one shard's lock, recovering (and counting) poisoning.
    fn shard_lock<'a>(
        &'a self,
        shard: &'a Shard<T>,
    ) -> MutexGuard<'a, VecDeque<WorkItem<T>>> {
        lock_counted(&shard.items, Some(&self.metrics.poisoned))
    }

    fn wake_sleepers(&self) {
        if self.sleepers.load(Ordering::SeqCst) > 0 {
            let g = lock_counted(&self.gate, Some(&self.metrics.poisoned));
            self.epoch.fetch_add(1, Ordering::SeqCst);
            drop(g);
            self.cv.notify_all();
        }
    }

    /// Push one item (power-of-two-choices shard routing over the open
    /// shards).  Returns `false` (and counts the rejection) once the
    /// queue — or every shard — is closed; the closed checks are re-done
    /// under the shard lock, so after `close()` / `close_shard()`
    /// returns no push can slip an item into a closed shard.
    pub fn push(&self, item: WorkItem<T>) -> bool {
        self.push_inner(item, true).is_none()
    }

    /// Like [`Self::push`], but a rejected item is handed back (`Some`)
    /// so the caller can drop-notice its context instead of losing it.
    pub fn push_or_return(&self, item: WorkItem<T>) -> Option<WorkItem<T>> {
        self.push_inner(item, true)
    }

    /// The routed push shared by `push` and the `close_shard` handoff:
    /// `None` = accepted, `Some(item)` = refused (the item is handed
    /// back so the handoff path can park it instead of losing it).
    /// `count_metrics` is false on the handoff path: a rerouted item was
    /// already counted as pushed when it first entered the queue.
    fn push_inner(
        &self,
        item: WorkItem<T>,
        count_metrics: bool,
    ) -> Option<WorkItem<T>> {
        if self.closed.load(Ordering::SeqCst) {
            if count_metrics {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            }
            return Some(item);
        }
        let n = self.shards.len();
        // p2c over the shard lengths picks the starting shard; the scan
        // below walks on from it past closed shards (the common case —
        // no closed shard — commits on the first iteration)
        let start = if n == 1 {
            0
        } else {
            let h = splitmix64(self.ticket.fetch_add(1, Ordering::Relaxed));
            let a = (h as u32 as usize) % n;
            let b = ((h >> 32) as usize) % n;
            let a_closed = self.shards[a].closed.load(Ordering::Relaxed);
            let b_closed = self.shards[b].closed.load(Ordering::Relaxed);
            let la = self.shards[a].len.load(Ordering::Relaxed);
            let lb = self.shards[b].len.load(Ordering::Relaxed);
            if b_closed || (!a_closed && la <= lb) {
                a
            } else {
                b
            }
        };
        let mut item = Some(item);
        for k in 0..n {
            let idx = (start + k) % n;
            let shard = &self.shards[idx];
            if shard.closed.load(Ordering::SeqCst) {
                continue;
            }
            let mut g = self.shard_lock(shard);
            if self.closed.load(Ordering::SeqCst) {
                drop(g);
                if count_metrics {
                    self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                }
                return item.take();
            }
            if shard.closed.load(Ordering::SeqCst) {
                // raced with close_shard: its drain already ran, so an
                // item slipped in here would strand — try the next shard
                continue;
            }
            g.push_back(item.take().expect("item pushed at most once"));
            // count while holding the shard lock: a pop (which also
            // holds it) must never see an item whose increment is still
            // pending, or len/total could transiently wrap below zero
            // and close()+drain could miss an accepted item
            shard.len.fetch_add(1, Ordering::SeqCst);
            self.total.fetch_add(1, Ordering::SeqCst);
            drop(g);
            if count_metrics {
                self.metrics.pushed.fetch_add(1, Ordering::Relaxed);
                self.metrics.note_arrival();
            }
            self.wake_sleepers();
            return None;
        }
        // every shard is closed: reject like a closed queue
        if count_metrics {
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
        }
        item.take()
    }

    /// Whether a shard has been closed via [`Self::close_shard`].
    pub fn shard_closed(&self, shard: usize) -> bool {
        self.shards[shard].closed.load(Ordering::SeqCst)
    }

    /// Close one shard and hand its backlog to the remaining open
    /// shards: a retiring instance stops receiving work and its queued
    /// items reroute instead of draining cold.  Returns the number of
    /// items rerouted.  When no other shard is open the backlog stays
    /// in the closed shard (consumers can still drain it); producers
    /// then see the queue as closed.
    ///
    /// This is the queue-level primitive for *incremental server
    /// surgery* (shrinking a live stage's instance count in place — a
    /// ROADMAP follow-on); today's plan swap prepares a whole new core
    /// and drains the old one stage-by-stage, so production traffic
    /// does not exercise this path yet.
    pub fn close_shard(&self, shard: usize) -> usize {
        let s = &self.shards[shard];
        s.closed.store(true, Ordering::SeqCst);
        // serialize with in-flight pushes: after the lock round-trip no
        // push can add to this shard, so the drained backlog is final
        let backlog: Vec<WorkItem<T>> = {
            let mut g = self.shard_lock(s);
            let k = g.len();
            if k > 0 {
                s.len.fetch_sub(k, Ordering::SeqCst);
                self.total.fetch_sub(k, Ordering::SeqCst);
            }
            g.drain(..).collect()
        };
        let mut rerouted = 0;
        for item in backlog {
            match self.push_inner(item, false) {
                None => rerouted += 1,
                Some(item) => {
                    // no open shard left: park the item back in this
                    // (now closed) shard — consumers drain closed
                    // shards, so nothing is lost
                    let mut g = self.shard_lock(s);
                    g.push_back(item);
                    s.len.fetch_add(1, Ordering::SeqCst);
                    self.total.fetch_add(1, Ordering::SeqCst);
                }
            }
        }
        self.wake_sleepers();
        rerouted
    }

    /// Non-blocking batched pop with work stealing: drain `home` first,
    /// then the other shards in ring order, until `max_batch` items are
    /// collected or every shard is empty.  Returns an empty vec when
    /// there is nothing to pop.
    pub fn try_pop_batch(
        &self,
        home: usize,
        max_batch: usize,
    ) -> Vec<WorkItem<T>> {
        let n = self.shards.len();
        let cap = max_batch.max(1);
        let mut out = Vec::new();
        for k in 0..n {
            let idx = (home + k) % n;
            let shard = &self.shards[idx];
            if shard.len.load(Ordering::SeqCst) == 0 {
                continue;
            }
            let mut g = self.shard_lock(shard);
            let mut taken = 0usize;
            while out.len() < cap {
                match g.pop_front() {
                    Some(it) => {
                        taken += 1;
                        out.push(it);
                    }
                    None => break,
                }
            }
            if taken > 0 {
                // popped is counted BEFORE the length decrements become
                // visible (all under the shard lock): an observer that
                // reads the queue as empty is then guaranteed to see
                // every removed item in `popped` — the graceful-drain
                // check (`empty ∧ completed == popped`) depends on
                // exactly this ordering
                self.metrics
                    .popped
                    .fetch_add(taken as u64, Ordering::SeqCst);
                shard.len.fetch_sub(taken, Ordering::SeqCst);
                self.total.fetch_sub(taken, Ordering::SeqCst);
            }
            drop(g);
            if out.len() >= cap {
                break;
            }
        }
        out
    }

    /// Blocking batched pop (steals like `try_pop_batch`).  Returns
    /// `None` once the queue is closed and fully drained.
    pub fn pop_batch(
        &self,
        home: usize,
        max_batch: usize,
    ) -> Option<Vec<WorkItem<T>>> {
        loop {
            self.sleepers.fetch_add(1, Ordering::SeqCst);
            let seen = self.epoch.load(Ordering::SeqCst);
            let out = self.try_pop_batch(home, max_batch);
            if !out.is_empty() {
                self.sleepers.fetch_sub(1, Ordering::SeqCst);
                return Some(out);
            }
            if self.closed.load(Ordering::SeqCst)
                && self.total.load(Ordering::SeqCst) == 0
            {
                self.sleepers.fetch_sub(1, Ordering::SeqCst);
                return None;
            }
            {
                let mut g =
                    lock_counted(&self.gate, Some(&self.metrics.poisoned));
                while self.epoch.load(Ordering::SeqCst) == seen {
                    let (ng, timed_out) = wait_timeout_recover(
                        &self.cv,
                        g,
                        Duration::from_millis(50),
                    );
                    g = ng;
                    if timed_out {
                        // safety tick: re-scan even without a wakeup so a
                        // raced drain/close can never strand this waiter
                        break;
                    }
                }
            }
            self.sleepers.fetch_sub(1, Ordering::SeqCst);
        }
    }

    /// Close the queue: later pushes are rejected, consumers drain the
    /// remaining items and then get `None`.  Serialises with in-flight
    /// pushes (every shard lock is taken once), so after `close()`
    /// returns the item set is final.
    pub fn close(&self) {
        self.closed.store(true, Ordering::SeqCst);
        for s in &self.shards {
            drop(self.shard_lock(s));
        }
        let g = lock_counted(&self.gate, Some(&self.metrics.poisoned));
        self.epoch.fetch_add(1, Ordering::SeqCst);
        drop(g);
        self.cv.notify_all();
    }

    /// Chaos hook: poison one shard's mutex the way a panicking consumer
    /// would (panic while holding the lock, caught at this boundary).
    /// Pushes, pops and drains must recover and count the recovery.
    pub fn poison_shard(&self, shard: usize) {
        let s = &self.shards[shard];
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = self.shard_lock(s);
            panic!("injected shard poison");
        }));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn item(v: f32) -> WorkItem<u32> {
        WorkItem {
            payload: vec![v],
            server_arrival: Instant::now(),
            budget_ms: 100.0,
            accumulated_ms: 0.0,
            ctx: v as u32,
        }
    }

    #[test]
    fn greedy_batching() {
        let q = BatchQueue::new();
        for i in 0..5 {
            assert!(q.push(item(i as f32)));
        }
        let b = q.pop_batch(4).unwrap();
        assert_eq!(b.len(), 4);
        let b = q.pop_batch(4).unwrap();
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn close_drains_then_none_and_counts_rejections() {
        let q = BatchQueue::new();
        assert!(q.push(item(1.0)));
        q.close();
        assert_eq!(q.pop_batch(8).unwrap().len(), 1);
        assert!(q.pop_batch(8).is_none());
        // pushes after close are rejected, not silently dropped
        assert!(!q.push(item(2.0)));
        assert!(q.pop_batch(8).is_none());
        assert_eq!(q.metrics().pushed(), 1);
        assert_eq!(q.metrics().popped(), 1);
        assert_eq!(q.metrics().rejected(), 1);
    }

    #[test]
    fn blocking_pop_wakes_on_push() {
        let q = Arc::new(BatchQueue::new());
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.pop_batch(2).unwrap().len());
        std::thread::sleep(Duration::from_millis(20));
        q.push(item(1.0));
        assert_eq!(h.join().unwrap(), 1);
    }

    #[test]
    fn timeout_pop_returns_empty() {
        let q: BatchQueue<u32> = BatchQueue::new();
        let b = q
            .pop_batch_timeout(4, Duration::from_millis(10))
            .unwrap();
        assert!(b.is_empty());
    }

    #[test]
    fn multiple_consumers_share_work() {
        let q = Arc::new(BatchQueue::new());
        for i in 0..64 {
            q.push(item(i as f32));
        }
        q.close();
        let mut handles = Vec::new();
        for _ in 0..4 {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                let mut n = 0;
                while let Some(b) = q.pop_batch(4) {
                    n += b.len();
                }
                n
            }));
        }
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 64);
    }

    #[test]
    fn sharded_pops_each_item_exactly_once() {
        let q: ShardedBatchQueue<u32> = ShardedBatchQueue::new(8);
        for i in 0..100 {
            assert!(q.push(item(i as f32)));
        }
        assert_eq!(q.len(), 100);
        let mut got = Vec::new();
        loop {
            let b = q.try_pop_batch(3, 7);
            if b.is_empty() {
                break;
            }
            assert!(b.len() <= 7);
            got.extend(b.into_iter().map(|w| w.ctx));
        }
        got.sort_unstable();
        assert_eq!(got, (0..100).collect::<Vec<u32>>());
        assert!(q.is_empty());
        assert_eq!(q.metrics().pushed(), 100);
        assert_eq!(q.metrics().popped(), 100);
    }

    #[test]
    fn sharded_steals_to_fill_a_batch() {
        // p2c routing spreads 32 items over 8 shards; a single pop from
        // home shard 0 must steal across all of them
        let q: ShardedBatchQueue<u32> = ShardedBatchQueue::new(8);
        for i in 0..32 {
            q.push(item(i as f32));
        }
        let b = q.try_pop_batch(0, 32);
        assert_eq!(b.len(), 32);
        assert!(q.is_empty());
    }

    #[test]
    fn sharded_close_rejects_and_counts() {
        let q: ShardedBatchQueue<u32> = ShardedBatchQueue::new(4);
        assert!(q.push(item(1.0)));
        q.close();
        assert!(!q.push(item(2.0)));
        assert_eq!(q.metrics().rejected(), 1);
        // drains remaining, then None
        assert_eq!(q.pop_batch(0, 8).unwrap().len(), 1);
        assert!(q.pop_batch(0, 8).is_none());
        assert_eq!(q.metrics().pushed(), 1);
        assert_eq!(q.metrics().popped(), 1);
    }

    #[test]
    fn sharded_blocking_pop_wakes_on_push() {
        let q = Arc::new(ShardedBatchQueue::new(4));
        let q2 = q.clone();
        let h =
            std::thread::spawn(move || q2.pop_batch(1, 2).unwrap().len());
        std::thread::sleep(Duration::from_millis(20));
        q.push(item(1.0));
        assert_eq!(h.join().unwrap(), 1);
    }

    #[test]
    fn arrival_rate_tracks_push_cadence() {
        let q: BatchQueue<u32> = BatchQueue::new();
        assert_eq!(q.metrics().arrival_rate_rps(), 0.0);
        q.push(item(0.0));
        // one arrival: still no gap to estimate from
        assert_eq!(q.metrics().arrival_rate_rps(), 0.0);
        for i in 1..40 {
            std::thread::sleep(Duration::from_millis(1));
            q.push(item(i as f32));
        }
        let rate = q.metrics().arrival_rate_rps();
        // ~1 kHz cadence; sleep overshoot only slows it down, so accept
        // a wide band that still rules out nonsense
        assert!(rate > 2.0 && rate < 2000.0, "rate {rate}");
        // sharded queue feeds the same estimator
        let s: ShardedBatchQueue<u32> = ShardedBatchQueue::new(4);
        for i in 0..20 {
            std::thread::sleep(Duration::from_millis(1));
            s.push(item(i as f32));
        }
        assert!(s.metrics().arrival_rate_rps() > 0.0);
    }

    #[test]
    fn close_shard_reroutes_backlog_exactly_once() {
        let q: ShardedBatchQueue<u32> = ShardedBatchQueue::new(4);
        for i in 0..80 {
            assert!(q.push(item(i as f32)));
        }
        let before = q.shard_len(0);
        let rerouted = q.close_shard(0);
        assert_eq!(rerouted, before, "whole backlog reroutes");
        assert!(q.shard_closed(0));
        assert_eq!(q.shard_len(0), 0);
        assert_eq!(q.len(), 80, "no item lost in the handoff");
        // new pushes never land on the closed shard
        for i in 80..160 {
            assert!(q.push(item(i as f32)));
        }
        assert_eq!(q.shard_len(0), 0);
        // pushed metric counts first entries only, not the reroute
        assert_eq!(q.metrics().pushed(), 160);
        // everything pops exactly once
        let mut got = Vec::new();
        loop {
            let b = q.try_pop_batch(1, 16);
            if b.is_empty() {
                break;
            }
            got.extend(b.into_iter().map(|w| w.ctx));
        }
        got.sort_unstable();
        assert_eq!(got, (0..160).collect::<Vec<u32>>());
    }

    #[test]
    fn closing_every_shard_rejects_like_a_closed_queue() {
        let q: ShardedBatchQueue<u32> = ShardedBatchQueue::new(2);
        assert!(q.push(item(1.0)));
        assert!(q.push(item(2.0)));
        q.close_shard(0);
        // last open shard: backlog stays put but is still drainable
        q.close_shard(1);
        assert_eq!(q.len(), 2);
        assert!(!q.push(item(3.0)));
        assert_eq!(q.metrics().rejected(), 1);
        let b = q.try_pop_batch(0, 8);
        assert_eq!(b.len(), 2);
    }

    #[test]
    fn poisoned_queue_recovers_and_counts() {
        let q = BatchQueue::new();
        assert!(q.push(item(1.0)));
        q.poison();
        // the queue keeps working after a consumer panic poisoned it
        assert!(q.push(item(2.0)));
        assert_eq!(q.pop_batch(8).unwrap().len(), 2);
        assert!(q.metrics().poisoned() >= 1);
    }

    #[test]
    fn poisoned_shard_recovers_and_loses_nothing() {
        let q: ShardedBatchQueue<u32> = ShardedBatchQueue::new(4);
        for i in 0..40 {
            assert!(q.push(item(i as f32)));
        }
        for s in 0..4 {
            q.poison_shard(s);
        }
        for i in 40..80 {
            assert!(q.push(item(i as f32)));
        }
        let mut got = Vec::new();
        loop {
            let b = q.try_pop_batch(0, 16);
            if b.is_empty() {
                break;
            }
            got.extend(b.into_iter().map(|w| w.ctx));
        }
        got.sort_unstable();
        assert_eq!(got, (0..80).collect::<Vec<u32>>());
        assert!(q.metrics().poisoned() >= 4);
    }

    #[test]
    fn sharded_routing_balances_shards() {
        let q: ShardedBatchQueue<u32> = ShardedBatchQueue::new(4);
        for i in 0..400 {
            q.push(item(i as f32));
        }
        // power-of-two-choices keeps the max/min spread tight
        let lens: Vec<usize> = (0..4).map(|s| q.shard_len(s)).collect();
        let (min, max) =
            (lens.iter().min().unwrap(), lens.iter().max().unwrap());
        assert!(max - min <= 20, "unbalanced shards: {lens:?}");
        assert_eq!(lens.iter().sum::<usize>(), 400);
    }
}
