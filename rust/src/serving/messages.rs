//! Request/response types + wire protocol of the serving data path.
//!
//! The paper's clients send intermediate tensors over network sockets;
//! we use a length-prefixed little-endian binary framing over TCP (and
//! the same structs in-process via channels).

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

/// A single inference request carrying the activation tensor produced by
/// the client's mobile fragment.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub client_id: u32,
    /// Model index (into `Config::models`).
    pub model: u16,
    /// Partition point: the payload is the activation after layer `p`.
    pub p: u16,
    /// Request sequence number (per client).
    pub seq: u32,
    /// Virtual timestamp (ms) when the frame was captured on-device.
    pub t_capture_ms: f64,
    /// Simulated mobile + uplink latency already spent (ms).
    pub upstream_ms: f64,
    /// Server-side time budget for this request (ms).
    pub budget_ms: f64,
    /// Activation row `[dims[p]]`.
    pub payload: Vec<f32>,
}

/// The server's answer.
#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    pub client_id: u32,
    pub seq: u32,
    /// Server-side latency (queueing + execution, ms, modeled GPU time).
    pub server_ms: f64,
    /// End-to-end latency (upstream + server, ms).
    pub e2e_ms: f64,
    /// Whether the request was dropped by the load balancer (SLO miss).
    pub dropped: bool,
    /// Output logits `[dim_out]` (empty when dropped).
    pub output: Vec<f32>,
}

impl Response {
    /// A drop/rejection notice: `dropped` set, no output payload.  Used
    /// by the balancer (unknown client, SLO-hopeless request) and the
    /// executor error path.
    pub fn drop_notice(
        client_id: u32,
        seq: u32,
        server_ms: f64,
        e2e_ms: f64,
    ) -> Response {
        Response {
            client_id,
            seq,
            server_ms,
            e2e_ms,
            dropped: true,
            output: Vec::new(),
        }
    }
}

const REQ_MAGIC: u32 = 0x47524654; // "GRFT"
const RESP_MAGIC: u32 = 0x47525350; // "GRSP"

fn put_u32(v: &mut Vec<u8>, x: u32) {
    v.extend_from_slice(&x.to_le_bytes());
}
fn put_f64(v: &mut Vec<u8>, x: f64) {
    v.extend_from_slice(&x.to_le_bytes());
}

struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn u32(&mut self) -> Result<u32> {
        if self.i + 4 > self.b.len() {
            bail!("truncated frame");
        }
        let x = u32::from_le_bytes(self.b[self.i..self.i + 4].try_into()?);
        self.i += 4;
        Ok(x)
    }
    fn f64(&mut self) -> Result<f64> {
        if self.i + 8 > self.b.len() {
            bail!("truncated frame");
        }
        let x = f64::from_le_bytes(self.b[self.i..self.i + 8].try_into()?);
        self.i += 8;
        Ok(x)
    }
    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        if self.i + 4 * n > self.b.len() {
            bail!("truncated payload");
        }
        let out = self.b[self.i..self.i + 4 * n]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        self.i += 4 * n;
        Ok(out)
    }
}

impl Request {
    pub fn encode(&self) -> Vec<u8> {
        let mut v = Vec::with_capacity(44 + 4 * self.payload.len());
        put_u32(&mut v, REQ_MAGIC);
        put_u32(&mut v, self.client_id);
        put_u32(&mut v, self.model as u32);
        put_u32(&mut v, self.p as u32);
        put_u32(&mut v, self.seq);
        put_f64(&mut v, self.t_capture_ms);
        put_f64(&mut v, self.upstream_ms);
        put_f64(&mut v, self.budget_ms);
        put_u32(&mut v, self.payload.len() as u32);
        for x in &self.payload {
            v.extend_from_slice(&x.to_le_bytes());
        }
        v
    }

    pub fn decode(b: &[u8]) -> Result<Request> {
        let mut c = Cursor { b, i: 0 };
        if c.u32()? != REQ_MAGIC {
            bail!("bad request magic");
        }
        let client_id = c.u32()?;
        let model = c.u32()? as u16;
        let p = c.u32()? as u16;
        let seq = c.u32()?;
        let t_capture_ms = c.f64()?;
        let upstream_ms = c.f64()?;
        let budget_ms = c.f64()?;
        let n = c.u32()? as usize;
        let payload = c.f32s(n)?;
        Ok(Request {
            client_id,
            model,
            p,
            seq,
            t_capture_ms,
            upstream_ms,
            budget_ms,
            payload,
        })
    }
}

impl Response {
    pub fn encode(&self) -> Vec<u8> {
        let mut v = Vec::with_capacity(32 + 4 * self.output.len());
        put_u32(&mut v, RESP_MAGIC);
        put_u32(&mut v, self.client_id);
        put_u32(&mut v, self.seq);
        put_f64(&mut v, self.server_ms);
        put_f64(&mut v, self.e2e_ms);
        put_u32(&mut v, self.dropped as u32);
        put_u32(&mut v, self.output.len() as u32);
        for x in &self.output {
            v.extend_from_slice(&x.to_le_bytes());
        }
        v
    }

    pub fn decode(b: &[u8]) -> Result<Response> {
        let mut c = Cursor { b, i: 0 };
        if c.u32()? != RESP_MAGIC {
            bail!("bad response magic");
        }
        let client_id = c.u32()?;
        let seq = c.u32()?;
        let server_ms = c.f64()?;
        let e2e_ms = c.f64()?;
        let dropped = c.u32()? != 0;
        let n = c.u32()? as usize;
        let output = c.f32s(n)?;
        Ok(Response { client_id, seq, server_ms, e2e_ms, dropped, output })
    }
}

/// Write one length-prefixed frame.
pub fn write_frame<W: Write>(w: &mut W, frame: &[u8]) -> Result<()> {
    w.write_all(&(frame.len() as u32).to_le_bytes())?;
    w.write_all(frame)?;
    Ok(())
}

/// Read one length-prefixed frame (cap 64 MiB).
pub fn read_frame<R: Read>(r: &mut R) -> Result<Vec<u8>> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len).context("reading frame length")?;
    let len = u32::from_le_bytes(len) as usize;
    if len > 64 << 20 {
        bail!("frame too large: {len}");
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf).context("reading frame body")?;
    Ok(buf)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req() -> Request {
        Request {
            client_id: 7,
            model: 2,
            p: 3,
            seq: 41,
            t_capture_ms: 123.5,
            upstream_ms: 17.25,
            budget_ms: 88.0,
            payload: vec![1.5, -2.0, 3.25],
        }
    }

    #[test]
    fn request_roundtrip() {
        let r = req();
        assert_eq!(Request::decode(&r.encode()).unwrap(), r);
    }

    #[test]
    fn response_roundtrip() {
        let r = Response {
            client_id: 7,
            seq: 41,
            server_ms: 12.0,
            e2e_ms: 99.0,
            dropped: false,
            output: vec![0.25; 64],
        };
        assert_eq!(Response::decode(&r.encode()).unwrap(), r);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Request::decode(&[1, 2, 3]).is_err());
        let mut enc = req().encode();
        enc[0] ^= 0xFF;
        assert!(Request::decode(&enc).is_err());
        enc = req().encode();
        enc.truncate(enc.len() - 2);
        assert!(Request::decode(&enc).is_err());
    }

    #[test]
    fn drop_notice_roundtrips() {
        let d = Response::drop_notice(3, 9, 1.5, 20.5);
        assert!(d.dropped && d.output.is_empty());
        assert_eq!(Response::decode(&d.encode()).unwrap(), d);
    }

    #[test]
    fn frame_roundtrip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"world!").unwrap();
        let mut r = &buf[..];
        assert_eq!(read_frame(&mut r).unwrap(), b"hello");
        assert_eq!(read_frame(&mut r).unwrap(), b"world!");
        assert!(read_frame(&mut r).is_err());
    }
}
