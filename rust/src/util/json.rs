//! Minimal JSON parser + writer.
//!
//! The offline crate set has no `serde_json`; this module parses the
//! config (`configs/models.json`) and the AOT manifest
//! (`artifacts/manifest.json`), and writes experiment result files.
//! Supports the full JSON grammar except `\u` surrogate pairs beyond the
//! BMP (not needed by our inputs, which are ASCII).

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => {
                m.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
            }
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f.fract() != 0.0 || f < 0.0 {
            bail!("not a non-negative integer: {f}");
        }
        Ok(f as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(Json::as_f64).collect()
    }

    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(Json::as_usize).collect()
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!(
                "expected {:?} at byte {} (found {:?})",
                c as char,
                self.i,
                self.peek().map(|x| x as char)
            )
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other, self.i),
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => bail!("expected , or ] at byte {}", self.i),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => bail!("expected , or }} at byte {}", self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| anyhow!("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("bad codepoint"))?,
                            );
                        }
                        _ => bail!("bad escape \\{}", c as char),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len()
                        && (self.b[self.i] & 0xC0) == 0x80
                    {
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(txt.parse::<f64>()?))
    }
}

// -- writer ----------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str()
                .unwrap(),
            "x"
        );
        assert_eq!(v.get("c").unwrap(), &Json::Null);
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "nul", "\"abc", "{\"a\" 1}", "1 2"] {
            assert!(Json::parse(bad).is_err(), "{bad:?} parsed");
        }
    }

    #[test]
    fn roundtrip_display_parse() {
        let src = r#"{"arr":[1,2.5,"s"],"num":-3,"obj":{"t":true}}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn parses_the_embedded_config() {
        let v = Json::parse(crate::config::EMBEDDED_CONFIG).unwrap();
        assert_eq!(v.get("models").unwrap().as_arr().unwrap().len(), 5);
        assert_eq!(v.get("input_kb").unwrap().as_f64().unwrap(), 588.0);
    }

    #[test]
    fn typed_accessor_errors() {
        let v = Json::parse(r#"{"a": 1.5}"#).unwrap();
        assert!(v.get("a").unwrap().as_usize().is_err());
        assert!(v.get("a").unwrap().as_str().is_err());
        assert!(v.get("missing").is_err());
        assert!(Json::Num(1.0).get("x").is_err());
    }

    #[test]
    fn unicode_strings() {
        let v = Json::parse(r#""héllo → wörld""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo → wörld");
    }
}
