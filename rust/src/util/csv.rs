//! Tiny CSV writer for experiment outputs (`results/<id>.csv`).

use std::fmt::Write as _;
use std::path::Path;

use anyhow::Result;

/// A CSV table with a header row.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Self {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |s: &str| {
            if s.contains([',', '"', '\n']) {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let _ = writeln!(
            out,
            "{}",
            self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for r in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }

    /// Write to `path`, creating parent directories.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_csv())?;
        Ok(())
    }

    /// Pretty-print as an aligned text table (for stdout).
    pub fn pretty(&self) -> String {
        let mut widths: Vec<usize> =
            self.header.iter().map(String::len).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = fmt_row(&self.header);
        out.push('\n');
        out.push_str(&"-".repeat(out.len().saturating_sub(1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }
}

/// Format an f64 with fixed precision, trimming NaN noise.
pub fn f(v: f64, prec: usize) -> String {
    if v.is_nan() {
        "nan".to_string()
    } else {
        format!("{v:.prec$}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_escaping() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["x,y", "q\"z"]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"z\""));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn pretty_aligns() {
        let mut t = Table::new(vec!["model", "share"]);
        t.row(vec!["inc", "123"]);
        t.row(vec!["vit", "7"]);
        let p = t.pretty();
        assert!(p.lines().count() == 4);
    }

    #[test]
    fn save_creates_dirs() {
        let dir = std::env::temp_dir().join(format!(
            "graft-csv-test-{}",
            std::process::id()
        ));
        let path = dir.join("nested/out.csv");
        let mut t = Table::new(vec!["a"]);
        t.row(vec!["1"]);
        t.save(&path).unwrap();
        assert!(path.exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn f_formats() {
        assert_eq!(f(1.23456, 2), "1.23");
        assert_eq!(f(f64::NAN, 2), "nan");
    }
}
