//! Scoped thread-pool parallel map (the offline crate set has no rayon).
//!
//! Used for parallel per-group re-alignment (the paper's "process pool",
//! §5.9 / Fig 19b).  Work-stealing is unnecessary at our granularity; a
//! shared atomic work index suffices.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Apply `f` to every item on `threads` worker threads, preserving order.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let threads = threads.max(1).min(items.len().max(1));
    if threads <= 1 || items.len() <= 1 {
        return items.iter().map(&f).collect();
    }
    let next = AtomicUsize::new(0);
    let out: Vec<Mutex<Option<R>>> =
        (0..items.len()).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(&items[i]);
                *out[i].lock().unwrap() = Some(r);
            });
        }
    });
    out.into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker filled every slot"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn preserves_order() {
        let items: Vec<u64> = (0..100).collect();
        let out = parallel_map(&items, 4, |&x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn runs_every_item_exactly_once() {
        let counter = AtomicU32::new(0);
        let items: Vec<u32> = (0..57).collect();
        let _ = parallel_map(&items, 8, |_| {
            counter.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(counter.load(Ordering::SeqCst), 57);
    }

    #[test]
    fn single_thread_and_empty() {
        assert_eq!(parallel_map(&[1, 2, 3], 1, |&x| x + 1), vec![2, 3, 4]);
        let empty: Vec<u32> = vec![];
        assert!(parallel_map(&empty, 4, |&x| x).is_empty());
    }

    #[test]
    fn actually_uses_multiple_threads() {
        use std::collections::HashSet;
        use std::sync::Mutex;
        let ids = Mutex::new(HashSet::new());
        let items: Vec<u32> = (0..64).collect();
        parallel_map(&items, 4, |_| {
            std::thread::sleep(std::time::Duration::from_millis(1));
            ids.lock().unwrap().insert(std::thread::current().id());
        });
        assert!(ids.lock().unwrap().len() > 1);
    }
}
