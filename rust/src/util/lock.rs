//! Poison-recovering lock acquisition.
//!
//! A panicking thread poisons every `Mutex`/`RwLock` it holds; the
//! default `.lock().unwrap()` then cascades that single panic through
//! every other thread touching the lock — one dead worker wedges the
//! whole serving core.  The protected state in this crate is always
//! valid at the poison point (queues push/pop whole items under the
//! lock; slot states are single enum writes), so recovery is safe:
//! take the guard out of the `PoisonError` and carry on.
//!
//! Every recovery is counted in a process-global counter (and
//! optionally a caller-supplied counter, e.g.
//! `ServerCounters::poisoned`) so chaos tests and benches can assert
//! how far an injected panic actually spread.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};
use std::time::Duration;

/// Process-global count of poisoned-lock recoveries.
static POISONED: AtomicU64 = AtomicU64::new(0);

/// Total poisoned-lock recoveries since process start.
pub fn poisoned_total() -> u64 {
    POISONED.load(Ordering::Relaxed)
}

#[cold]
fn note_poison(extra: Option<&AtomicU64>) {
    POISONED.fetch_add(1, Ordering::Relaxed);
    if let Some(c) = extra {
        c.fetch_add(1, Ordering::Relaxed);
    }
}

/// `mutex.lock()` that recovers a poisoned lock instead of panicking.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    lock_counted(m, None)
}

/// [`lock_recover`] that additionally bumps `counter` on recovery.
pub fn lock_counted<'a, T>(
    m: &'a Mutex<T>,
    counter: Option<&AtomicU64>,
) -> MutexGuard<'a, T> {
    m.lock().unwrap_or_else(|e| {
        note_poison(counter);
        e.into_inner()
    })
}

/// `mutex.try_lock()` that recovers a poisoned lock: `None` only means
/// *contended*, never *poisoned*.
pub fn try_lock_counted<'a, T>(
    m: &'a Mutex<T>,
    counter: Option<&AtomicU64>,
) -> Option<MutexGuard<'a, T>> {
    match m.try_lock() {
        Ok(g) => Some(g),
        Err(std::sync::TryLockError::Poisoned(e)) => {
            note_poison(counter);
            Some(e.into_inner())
        }
        Err(std::sync::TryLockError::WouldBlock) => None,
    }
}

/// `cv.wait(guard)` that recovers poisoning instead of panicking.
pub fn wait_recover<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(|e| {
        note_poison(None);
        e.into_inner()
    })
}

/// `cv.wait_timeout(guard, dur)` that recovers poisoning; the timed-out
/// flag is preserved.
pub fn wait_timeout_recover<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    dur: Duration,
) -> (MutexGuard<'a, T>, bool) {
    match cv.wait_timeout(guard, dur) {
        Ok((g, t)) => (g, t.timed_out()),
        Err(e) => {
            note_poison(None);
            let (g, t) = e.into_inner();
            (g, t.timed_out())
        }
    }
}

/// `rw.read()` that recovers a poisoned lock instead of panicking.
pub fn read_recover<T>(rw: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    rw.read().unwrap_or_else(|e| {
        note_poison(None);
        e.into_inner()
    })
}

/// `rw.write()` that recovers a poisoned lock instead of panicking.
pub fn write_recover<T>(rw: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    rw.write().unwrap_or_else(|e| {
        note_poison(None);
        e.into_inner()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn poison<T: Send + 'static>(m: &Arc<Mutex<T>>) {
        let m2 = m.clone();
        std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison injector");
        })
        .join()
        .unwrap_err();
    }

    #[test]
    fn recovers_poisoned_mutex_and_counts() {
        let m = Arc::new(Mutex::new(7u32));
        poison(&m);
        assert!(m.is_poisoned());
        let before = poisoned_total();
        let extra = AtomicU64::new(0);
        {
            let g = lock_counted(&m, Some(&extra));
            assert_eq!(*g, 7);
        }
        assert!(poisoned_total() > before);
        assert_eq!(extra.load(Ordering::Relaxed), 1);
        // Data stays reachable on later plain recoveries too.
        *lock_recover(&m) = 9;
        assert_eq!(*lock_recover(&m), 9);
    }

    #[test]
    fn wait_timeout_recovers_and_reports_timeout() {
        let m = Arc::new(Mutex::new(0u32));
        let cv = Condvar::new();
        poison(&m);
        let g = lock_recover(&m);
        let (g, timed_out) =
            wait_timeout_recover(&cv, g, Duration::from_millis(5));
        assert!(timed_out);
        drop(g);
    }

    #[test]
    fn rwlock_recovery() {
        let rw = Arc::new(RwLock::new(3u32));
        let rw2 = rw.clone();
        std::thread::spawn(move || {
            let _g = rw2.write().unwrap();
            panic!("poison injector");
        })
        .join()
        .unwrap_err();
        assert_eq!(*read_recover(&rw), 3);
        *write_recover(&rw) = 4;
        assert_eq!(*read_recover(&rw), 4);
    }
}
