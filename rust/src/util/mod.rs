//! In-tree substrates replacing unavailable third-party crates (the
//! offline registry carries only the `xla` closure): JSON, PRNG, thread
//! pool, micro-bench harness and CSV helpers.

pub mod bench;
pub mod csv;
pub mod json;
pub mod lock;
pub mod pool;
pub mod rng;

pub use json::Json;
pub use pool::parallel_map;
pub use rng::Rng;
