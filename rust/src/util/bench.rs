//! Micro-benchmark harness (the offline crate set has no criterion).
//!
//! Warmup + timed iterations with mean / p50 / p95 reporting; used by the
//! `rust/benches/*.rs` targets (declared `harness = false`).

use std::hint::black_box;
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>8} iters  mean {:>12?}  p50 {:>12?}  p95 {:>12?}",
            self.name, self.iters, self.mean, self.p50, self.p95
        )
    }
}

/// Time one call, returning `(elapsed ms, result)` — the shared
/// wall-clock helper of the bench CLIs and the replan scenario.
pub fn time_ms<R>(f: impl FnOnce() -> R) -> (f64, R) {
    let t = Instant::now();
    let r = f();
    (t.elapsed().as_secs_f64() * 1e3, r)
}

/// Benchmark a closure: warm up for `warmup` iterations, then measure
/// until `target_time` elapses (at least `min_iters`).
pub fn bench<F, R>(name: &str, mut f: F) -> BenchResult
where
    F: FnMut() -> R,
{
    bench_with(name, 3, 30, Duration::from_millis(700), &mut f)
}

pub fn bench_with<F, R>(
    name: &str,
    warmup: usize,
    min_iters: usize,
    target_time: Duration,
    f: &mut F,
) -> BenchResult
where
    F: FnMut() -> R,
{
    for _ in 0..warmup {
        black_box(f());
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while samples.len() < min_iters || start.elapsed() < target_time {
        let t = Instant::now();
        black_box(f());
        samples.push(t.elapsed());
        if samples.len() >= 1_000_000 {
            break;
        }
    }
    samples.sort();
    let mean = samples.iter().sum::<Duration>() / samples.len() as u32;
    let p = |q: f64| samples[((samples.len() - 1) as f64 * q) as usize];
    BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean,
        p50: p(0.50),
        p95: p(0.95),
    }
}

/// Run and print a group of benches (helper for bench binaries).
pub fn run_group(title: &str, benches: Vec<BenchResult>) {
    println!("== {title} ==");
    for b in &benches {
        println!("{}", b.report());
    }
    println!();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench_with(
            "noop",
            1,
            10,
            Duration::from_millis(5),
            &mut || 1 + 1,
        );
        assert!(r.iters >= 10);
        assert!(r.p50 <= r.p95);
    }

    #[test]
    fn time_ms_returns_result_and_nonnegative_time() {
        let (ms, v) = time_ms(|| 6 * 7);
        assert_eq!(v, 42);
        assert!(ms >= 0.0);
    }

    #[test]
    fn bench_orders_percentiles() {
        let mut n = 0u64;
        let r = bench_with(
            "spin",
            0,
            20,
            Duration::from_millis(5),
            &mut || {
                n = n.wrapping_add(1);
                std::hint::black_box(n)
            },
        );
        assert!(r.mean.as_nanos() > 0);
    }
}
