//! Deterministic PRNG (xoshiro256++ seeded via SplitMix64).
//!
//! The offline crate set has no `rand`; this is a small, well-tested
//! replacement used everywhere randomness is needed (trace generation,
//! grouping seeds, workload arrivals, property tests).  All experiment
//! randomness is seeded, so every figure regenerates identically.

/// xoshiro256++ — public-domain algorithm by Blackman & Vigna.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the state.
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        debug_assert!(hi > lo);
        lo + (hi - lo) * self.f64()
    }

    /// Uniform usize in [0, n) (n > 0); unbiased via rejection.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            v.swap(i, self.below(i + 1));
        }
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Exponential with the given mean.
    pub fn exponential(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.f64()).ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval_and_uniform_ish() {
        let mut r = Rng::seed_from_u64(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::seed_from_u64(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>(), "shuffle did nothing");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(11);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::seed_from_u64(13);
        let n = 100_000;
        let mean =
            (0..n).map(|_| r.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 4.0).abs() < 0.1, "mean {mean}");
    }
}
