//! Model weight blobs (`artifacts/weights_<model>.bin`).
//!
//! Layout (written by `StandInModel.weights_blob` on the Python side):
//! for each layer i = 1..L, `w_i` row-major `[dims[i-1], dims[i]]` then
//! `b_i` `[dims[i]]`, all little-endian f32.  Offsets derive from `dims`
//! alone, so the file carries no header.

use std::path::Path;

use anyhow::{bail, Context, Result};

/// All layers of one model, parsed into (w, b) f32 vectors.
#[derive(Debug, Clone)]
pub struct ModelWeights {
    pub dims: Vec<usize>,
    /// layers[i] = (w flattened row-major, b), for layer i+1.
    pub layers: Vec<(Vec<f32>, Vec<f32>)>,
}

impl ModelWeights {
    pub fn load(path: &Path, dims: &[usize]) -> Result<ModelWeights> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&bytes, dims)
    }

    pub fn parse(bytes: &[u8], dims: &[usize]) -> Result<ModelWeights> {
        if bytes.len() % 4 != 0 {
            bail!("weight blob not a multiple of 4 bytes");
        }
        let floats: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let expected: usize = (0..dims.len() - 1)
            .map(|i| dims[i] * dims[i + 1] + dims[i + 1])
            .sum();
        if floats.len() != expected {
            bail!(
                "weight blob has {} floats, dims imply {}",
                floats.len(),
                expected
            );
        }
        let mut layers = Vec::with_capacity(dims.len() - 1);
        let mut off = 0;
        for i in 0..dims.len() - 1 {
            let wlen = dims[i] * dims[i + 1];
            let w = floats[off..off + wlen].to_vec();
            off += wlen;
            let b = floats[off..off + dims[i + 1]].to_vec();
            off += dims[i + 1];
            layers.push((w, b));
        }
        Ok(ModelWeights { dims: dims.to_vec(), layers })
    }

    /// The (w, b) of 1-indexed layer `i`.
    pub fn layer(&self, i: usize) -> Result<&(Vec<f32>, Vec<f32>)> {
        if i == 0 || i > self.layers.len() {
            bail!("layer {i} out of range 1..={}", self.layers.len());
        }
        Ok(&self.layers[i - 1])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blob(dims: &[usize]) -> Vec<u8> {
        let mut out = Vec::new();
        let mut x = 0.0f32;
        for i in 0..dims.len() - 1 {
            for _ in 0..dims[i] * dims[i + 1] + dims[i + 1] {
                out.extend_from_slice(&x.to_le_bytes());
                x += 1.0;
            }
        }
        out
    }

    #[test]
    fn parse_layout() {
        let dims = [2usize, 3, 1];
        let w = ModelWeights::parse(&blob(&dims), &dims).unwrap();
        assert_eq!(w.layers.len(), 2);
        assert_eq!(w.layer(1).unwrap().0, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(w.layer(1).unwrap().1, vec![6.0, 7.0, 8.0]);
        assert_eq!(w.layer(2).unwrap().0, vec![9.0, 10.0, 11.0]);
        assert_eq!(w.layer(2).unwrap().1, vec![12.0]);
        assert!(w.layer(0).is_err());
        assert!(w.layer(3).is_err());
    }

    #[test]
    fn rejects_wrong_sizes() {
        let dims = [2usize, 3, 1];
        let mut b = blob(&dims);
        b.extend_from_slice(&[0, 0, 0, 0]);
        assert!(ModelWeights::parse(&b, &dims).is_err());
        assert!(ModelWeights::parse(&[1, 2, 3], &dims).is_err());
    }
}
