//! Live reconfiguration: the plan-transition engine (paper §4.1/Fig 6:
//! monitor → re-plan → redeploy, without restarting the data path).
//!
//! Two pieces live here:
//!
//! * [`diff_plans`] — diff an old and a new [`ExecutionPlan`] into a
//!   minimal-migration [`TransitionPlan`].  Re-aligned sets are matched
//!   by their perturbation-stable identity (model + client-id set, the
//!   same notion as [`crate::coordinator::reuse::warm_signature`]);
//!   matched sets whose configuration is unchanged keep their instances
//!   (and, through [`crate::coordinator::placement::place_delta`],
//!   their GPU), changed ones are staged prepare → drain →
//!   atomic-switch.
//! * [`LiveServer`] — a reconfigurable serving front over
//!   [`Server`].  [`LiveServer::reconfigure`] applies a new plan under
//!   live traffic without dropping or double-executing any in-flight
//!   request: the new plan's stages are *prepared* (spawned idle), the
//!   routing is *switched* atomically (submissions hold a read lock
//!   across their queue push, so no submit can race the swap into a
//!   closed queue), and the old core *drains* gracefully
//!   ([`Server::drain`]: alignment stages first, then shared stages,
//!   so an in-flight alignment batch always finds its downstream queue
//!   open).  Old shards finish under their SLO while the new shards
//!   are already serving.
//!
//! The replan controller
//! ([`crate::coordinator::controller::ReplanController`]) drives this
//! engine from observed arrival rates; `graft bench-transition`
//! measures it (swap latency, migrations vs the full-repack oracle,
//! zero dropped requests).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, RwLock};
use std::time::Instant;

use crate::coordinator::plan::{ExecutionPlan, RealignedSet, StagePlan};
use crate::profiler::CostModel;
use crate::serving::server::RequestSink;
use crate::serving::{
    FragmentExecutor, Request, Response, Server, ServerOptions,
};
use crate::util::lock::{lock_recover, read_recover, write_recover};

/// How one re-aligned set moves from the old plan to the new one.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SetChange {
    /// Same identity, identical configuration: instances keep serving
    /// (and keep their GPU under delta placement).
    Keep { old: usize, new: usize },
    /// Same identity, changed configuration (point, members, allocs):
    /// prepare the new stages, drain the old ones, switch.
    Update { old: usize, new: usize },
    /// New set: prepare + open.
    Add { new: usize },
    /// Departed set: drain + retire.
    Remove { old: usize },
}

/// The minimal-migration diff between two execution plans.
#[derive(Debug, Clone, Default)]
pub struct TransitionPlan {
    pub changes: Vec<SetChange>,
    pub kept_sets: usize,
    pub updated_sets: usize,
    pub added_sets: usize,
    pub removed_sets: usize,
    /// Instances of kept sets — they survive the swap untouched.
    pub kept_instances: usize,
    /// Instances that must start (or restart) under the new plan.
    pub restarted_instances: usize,
    /// Old instances that must drain and retire (updated + removed).
    pub retired_instances: usize,
}

fn set_identity(set: &RealignedSet) -> u64 {
    use std::collections::hash_map::DefaultHasher;
    use std::hash::{Hash, Hasher};
    let mut clients: Vec<u32> = set
        .members
        .iter()
        .flat_map(|m| m.spec.clients.iter().map(|c| c.0))
        .collect();
    clients.sort_unstable();
    let mut h = DefaultHasher::new();
    set.model.hash(&mut h);
    clients.hash(&mut h);
    h.finish()
}

/// Configuration equality modulo GPU stamps (the old plan is stamped,
/// the new one may not be yet — placement must not affect whether a
/// set counts as changed).
fn stage_config_eq(a: &StagePlan, b: &StagePlan) -> bool {
    a.frag == b.frag
        && a.alloc == b.alloc
        && a.budget_ms == b.budget_ms
        && a.demand_rps == b.demand_rps
}

fn set_config_eq(a: &RealignedSet, b: &RealignedSet) -> bool {
    a.model == b.model
        && a.point == b.point
        && a.members.len() == b.members.len()
        && stage_config_eq(&a.shared, &b.shared)
        && a.members.iter().zip(&b.members).all(|(ma, mb)| {
            ma.spec == mb.spec
                && match (&ma.align, &mb.align) {
                    (None, None) => true,
                    (Some(x), Some(y)) => stage_config_eq(x, y),
                    _ => false,
                }
        })
}

fn set_instances(set: &RealignedSet) -> usize {
    set.shared.alloc.instances as usize
        + set
            .members
            .iter()
            .filter_map(|m| m.align.as_ref())
            .map(|a| a.alloc.instances as usize)
            .sum::<usize>()
}

/// Diff `old` → `new` into a minimal-migration transition plan.  Sets
/// are matched by perturbation-stable identity (model + client ids);
/// matched sets with identical configuration are kept, the rest are
/// staged as update/add/remove.
pub fn diff_plans(old: &ExecutionPlan, new: &ExecutionPlan) -> TransitionPlan {
    use std::collections::HashMap;
    let mut by_id: HashMap<u64, Vec<usize>> = HashMap::new();
    for (i, set) in old.sets.iter().enumerate() {
        by_id.entry(set_identity(set)).or_default().push(i);
    }
    let mut t = TransitionPlan::default();
    for (ni, nset) in new.sets.iter().enumerate() {
        let matched = by_id
            .get_mut(&set_identity(nset))
            .and_then(|bucket| bucket.pop());
        match matched {
            Some(oi) if set_config_eq(&old.sets[oi], nset) => {
                t.kept_sets += 1;
                t.kept_instances += set_instances(nset);
                t.changes.push(SetChange::Keep { old: oi, new: ni });
            }
            Some(oi) => {
                t.updated_sets += 1;
                t.restarted_instances += set_instances(nset);
                t.retired_instances += set_instances(&old.sets[oi]);
                t.changes.push(SetChange::Update { old: oi, new: ni });
            }
            None => {
                t.added_sets += 1;
                t.restarted_instances += set_instances(nset);
                t.changes.push(SetChange::Add { new: ni });
            }
        }
    }
    for bucket in by_id.values() {
        for &oi in bucket {
            t.removed_sets += 1;
            t.retired_instances += set_instances(&old.sets[oi]);
            t.changes.push(SetChange::Remove { old: oi });
        }
    }
    t
}

/// What one [`LiveServer::reconfigure`] did, and how long each phase
/// took.
#[derive(Debug, Clone)]
pub struct TransitionReport {
    pub transition: TransitionPlan,
    /// Building the new serving core (queues + executors, idle).
    pub prepare_ms: f64,
    /// The atomic routing switch (blocks only on in-progress submits).
    pub switch_ms: f64,
    /// Graceful drain of the old core (in-flight work finishing).
    pub drain_ms: f64,
    pub total_ms: f64,
    /// Items the *old* core refused after the switch.  Must be 0: the
    /// submit/switch locking makes a post-switch push into the old core
    /// impossible, and the ordered drain never closes a queue that can
    /// still receive forwards.
    pub old_rejected: u64,
    /// Requests the old core dropped over its lifetime (SLO drops
    /// under `drop_on_slo`; 0 in the zero-drop bench configuration).
    pub old_dropped: u64,
}

/// Aggregated counters across the current core and every retired one.
#[derive(Debug, Clone, Copy, Default)]
pub struct LiveTotals {
    pub served: u64,
    pub dropped: u64,
    pub rejected: u64,
    pub batches: u64,
}

/// A serving front that can swap execution plans under live traffic.
pub struct LiveServer {
    executor: Arc<dyn FragmentExecutor>,
    cm: CostModel,
    opts: ServerOptions,
    current: RwLock<Arc<Server>>,
    plan: Mutex<ExecutionPlan>,
    /// Serializes reconfigurations (ticks can overlap a slow drain).
    swap_lock: Mutex<()>,
    swaps: AtomicU64,
    retired_served: AtomicU64,
    retired_dropped: AtomicU64,
    retired_rejected: AtomicU64,
    retired_batches: AtomicU64,
}

impl LiveServer {
    /// Start serving `plan` (the executor/options apply to every
    /// subsequent plan as well).
    pub fn start(
        executor: Arc<dyn FragmentExecutor>,
        cm: &CostModel,
        plan: &ExecutionPlan,
        opts: ServerOptions,
    ) -> LiveServer {
        let server =
            Arc::new(Server::start(executor.clone(), cm, plan, opts));
        LiveServer {
            executor,
            cm: cm.clone(),
            opts,
            current: RwLock::new(server),
            plan: Mutex::new(plan.clone()),
            swap_lock: Mutex::new(()),
            swaps: AtomicU64::new(0),
            retired_served: AtomicU64::new(0),
            retired_dropped: AtomicU64::new(0),
            retired_rejected: AtomicU64::new(0),
            retired_batches: AtomicU64::new(0),
        }
    }

    /// The current serving core (snapshot — may be retired by a later
    /// reconfigure, but keeps serving its in-flight work either way).
    pub fn server(&self) -> Arc<Server> {
        read_recover(&self.current).clone()
    }

    /// The currently deployed plan.
    pub fn plan(&self) -> ExecutionPlan {
        lock_recover(&self.plan).clone()
    }

    /// Completed reconfigurations.
    pub fn swap_count(&self) -> u64 {
        self.swaps.load(Ordering::SeqCst)
    }

    /// Counters summed over the live core and every retired core.
    /// Rejections are the per-queue counts only — the balancer-level
    /// `ServerCounters::rejected` mirrors the same events, so summing
    /// both would double-count every refusal.
    pub fn totals(&self) -> LiveTotals {
        let cur = self.server();
        LiveTotals {
            served: self.retired_served.load(Ordering::Relaxed)
                + cur.counters.served.load(Ordering::Relaxed),
            dropped: self.retired_dropped.load(Ordering::Relaxed)
                + cur.counters.dropped.load(Ordering::Relaxed),
            rejected: self.retired_rejected.load(Ordering::Relaxed)
                + cur.queue_rejections(),
            batches: self.retired_batches.load(Ordering::Relaxed)
                + cur.counters.batches.load(Ordering::Relaxed),
        }
    }

    /// Emit the live front's metrics under the canonical registry
    /// names: the current core's metrics with the retired cores' totals
    /// folded in, so the serving counters stay *monotonic across hot
    /// swaps* (a swap installs a fresh core whose counters start at 0),
    /// plus the swap counter itself.
    pub fn collect_metrics(&self, out: &mut Vec<crate::obs::Metric>) {
        use crate::obs::{Metric, MetricValue};
        let cur = self.server();
        let mut inner = Vec::new();
        cur.collect_metrics(&mut inner);
        for m in &mut inner {
            let add = match m.name.as_str() {
                "graft_serving_served_total" => {
                    self.retired_served.load(Ordering::Relaxed)
                }
                "graft_serving_dropped_total" => {
                    self.retired_dropped.load(Ordering::Relaxed)
                }
                "graft_serving_batches_total" => {
                    self.retired_batches.load(Ordering::Relaxed)
                }
                _ => 0,
            };
            if add > 0 {
                if let MetricValue::Counter(v) = &mut m.value {
                    *v += add;
                }
            }
        }
        out.append(&mut inner);
        // rejected is per-stage labeled; retired cores contribute one
        // extra series so `counter_sum` matches `totals().rejected`
        let rr = self.retired_rejected.load(Ordering::Relaxed);
        if rr > 0 {
            out.push(
                Metric::counter("graft_queue_rejected_total", rr)
                    .with_label("stage", "retired"),
            );
        }
        out.push(Metric::counter(
            "graft_transition_swaps_total",
            self.swaps.load(Ordering::Relaxed),
        ));
    }

    /// Hot-swap to `new_plan`: prepare the new core, switch the routing
    /// atomically, drain the old core gracefully.  In-flight requests
    /// finish on the old core (their reply channels are per-request, so
    /// responses route correctly); requests submitted after the switch
    /// run on the new core — nothing is dropped, nothing runs twice.
    pub fn reconfigure(&self, new_plan: &ExecutionPlan) -> TransitionReport {
        let _swap = lock_recover(&self.swap_lock);
        let t0 = Instant::now();
        let old_plan = self.plan();
        let transition = diff_plans(&old_plan, new_plan);

        // prepare: the new core's queues open and its executors idle
        let new_server = Arc::new(Server::start(
            self.executor.clone(),
            &self.cm,
            new_plan,
            self.opts,
        ));
        let prepare_ms = t0.elapsed().as_secs_f64() * 1e3;

        // switch: the write lock waits for in-progress submits (they
        // hold the read lock across their queue push), then every later
        // submit sees the new core — no push can land in a queue the
        // drain is about to close
        let t1 = Instant::now();
        let old_server = {
            let mut cur = write_recover(&self.current);
            std::mem::replace(&mut *cur, new_server)
        };
        *lock_recover(&self.plan) = new_plan.clone();
        let switch_ms = t1.elapsed().as_secs_f64() * 1e3;

        // drain: old shards finish under their SLO while the new
        // shards already serve
        let t2 = Instant::now();
        old_server.drain();
        let drain_ms = t2.elapsed().as_secs_f64() * 1e3;

        let c = &old_server.counters;
        // queue-level count only: ServerCounters::rejected mirrors the
        // same refusals, so adding it would report every loss twice
        let old_rejected = old_server.queue_rejections();
        let old_dropped = c.dropped.load(Ordering::Relaxed);
        self.retired_served
            .fetch_add(c.served.load(Ordering::Relaxed), Ordering::Relaxed);
        self.retired_dropped.fetch_add(old_dropped, Ordering::Relaxed);
        self.retired_rejected.fetch_add(old_rejected, Ordering::Relaxed);
        self.retired_batches
            .fetch_add(c.batches.load(Ordering::Relaxed), Ordering::Relaxed);
        self.swaps.fetch_add(1, Ordering::SeqCst);

        TransitionReport {
            transition,
            prepare_ms,
            switch_ms,
            drain_ms,
            total_ms: t0.elapsed().as_secs_f64() * 1e3,
            old_rejected,
            old_dropped,
        }
    }

    /// Tear down the current core (end of process; retired cores were
    /// already drained and joined by their reconfigure).
    pub fn shutdown(self) {
        let server = self
            .current
            .into_inner()
            .unwrap_or_else(|e| e.into_inner());
        match Arc::try_unwrap(server) {
            Ok(s) => s.shutdown(),
            // a front-end still holds the Arc: close the queues so its
            // executors exit; threads are detached with the Arc
            Err(s) => s.drain(),
        }
    }
}

impl RequestSink for LiveServer {
    fn submit(&self, req: Request, reply: mpsc::Sender<Response>) {
        // hold the read lock across the push: reconfigure's write lock
        // then guarantees no submit is still targeting the old core
        // when its drain begins
        let cur = read_recover(&self.current);
        cur.submit(req, reply);
    }

    fn on_conn_evicted(&self) {
        self.server().on_conn_evicted();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::coordinator::repartition::{realign_group, RepartitionOptions};
    use crate::coordinator::{ClientId, FragmentSpec};

    fn cm() -> CostModel {
        CostModel::new(Config::embedded())
    }

    fn plan_of(cm: &CostModel, specs: &[(u32, usize, f64, f64)]) -> ExecutionPlan {
        let mi = cm.model_index("inc").unwrap();
        let specs: Vec<FragmentSpec> = specs
            .iter()
            .map(|&(c, p, t, q)| {
                FragmentSpec::single(ClientId(c), mi, p, t, q)
            })
            .collect();
        realign_group(cm, &specs, &RepartitionOptions::default())
    }

    #[test]
    fn identical_plans_diff_to_all_keep() {
        let cm = cm();
        let a = plan_of(&cm, &[(0, 2, 110.0, 30.0), (1, 3, 95.0, 30.0)]);
        let t = diff_plans(&a, &a.clone());
        assert_eq!(t.kept_sets, a.sets.len());
        assert_eq!(t.updated_sets + t.added_sets + t.removed_sets, 0);
        assert_eq!(t.restarted_instances, 0);
        assert_eq!(t.retired_instances, 0);
        assert!(t.kept_instances > 0);
    }

    #[test]
    fn changed_budget_diffs_to_update_not_add() {
        let cm = cm();
        // single-client plans: one set with the same identity on both
        // sides regardless of how realignment shapes it
        let a = plan_of(&cm, &[(0, 2, 110.0, 30.0)]);
        let b = plan_of(&cm, &[(0, 2, 100.0, 30.0)]);
        assert_ne!(a, b, "budget move must change the plan");
        let t = diff_plans(&a, &b);
        assert_eq!(t.added_sets, 0);
        assert_eq!(t.removed_sets, 0);
        assert_eq!(t.updated_sets, b.sets.len());
        assert!(t.restarted_instances > 0);
        assert!(t.retired_instances > 0);
    }

    #[test]
    fn arrivals_and_departures_diff_to_add_remove() {
        let cm = cm();
        let a = plan_of(&cm, &[(0, 2, 110.0, 30.0)]);
        let b = plan_of(&cm, &[(7, 2, 110.0, 30.0)]);
        let t = diff_plans(&a, &b);
        assert_eq!(t.added_sets, b.sets.len());
        assert_eq!(t.removed_sets, a.sets.len());
        assert_eq!(t.kept_sets + t.updated_sets, 0);
    }
}
