//! Runtime: PJRT loading + execution of the AOT artifacts.
//!
//! `make artifacts` (Python, build-time) writes `artifacts/*.hlo.txt`,
//! `weights_*.bin` and `manifest.json`; this module is everything the
//! Rust request path needs to run them: the manifest index, the weight
//! blobs, and the caching PJRT [`Engine`].

mod engine;
mod manifest;
pub mod transition;
mod weights;

pub use engine::{Engine, ExecOutput};
pub use manifest::{
    default_artifacts_dir, deployment_json, ArtifactEntry, Manifest,
    ManifestModel,
};
pub use transition::{
    diff_plans, LiveServer, LiveTotals, SetChange, TransitionPlan,
    TransitionReport,
};
pub use weights::ModelWeights;
