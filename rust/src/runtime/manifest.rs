//! AOT artifact manifest (`artifacts/manifest.json`) and the
//! deployment manifest for placed execution plans.
//!
//! The artifact manifest is written once by `python/compile/aot.py`;
//! it indexes every compiled fragment executable by `(model, start,
//! end, batch)` plus the weight blob per model.  The Rust runtime never
//! parses HLO itself — it hands the text to PJRT — so this manifest is
//! the only metadata contract between the Python compile path and the
//! Rust request path.
//!
//! [`deployment_json`] is the outbound counterpart: it serialises a
//! *placed* [`ExecutionPlan`] as a per-GPU instance listing (one MPS
//! server per GPU, each instance with its fragment, batch bucket and
//! share) so launch tooling can consume the planner's placement
//! decisions (`graft plan --deploy FILE`).

use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::coordinator::placement::stamped_usage;
use crate::coordinator::ExecutionPlan;
use crate::profiler::CostModel;
use crate::util::Json;

/// One compiled fragment executable.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub model: String,
    pub start: usize,
    pub end: usize,
    pub batch: u32,
    pub path: PathBuf,
    pub weights: PathBuf,
    pub input_shape: [usize; 2],
    pub output_shape: [usize; 2],
    /// 1-indexed layers whose (w, b) follow the activation input, in order.
    pub param_layers: Vec<usize>,
}

/// Per-model metadata from the manifest.
#[derive(Debug, Clone)]
pub struct ManifestModel {
    pub dims: Vec<usize>,
    pub points: Vec<usize>,
}

/// The full artifact index.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub config_digest: String,
    pub batches: Vec<u32>,
    pub models: HashMap<String, ManifestModel>,
    entries: HashMap<(String, usize, usize, u32), ArtifactEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let j = Json::parse(text).context("parsing manifest.json")?;
        let mut models = HashMap::new();
        if let Json::Obj(m) = j.get("models")? {
            for (name, v) in m {
                models.insert(
                    name.clone(),
                    ManifestModel {
                        dims: v.get("dims")?.as_usize_vec()?,
                        points: v.get("points")?.as_usize_vec()?,
                    },
                );
            }
        } else {
            bail!("manifest models is not an object");
        }
        let mut entries = HashMap::new();
        for e in j.get("entries")?.as_arr()? {
            let model = e.get("model")?.as_str()?.to_string();
            let start = e.get("start")?.as_usize()?;
            let end = e.get("end")?.as_usize()?;
            let batch = e.get("batch")?.as_usize()? as u32;
            let ishape = e.get("input_shape")?.as_usize_vec()?;
            let oshape = e.get("output_shape")?.as_usize_vec()?;
            if ishape.len() != 2 || oshape.len() != 2 {
                bail!("bad shapes for {model} s{start} e{end} b{batch}");
            }
            entries.insert(
                (model.clone(), start, end, batch),
                ArtifactEntry {
                    model,
                    start,
                    end,
                    batch,
                    path: dir.join(e.get("path")?.as_str()?),
                    weights: dir.join(e.get("weights")?.as_str()?),
                    input_shape: [ishape[0], ishape[1]],
                    output_shape: [oshape[0], oshape[1]],
                    param_layers: e.get("param_layers")?.as_usize_vec()?,
                },
            );
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            config_digest: j.get("config_digest")?.as_str()?.to_string(),
            batches: j
                .get("batches")?
                .as_usize_vec()?
                .into_iter()
                .map(|b| b as u32)
                .collect(),
            models,
            entries,
        })
    }

    /// Exact lookup.
    pub fn get(
        &self,
        model: &str,
        start: usize,
        end: usize,
        batch: u32,
    ) -> Option<&ArtifactEntry> {
        self.entries.get(&(model.to_string(), start, end, batch))
    }

    /// Smallest compiled batch `>= batch` for the fragment (bucketed
    /// batching: partial batches are padded up to the bucket).
    pub fn bucket_for(
        &self,
        model: &str,
        start: usize,
        end: usize,
        batch: u32,
    ) -> Option<&ArtifactEntry> {
        let mut best: Option<&ArtifactEntry> = None;
        for (_, e) in self.entries.iter() {
            if e.model == model
                && e.start == start
                && e.end == end
                && e.batch >= batch
                && best.map_or(true, |b| e.batch < b.batch)
            {
                best = Some(e);
            }
        }
        best
    }

    /// All fragments available for a model (start, end pairs).
    pub fn fragments(&self, model: &str) -> Vec<(usize, usize)> {
        let mut v: Vec<(usize, usize)> = self
            .entries
            .values()
            .filter(|e| e.model == model)
            .map(|e| (e.start, e.end))
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Serialise a placed plan as a deployment manifest: one entry per
/// GPU with its aggregate share/memory load and the instances it
/// hosts.  Returns `None` when the plan carries no (complete) GPU
/// placement — an unplaced plan has nothing to deploy.
pub fn deployment_json(cm: &CostModel, plan: &ExecutionPlan) -> Option<Json> {
    let usage = stamped_usage(cm, plan)?;
    let mut per_gpu: Vec<Vec<Json>> = vec![Vec::new(); usage.len()];
    for s in plan.stages() {
        let model = &cm.config().models[s.frag.model].name;
        for &gpu in &s.gpus {
            let mut inst = BTreeMap::new();
            inst.insert("model".into(), Json::Str(model.clone()));
            inst.insert("start".into(), Json::Num(s.frag.start as f64));
            inst.insert("end".into(), Json::Num(s.frag.end as f64));
            inst.insert("batch".into(), Json::Num(s.alloc.batch as f64));
            inst.insert("share".into(), Json::Num(s.alloc.share as f64));
            per_gpu[gpu as usize].push(Json::Obj(inst));
        }
    }
    let gpus: Vec<Json> = usage
        .iter()
        .zip(per_gpu)
        .enumerate()
        .map(|(i, (u, instances))| {
            let mut o = BTreeMap::new();
            o.insert("gpu".into(), Json::Num(i as f64));
            o.insert("share".into(), Json::Num(u.share as f64));
            o.insert(
                "mem_mb".into(),
                Json::Num((u.mem_mb * 1e3).round() / 1e3),
            );
            o.insert("instances".into(), Json::Arr(instances));
            Json::Obj(o)
        })
        .collect();
    let mut doc = BTreeMap::new();
    doc.insert("manifest".into(), Json::Str("deployment".into()));
    doc.insert("schema_version".into(), Json::Num(1.0));
    doc.insert("gpus".into(), Json::Arr(gpus));
    Some(Json::Obj(doc))
}

/// Default artifacts directory: `$GRAFT_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("GRAFT_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "config_digest": "abc123",
      "models": {"vgg": {"dims": [256,512,512,448,384,320,64],
                          "points": [0,1,2,3,6]}},
      "batches": [1,2],
      "entries": [
        {"model": "vgg", "start": 1, "end": 6, "batch": 2,
         "path": "vgg_s1_e6_b2.hlo.txt", "weights": "weights_vgg.bin",
         "input_shape": [2, 512], "output_shape": [2, 64],
         "param_layers": [2,3,4,5,6]},
        {"model": "vgg", "start": 1, "end": 6, "batch": 1,
         "path": "vgg_s1_e6_b1.hlo.txt", "weights": "weights_vgg.bin",
         "input_shape": [1, 512], "output_shape": [1, 64],
         "param_layers": [2,3,4,5,6]}
      ]
    }"#;

    #[test]
    fn parse_and_lookup() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(m.len(), 2);
        let e = m.get("vgg", 1, 6, 2).unwrap();
        assert_eq!(e.input_shape, [2, 512]);
        assert_eq!(e.param_layers, vec![2, 3, 4, 5, 6]);
        assert!(e.path.ends_with("vgg_s1_e6_b2.hlo.txt"));
        assert!(m.get("vgg", 0, 6, 2).is_none());
    }

    #[test]
    fn bucket_rounds_up() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(m.bucket_for("vgg", 1, 6, 1).unwrap().batch, 1);
        assert_eq!(m.bucket_for("vgg", 1, 6, 2).unwrap().batch, 2);
        assert!(m.bucket_for("vgg", 1, 6, 3).is_none());
    }

    #[test]
    fn fragments_listing() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(m.fragments("vgg"), vec![(1, 6)]);
        assert!(m.fragments("inc").is_empty());
    }

    #[test]
    fn deployment_manifest_lists_every_placed_instance() {
        use crate::config::Config;
        use crate::coordinator::baselines::gslice;
        use crate::coordinator::placement::{place, stamp};
        use crate::coordinator::{ClientId, FragmentSpec};
        use crate::profiler::AllocConstraints;

        let cm = CostModel::new(Config::embedded());
        let inc = cm.model_index("inc").unwrap();
        let specs: Vec<FragmentSpec> = (0..6)
            .map(|i| FragmentSpec::single(ClientId(i), inc, 3, 100.0, 30.0))
            .collect();
        let mut plan = gslice(&cm, &specs, &AllocConstraints::default());
        assert!(deployment_json(&cm, &plan).is_none(), "unplaced plan");
        let placement = place(&cm, &plan, None).unwrap();
        stamp(&mut plan, &placement);
        let doc = deployment_json(&cm, &plan).unwrap();
        // the document round-trips through the JSON printer/parser
        let re = Json::parse(&doc.to_string()).unwrap();
        let gpus = re.get("gpus").unwrap().as_arr().unwrap();
        assert_eq!(gpus.len(), placement.gpus());
        let total_instances: usize = gpus
            .iter()
            .map(|g| g.get("instances").unwrap().as_arr().unwrap().len())
            .sum();
        let planned: usize = plan
            .stages()
            .map(|s| s.alloc.instances as usize)
            .sum();
        assert_eq!(total_instances, planned);
    }
}
