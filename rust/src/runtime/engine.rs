//! PJRT execution engine: loads AOT HLO-text artifacts, compiles them on
//! the CPU PJRT client, caches the executables, and runs fragment
//! inference on the request path.
//!
//! Weights are uploaded once per model as device buffers; per-request
//! work is: host activation → device buffer → `execute_b` → host output.
//! Python is never involved (see /opt/xla-example/README.md for the
//! HLO-text interchange rationale).

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use super::manifest::{ArtifactEntry, Manifest};
use super::weights::ModelWeights;

/// Key of a compiled executable.
pub type FragKey = (String, usize, usize, u32);

/// The runtime engine.  Thread-safe: executables and weights are built
/// once under a lock and then shared; PJRT execution itself is
/// re-entrant.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    state: Mutex<EngineState>,
}

#[derive(Default)]
struct EngineState {
    executables: HashMap<FragKey, std::sync::Arc<xla::PjRtLoadedExecutable>>,
    /// Per (model, start, end): weight arguments pre-uploaded as device
    /// buffers in call order (uploading ~MBs of weights per request was
    /// the runtime's top bottleneck — see EXPERIMENTS.md §Perf).
    weight_args: HashMap<(String, usize, usize), std::sync::Arc<Vec<xla::PjRtBuffer>>>,
    /// Parsed weight blobs per model.
    weights: HashMap<String, std::sync::Arc<ModelWeights>>,
}

/// Result of one fragment execution.
#[derive(Debug, Clone)]
pub struct ExecOutput {
    /// `[batch, dim_out]` row-major.
    pub data: Vec<f32>,
    pub batch: usize,
    pub dim_out: usize,
}

impl Engine {
    /// Create an engine over an artifacts directory.
    pub fn new(artifacts_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Engine { client, manifest, state: Mutex::new(EngineState::default()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Execute fragment `(model, start, end)` on `rows` activations of
    /// width `dim_in`.  Rows are padded up to the smallest compiled batch
    /// bucket; only the first `rows.len()` outputs are returned.
    pub fn run(
        &self,
        model: &str,
        start: usize,
        end: usize,
        rows: &[Vec<f32>],
    ) -> Result<ExecOutput> {
        if rows.is_empty() {
            bail!("empty batch");
        }
        let entry = self
            .manifest
            .bucket_for(model, start, end, rows.len() as u32)
            .ok_or_else(|| {
                anyhow!(
                    "no artifact for {model} s{start} e{end} batch>={}",
                    rows.len()
                )
            })?
            .clone();
        let dim_in = entry.input_shape[1];
        for (i, r) in rows.iter().enumerate() {
            if r.len() != dim_in {
                bail!(
                    "row {i} has width {} but fragment expects {dim_in}",
                    r.len()
                );
            }
        }
        let exe = self.executable(&entry)?;
        let weight_args = self.weight_args(&entry)?;

        // Pad the batch to the bucket with zero rows.
        let bucket = entry.batch as usize;
        let mut flat = Vec::with_capacity(bucket * dim_in);
        for r in rows {
            flat.extend_from_slice(r);
        }
        flat.resize(bucket * dim_in, 0.0);
        let x = self
            .client
            .buffer_from_host_buffer::<f32>(&flat, &[bucket, dim_in], None)
            .map_err(|e| anyhow!("upload input: {e:?}"))?;

        let mut args: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(1 + weight_args.len());
        args.push(&x);
        args.extend(weight_args.iter());

        let result = exe
            .execute_b::<&xla::PjRtBuffer>(&args)
            .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        // aot.py lowers with return_tuple=True -> 1-tuple.
        let out = result
            .to_tuple1()
            .map_err(|e| anyhow!("untuple: {e:?}"))?;
        let data_full = out
            .to_vec::<f32>()
            .map_err(|e| anyhow!("to_vec: {e:?}"))?;
        let dim_out = entry.output_shape[1];
        if data_full.len() != bucket * dim_out {
            bail!(
                "output has {} elements, expected {}",
                data_full.len(),
                bucket * dim_out
            );
        }
        Ok(ExecOutput {
            data: data_full[..rows.len() * dim_out].to_vec(),
            batch: rows.len(),
            dim_out,
        })
    }

    /// Compile (or fetch cached) the executable for an artifact.
    fn executable(
        &self,
        entry: &ArtifactEntry,
    ) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        let key: FragKey =
            (entry.model.clone(), entry.start, entry.end, entry.batch);
        {
            let st = self.state.lock().unwrap();
            if let Some(exe) = st.executables.get(&key) {
                return Ok(exe.clone());
            }
        }
        let path = entry
            .path
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 path"))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parse HLO {path}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(
            self.client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {path}: {e:?}"))?,
        );
        let mut st = self.state.lock().unwrap();
        Ok(st.executables.entry(key).or_insert(exe).clone())
    }

    /// Weight arguments for a fragment as device-resident buffers, in
    /// `fragment_fn` order (uploaded once, reused by every request).
    fn weight_args(
        &self,
        entry: &ArtifactEntry,
    ) -> Result<std::sync::Arc<Vec<xla::PjRtBuffer>>> {
        let key = (entry.model.clone(), entry.start, entry.end);
        {
            let st = self.state.lock().unwrap();
            if let Some(w) = st.weight_args.get(&key) {
                return Ok(w.clone());
            }
        }
        let weights = self.model_weights(entry)?;
        let dims = &weights.dims;
        let mut args = Vec::with_capacity(2 * entry.param_layers.len());
        for &layer in &entry.param_layers {
            let (w, b) = weights.layer(layer)?;
            args.push(
                self.client
                    .buffer_from_host_buffer::<f32>(
                        w,
                        &[dims[layer - 1], dims[layer]],
                        None,
                    )
                    .map_err(|e| anyhow!("upload w{layer}: {e:?}"))?,
            );
            args.push(
                self.client
                    .buffer_from_host_buffer::<f32>(b, &[dims[layer]], None)
                    .map_err(|e| anyhow!("upload b{layer}: {e:?}"))?,
            );
        }
        let args = std::sync::Arc::new(args);
        let mut st = self.state.lock().unwrap();
        Ok(st.weight_args.entry(key).or_insert(args).clone())
    }

    fn model_weights(
        &self,
        entry: &ArtifactEntry,
    ) -> Result<std::sync::Arc<ModelWeights>> {
        {
            let st = self.state.lock().unwrap();
            if let Some(w) = st.weights.get(&entry.model) {
                return Ok(w.clone());
            }
        }
        let dims = &self
            .manifest
            .models
            .get(&entry.model)
            .ok_or_else(|| anyhow!("model {} not in manifest", entry.model))?
            .dims;
        let w = std::sync::Arc::new(
            ModelWeights::load(&entry.weights, dims)
                .with_context(|| format!("weights for {}", entry.model))?,
        );
        let mut st = self.state.lock().unwrap();
        Ok(st
            .weights
            .entry(entry.model.clone())
            .or_insert(w)
            .clone())
    }

    /// Eagerly compile every artifact of the given fragments (warmup).
    pub fn warmup(&self, frags: &[(String, usize, usize)]) -> Result<usize> {
        let mut n = 0;
        for (model, start, end) in frags {
            for &batch in &self.manifest.batches.clone() {
                if let Some(e) = self.manifest.get(model, *start, *end, batch)
                {
                    let e = e.clone();
                    self.executable(&e)?;
                    self.weight_args(&e)?;
                    n += 1;
                }
            }
        }
        Ok(n)
    }
}

// Engine is used from multiple instance threads.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}
