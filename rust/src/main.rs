//! Graft CLI — leader entrypoint.
//!
//! Subcommands:
//!   experiment <id|all> [--out DIR]   regenerate paper tables/figures
//!   plan --model M --scale S [--t T]  print an execution plan
//!        [--planner-threads T]        (per-model planner shards; the
//!                                     plan is identical at any T)
//!   serve [--model M] [--clients N] [--duration S] [--addr A]
//!         [--reconfigure] [--metrics-addr A] [--trace-sample N]
//!                                     run the real serving data path
//!                                     (--reconfigure: replan controller
//!                                     hot-swaps plans on demand drift;
//!                                     --metrics-addr: /metrics endpoint;
//!                                     --trace-sample: trace every Nth
//!                                     request into the budget report)
//!   obs-report [--clients N] [--requests R] [--trace-sample N]
//!              [--format prom|json] [--out FILE]
//!              [--metrics-addr A [--serve-for S]] | [--addr A]
//!                                     traced synthetic run -> SLO-budget
//!                                     attribution + metrics exposition
//!                                     (--addr scrapes a live endpoint)
//!   trace [--seed N] [--len S]        print a synthetic 5G trace
//!   models                            list model specs (Table 2)
//!   bench-scheduler [--sizes N,N,..] [--reps R] [--out FILE]
//!                   [--planner-threads T] [--shard-sizes N,N,..]
//!                                     time Scheduler::plan at scale
//!                                     (incl. sharded parallel planning
//!                                     vs the sequential oracle, up to
//!                                     n=100k) and emit
//!                                     BENCH_scheduler.json
//!   bench-serving [--sizes N,N,..] [--requests R] [--out FILE]
//!                                     drive the serving data path under
//!                                     both executor modes and emit
//!                                     BENCH_serving.json
//!   bench-placement [--sizes N,N,..] [--out FILE]
//!                                     compare planner-integrated GPU
//!                                     placement against the post-hoc
//!                                     FFD oracle and emit
//!                                     BENCH_placement.json
//!   bench-transition [--sizes N,N,..] [--requests R] [--out FILE]
//!                                     hot-swap perturbed plans under
//!                                     live traffic (zero-drop,
//!                                     delta-placement vs full repack)
//!                                     and emit BENCH_transition.json
//!   bench-faults [--sizes N,N,..] [--requests R] [--out FILE]
//!                                     fail a live GPU under load,
//!                                     measure detection → emergency
//!                                     replan → hot-swap recovery and
//!                                     emit BENCH_faults.json

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use graft::config::Config;
use graft::coordinator::repartition::RepartitionOptions;
use graft::coordinator::scheduler::{Scheduler, SchedulerOptions};
use graft::coordinator::{ControllerOptions, ReplanController};
use graft::experiments;
use graft::hybrid::{BandwidthTrace, TraceParams};
use graft::obs::{
    render_stats_line, Metric, MetricsRegistry, MetricsServer, TraceOptions,
};
use graft::profiler::{AllocConstraints, CostModel};
use graft::runtime::{default_artifacts_dir, Engine, LiveServer};
use graft::serving::{ServerOptions, TcpFront};

fn main() {
    // die quietly on closed pipes (`graft ... | head`), like other CLIs
    unsafe {
        libc::signal(libc::SIGPIPE, libc::SIG_DFL);
    }
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Minimal flag parser: positionals + `--key value` pairs.
struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
}

fn parse_args(argv: &[String]) -> Args {
    let mut positional = Vec::new();
    let mut flags = HashMap::new();
    let mut it = argv.iter().peekable();
    while let Some(a) = it.next() {
        if let Some(key) = a.strip_prefix("--") {
            let val = it
                .peek()
                .filter(|v| !v.starts_with("--"))
                .map(|v| v.to_string());
            if let Some(v) = val {
                it.next();
                flags.insert(key.to_string(), v);
            } else {
                flags.insert(key.to_string(), "true".to_string());
            }
        } else {
            positional.push(a.clone());
        }
    }
    Args { positional, flags }
}

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cm = CostModel::new(Config::embedded());
    let Some(cmd) = argv.first().map(String::as_str) else {
        print_usage();
        return Ok(());
    };
    let args = parse_args(&argv[1..]);
    match cmd {
        "experiment" => cmd_experiment(&cm, &args),
        "plan" => cmd_plan(&cm, &args),
        "bench-scheduler" => cmd_bench_scheduler(&args),
        "bench-serving" => cmd_bench_serving(&cm, &args),
        "bench-placement" => cmd_bench_placement(&args),
        "bench-transition" => cmd_bench_transition(&args),
        "bench-faults" => cmd_bench_faults(&args),
        "serve" => cmd_serve(&cm, &args),
        "obs-report" => cmd_obs_report(&cm, &args),
        "trace" => cmd_trace(&args),
        "models" => {
            let t = experiments::motivation::tab2(&cm);
            print!("{}", t.pretty());
            Ok(())
        }
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command {other:?} (try `graft help`)"),
    }
}

fn print_usage() {
    println!(
        "graft — inference serving for hybrid DL via DNN re-alignment\n\n\
         usage:\n\
         \x20 graft experiment <id|all> [--out results]\n\
         \x20 graft plan --model inc --scale small-homo [--t 5] [--deploy FILE] [--planner-threads 1]\n\
         \x20 graft serve [--model vgg] [--clients 4] [--duration 10] [--addr 127.0.0.1:0] [--reconfigure] [--planner-threads 1] [--metrics-addr 127.0.0.1:9464] [--trace-sample 8]\n\
         \x20 graft obs-report [--clients 64] [--requests 4000] [--trace-sample 1] [--format prom] [--out FILE] [--metrics-addr 127.0.0.1:9464 --serve-for 5] [--addr HOST:PORT]\n\
         \x20 graft trace [--seed 7] [--len 60]\n\
         \x20 graft models\n\
         \x20 graft bench-scheduler [--sizes 1000,5000,10000] [--reps 3] [--planner-threads 4] [--shard-sizes 1000,10000,100000] [--out BENCH_scheduler.json]\n\
         \x20 graft bench-serving [--sizes 1000,5000,10000] [--requests 40000] [--out BENCH_serving.json]\n\
         \x20 graft bench-placement [--sizes 1000,5000,10000] [--out BENCH_placement.json]\n\
         \x20 graft bench-transition [--sizes 1000,5000,10000] [--requests 8000] [--out BENCH_transition.json]\n\
         \x20 graft bench-faults [--sizes 1000,5000,10000] [--requests 8000] [--out BENCH_faults.json]\n\n\
         experiments: {}",
        experiments::ALL.join(" ")
    );
}

fn cmd_experiment(cm: &CostModel, args: &Args) -> Result<()> {
    let id = args
        .positional
        .first()
        .context("usage: graft experiment <id|all>")?;
    let out = PathBuf::from(
        args.flags.get("out").cloned().unwrap_or_else(|| "results".into()),
    );
    let ids: Vec<&str> = if id == "all" {
        experiments::ALL.to_vec()
    } else {
        vec![id.as_str()]
    };
    for id in ids {
        let t0 = std::time::Instant::now();
        let table = experiments::run_and_save(id, cm, &out)?;
        println!(
            "== {id} ({} rows, {:.1}s) -> {} ==",
            table.rows.len(),
            t0.elapsed().as_secs_f64(),
            out.join(format!("{id}.csv")).display()
        );
        print!("{}", table.pretty());
        println!();
    }
    Ok(())
}

fn scale_from(name: &str) -> Result<experiments::common::Scale> {
    use experiments::common::Scale::*;
    Ok(match name {
        "small-homo" => SmallHomo,
        "small-heter" => SmallHeter,
        "large-homo" => LargeHomo,
        "large-heter" => LargeHeter,
        _ => bail!("unknown scale {name:?}"),
    })
}

fn cmd_plan(cm: &CostModel, args: &Args) -> Result<()> {
    let model = args.flags.get("model").map(String::as_str).unwrap_or("inc");
    let scale = scale_from(
        args.flags.get("scale").map(String::as_str).unwrap_or("small-homo"),
    )?;
    let t_s: f64 = args
        .flags
        .get("t")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(5.0);
    let mi = cm
        .model_index(model)
        .with_context(|| format!("unknown model {model}"))?;
    let clients = experiments::common::fleet(
        cm,
        mi,
        scale,
        cm.config().slo_ratio_default,
        42,
    );
    let planner_threads: usize = args
        .flags
        .get("planner-threads")
        .map(|s| s.parse())
        .transpose()
        .context("parsing --planner-threads")?
        .unwrap_or(1);
    let specs = experiments::common::snapshot(cm, &clients, t_s);
    let sched = Scheduler::new(
        cm.clone(),
        SchedulerOptions { planner_threads, ..Default::default() },
    );
    let (plan, stats) = sched.plan(&specs);
    println!(
        "{} clients -> {} specs -> {} merged -> {} sets, total share {}%, \
         plan computed in {:.2} ms",
        clients.len(),
        stats.n_input,
        stats.n_after_merge,
        plan.sets.len(),
        plan.total_share(),
        stats.total_ms,
    );
    println!(
        "  reuse: {}/{} group plans replayed, {}/{} merge classes \
         re-merged, {} warm DP hits, {} grid points costed ({} screened \
         out)",
        stats.n_groups_reused,
        stats.n_groups,
        stats.classes_remerged,
        stats.merge_classes,
        stats.dp_warm_hits,
        stats.grid_points_evaluated,
        stats.grid_points_pruned,
    );
    println!(
        "  grouping: {}/{} groups replayed, {} fragments regrouped, \
         {} fallback slices",
        stats.groups_replayed,
        stats.n_groups,
        stats.fragments_regrouped,
        stats.group_fallbacks,
    );
    if stats.planner_shards > 0 {
        println!(
            "  shards: {} on {} thread(s), slowest {:.2} ms, imbalance \
             {:.2}x (max/mean)",
            stats.planner_shards,
            planner_threads,
            stats.shard_max_ms,
            stats.shard_imbalance,
        );
        for sh in &stats.shards {
            println!(
                "    shard model {} ({}): {} specs -> {} merged -> {} \
                 groups in {:.2} ms",
                cm.config().models[sh.model].name,
                sh.model,
                sh.n_specs,
                sh.n_merged,
                sh.n_groups,
                sh.ms,
            );
        }
    }
    if stats.gpus > 0 {
        println!(
            "  placed on {} GPUs (share lower bound {}, fragmentation \
             {:.1}%, {} feedback rounds)",
            stats.gpus,
            plan.gpus_share_lower_bound(cm.config().gpu.max_share),
            stats.fragmentation * 100.0,
            stats.placement_rounds,
        );
    }
    if let Some(path) = args.flags.get("deploy") {
        match graft::runtime::deployment_json(cm, &plan) {
            Some(doc) => {
                std::fs::write(path, format!("{doc}\n"))
                    .with_context(|| format!("writing {path}"))?;
                println!("  wrote deployment manifest to {path}");
            }
            None => println!("  no placement to deploy (plan unstamped)"),
        }
    }
    for (i, set) in plan.sets.iter().enumerate() {
        println!(
            "  set {i}: model {} repartition@{} shared {:?} ({} members)",
            cm.config().models[set.model].name,
            set.point,
            set.shared.alloc,
            set.members.len()
        );
        for m in &set.members {
            match &m.align {
                Some(a) => {
                    println!("    member p={} align {:?}", m.spec.p, a.alloc)
                }
                None => println!("    member p={} (no align stage)", m.spec.p),
            }
        }
    }
    if !plan.infeasible.is_empty() {
        println!("  infeasible: {} specs", plan.infeasible.len());
    }
    Ok(())
}

/// `graft bench-scheduler`: time `Scheduler::plan` on mixed-model demand
/// sets and emit a machine-readable trajectory (`BENCH_scheduler.json`)
/// so successive PRs can track planner performance.
///
/// Per size three planner configurations are timed:
///   cold      — fresh caches (first trigger after startup),
///   warm      — re-plan of identical demands (incremental replay),
///   perturbed — re-plan after ~1% of clients changed partition point /
///               budget (the trigger-based re-planning steady state),
/// plus `uncached` — allocation cache and incremental reuse disabled —
/// as the reference the speedup is measured against.
///
/// A second `replan` section then measures trigger-to-trigger
/// replanning head-on: per size and perturbation share k ∈ {1, 5, 20}%
/// it cold-plans a fresh fleet, perturbs k% of the clients, re-plans on
/// the same scheduler and self-checks that (a) the replanned plan
/// matches a fresh cold plan's quality — covers every client, meets
/// every budget, and stays within the share slack (byte-identity is no
/// longer the contract: incremental grouping replays previous groups
/// instead of re-deriving them, trading exact identity for an ε-audited
/// objective bound); (b) the warm replan is not slower than cold
/// planning; and (c) at k ∈ {1, 5}% the incremental grouping time beats
/// the scratch grouping time (small absolute slacks absorb timer noise
/// at CI smoke sizes — at bench sizes the margins are orders of
/// magnitude).  Each replan row carries the grouping counters
/// (`groups_replayed`, `fragments_regrouped`) and a `grouping_ok` flag
/// CI greps for, plus the context-persistence cost (`ctx_save_ms` /
/// `ctx_resave_ms`) with a self-check that the dirty flag skipped the
/// clean re-save (`ctx_resave_skipped`).
///
/// A third `sharded` section (schema v4) measures sharded parallel
/// planning over `--shard-sizes` (default up to n=100k) at
/// `--planner-threads` workers (default 4): per point it cold-plans the
/// same mixed demand sequentially (`planner_threads = 1`, the oracle)
/// and sharded, self-checks byte-identity at every n (hard bail — the
/// determinism contract), and at n ≥ 100k with ≥ 4 threads additionally
/// requires the sharded wall time to beat the sequential one — gated on
/// the machine actually having ≥ 4 cores (`available_parallelism`), so
/// a 1-core CI smoke box checks identity but not speedup.  Each row
/// carries a `shards_ok` flag CI greps for.
fn cmd_bench_scheduler(args: &Args) -> Result<()> {
    use graft::coordinator::repartition::{
        plan_covers_demand, plan_is_slo_safe,
    };
    use graft::coordinator::FragmentSpec;
    use graft::experiments::common::random_mixed_fragments;
    use graft::experiments::scale::{
        perturb_fragments, replan_scenario, sharded_plan_scenario,
    };
    use graft::util::bench::time_ms;
    use graft::util::Json;
    use std::collections::BTreeMap;

    let sizes: Vec<usize> = args
        .flags
        .get("sizes")
        .map(String::as_str)
        .unwrap_or("1000,5000,10000")
        .split(',')
        .map(|s| s.trim().parse().context("parsing --sizes"))
        .collect::<Result<_>>()?;
    let reps: usize = args
        .flags
        .get("reps")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(3);
    let planner_threads: usize = args
        .flags
        .get("planner-threads")
        .map(|s| s.parse())
        .transpose()
        .context("parsing --planner-threads")?
        .unwrap_or(4);
    let shard_sizes: Vec<usize> = args
        .flags
        .get("shard-sizes")
        .map(String::as_str)
        .unwrap_or("1000,10000,100000")
        .split(',')
        .map(|s| s.trim().parse().context("parsing --shard-sizes"))
        .collect::<Result<_>>()?;
    let out = PathBuf::from(
        args.flags
            .get("out")
            .cloned()
            .unwrap_or_else(|| "BENCH_scheduler.json".into()),
    );

    let time_plan = |sched: &Scheduler, specs: &[FragmentSpec]| {
        let (ms, (plan, stats)) = time_ms(|| sched.plan(specs));
        (ms, plan, stats)
    };
    let num = Json::Num;
    let ms3 = |v: f64| Json::Num((v * 1e3).round() / 1e3);

    let mut runs = Vec::new();
    println!(
        "{:>8} {:>10} {:>10} {:>12} {:>12} {:>8} {:>8}",
        "n", "cold_ms", "warm_ms", "perturb_ms", "uncached_ms", "reused",
        "share"
    );
    for &n in &sizes {
        let mut best: Option<BTreeMap<String, Json>> = None;
        for _rep in 0..reps.max(1) {
            let cm = CostModel::new(Config::embedded());
            let sched =
                Scheduler::new(cm.clone(), SchedulerOptions::default());
            let mut specs = random_mixed_fragments(&cm, n, 0xB15C);

            let (cold_ms, cold_plan, cold_stats) = time_plan(&sched, &specs);
            // snapshot before the warm/perturbed passes inflate it
            let (hits, misses) = cm.cache_stats();
            let (warm_ms, warm_plan, warm_stats) = time_plan(&sched, &specs);
            if warm_plan != cold_plan {
                bail!("incremental re-plan diverged from cold plan at n={n}");
            }
            if warm_stats.fragments_regrouped != 0 {
                bail!(
                    "unchanged demands regrouped {} fragments at n={n}",
                    warm_stats.fragments_regrouped
                );
            }
            // ~1% of clients move their partition point / budget (the
            // shared replan-scenario perturbation)
            perturb_fragments(&cm, &mut specs, 1);
            let (pert_ms, pert_plan, pert_stats) = time_plan(&sched, &specs);

            // reference: no allocation cache, no incremental reuse.
            // Incremental grouping makes the perturbed plan heuristic,
            // so the check is quality (coverage / SLO safety / share
            // slack) rather than the byte-identity of earlier PRs.
            let un_cm = CostModel::new_uncached(Config::embedded());
            let un_sched = Scheduler::new(
                un_cm,
                SchedulerOptions { incremental: false, ..Default::default() },
            );
            let (uncached_ms, un_plan, _) = time_plan(&un_sched, &specs);
            if !plan_covers_demand(&pert_plan) || !plan_is_slo_safe(&pert_plan)
            {
                bail!("perturbed incremental plan invalid at n={n}");
            }
            if pert_plan.total_share() as f64
                > un_plan.total_share() as f64 * 1.2
            {
                bail!(
                    "perturbed incremental share {} too far above the \
                     uncached reference {} at n={n}",
                    pert_plan.total_share(),
                    un_plan.total_share()
                );
            }

            let mut row = BTreeMap::new();
            row.insert("n_clients".into(), num(n as f64));
            row.insert("cold_ms".into(), ms3(cold_ms));
            row.insert("warm_ms".into(), ms3(warm_ms));
            row.insert("perturbed_ms".into(), ms3(pert_ms));
            row.insert("uncached_ms".into(), ms3(uncached_ms));
            row.insert("merge_ms".into(), ms3(cold_stats.merge_ms));
            row.insert("group_ms".into(), ms3(cold_stats.group_ms));
            row.insert(
                "repartition_ms".into(),
                ms3(cold_stats.repartition_ms),
            );
            // placement joined the planner in PR 3 — its share of the
            // cold time is reported so the merge/group/repartition
            // trend stays comparable across PRs
            row.insert(
                "placement_ms".into(),
                ms3(cold_stats.placement_ms),
            );
            row.insert("gpus".into(), num(cold_stats.gpus as f64));
            row.insert(
                "n_after_merge".into(),
                num(cold_stats.n_after_merge as f64),
            );
            row.insert("n_groups".into(), num(cold_stats.n_groups as f64));
            row.insert(
                "n_groups_reused_perturbed".into(),
                num(pert_stats.n_groups_reused as f64),
            );
            // incremental grouping counters: unchanged demands replay
            // everything, the 1% perturbation regroups only the delta
            row.insert(
                "groups_replayed_warm".into(),
                num(warm_stats.groups_replayed as f64),
            );
            row.insert(
                "groups_replayed_perturbed".into(),
                num(pert_stats.groups_replayed as f64),
            );
            row.insert(
                "fragments_regrouped_perturbed".into(),
                num(pert_stats.fragments_regrouped as f64),
            );
            // PR 4 delta-awareness counters: merge classes re-merged /
            // warm DP hits on the perturbed trigger, grid points the
            // cold plan's adaptive d_shared search actually costed
            row.insert(
                "merge_classes".into(),
                num(cold_stats.merge_classes as f64),
            );
            row.insert(
                "classes_remerged_perturbed".into(),
                num(pert_stats.classes_remerged as f64),
            );
            row.insert(
                "dp_warm_hits_perturbed".into(),
                num(pert_stats.dp_warm_hits as f64),
            );
            row.insert(
                "grid_points_evaluated".into(),
                num(cold_stats.grid_points_evaluated as f64),
            );
            row.insert(
                "alloc_cache_hit_rate".into(),
                num((hits as f64 / (hits + misses).max(1) as f64 * 1e4)
                    .round()
                    / 1e4),
            );
            row.insert(
                "total_share".into(),
                num(cold_plan.total_share() as f64),
            );
            let better = best.as_ref().map_or(true, |b| {
                row["cold_ms"].as_f64().unwrap_or(f64::MAX)
                    < b["cold_ms"].as_f64().unwrap_or(f64::MAX)
            });
            if better {
                best = Some(row);
            }
        }
        let row = best.expect("reps >= 1");
        println!(
            "{:>8} {:>10} {:>10} {:>12} {:>12} {:>8} {:>8}",
            n,
            format!("{:.1}", row["cold_ms"].as_f64()?),
            format!("{:.1}", row["warm_ms"].as_f64()?),
            format!("{:.1}", row["perturbed_ms"].as_f64()?),
            format!("{:.1}", row["uncached_ms"].as_f64()?),
            format!("{:.0}", row["n_groups_reused_perturbed"].as_f64()?),
            format!("{:.0}", row["total_share"].as_f64()?),
        );
        runs.push(Json::Obj(row));
    }

    // `replan` scenario: trigger-to-trigger incremental replanning at
    // several perturbation shares, self-checked for plan quality
    // (coverage / SLO safety / share slack vs the fresh cold plan),
    // warm-not-slower-than-cold, and incremental-grouping-not-slower-
    // than-scratch at the small perturbation shares.
    let mut replans = Vec::new();
    println!(
        "\n{:>8} {:>5} {:>10} {:>10} {:>8} {:>9} {:>9} {:>9} {:>8}",
        "n", "k%", "cold_ms", "replan_ms", "speedup", "reused", "remerged",
        "regrouped", "share"
    );
    for &n in &sizes {
        for &pct in &[1usize, 5, 20] {
            let r = replan_scenario(n, pct, 0xB15C);
            if !r.covers || !r.slo_safe {
                bail!(
                    "replanned plan invalid at n={n} k={pct}% (covers {} \
                     slo_safe {})",
                    r.covers,
                    r.slo_safe
                );
            }
            if r.share_ratio > 1.2 {
                bail!(
                    "replanned share drifted {:.3}x past the fresh cold \
                     plan at n={n} k={pct}%",
                    r.share_ratio
                );
            }
            // warm replan must not lose to cold-planning the *same*
            // perturbed demands (10% + 5 ms slack for timer noise at
            // the n=200 CI smoke size; at bench sizes the margin is
            // orders of magnitude)
            if r.replan_ms > r.cold_fresh_ms * 1.1 + 5.0 {
                bail!(
                    "warm replan slower than cold at n={n} k={pct}%: \
                     {:.2} ms vs {:.2} ms",
                    r.replan_ms,
                    r.cold_fresh_ms
                );
            }
            // the tentpole claim: delta-aware grouping beats scratch
            // grouping at small perturbation shares (k ∈ {1, 5}%; the
            // 2 ms absolute slack absorbs timer noise and the ε-audit
            // overhead at the n=200 CI smoke size)
            if pct <= 5 && r.group_replan_ms > r.group_cold_ms * 1.1 + 2.0 {
                bail!(
                    "incremental grouping slower than scratch at n={n} \
                     k={pct}%: {:.2} ms vs {:.2} ms",
                    r.group_replan_ms,
                    r.group_cold_ms
                );
            }
            // the dirty flag must skip the clean re-save entirely
            if !r.ctx_resave_skipped {
                bail!(
                    "unchanged replan context was rewritten at n={n} \
                     k={pct}% (dirty flag failed)"
                );
            }
            println!(
                "{:>8} {:>5} {:>10} {:>10} {:>8} {:>9} {:>9} {:>9} {:>8}",
                n,
                pct,
                format!("{:.1}", r.cold_ms),
                format!("{:.1}", r.replan_ms),
                format!("{:.2}x", r.speedup),
                format!("{}/{}", r.groups_reused, r.n_groups),
                format!("{}/{}", r.classes_remerged, r.merge_classes),
                r.fragments_regrouped,
                r.total_share,
            );
            let mut row = BTreeMap::new();
            row.insert("n_clients".into(), num(r.n_clients as f64));
            row.insert("perturb_pct".into(), num(r.perturb_pct as f64));
            row.insert("cold_ms".into(), ms3(r.cold_ms));
            row.insert("replan_ms".into(), ms3(r.replan_ms));
            row.insert("cold_fresh_ms".into(), ms3(r.cold_fresh_ms));
            row.insert(
                "speedup".into(),
                num((r.speedup * 1e3).round() / 1e3),
            );
            row.insert("n_groups".into(), num(r.n_groups as f64));
            row.insert("groups_reused".into(), num(r.groups_reused as f64));
            row.insert("merge_classes".into(), num(r.merge_classes as f64));
            row.insert(
                "classes_remerged".into(),
                num(r.classes_remerged as f64),
            );
            row.insert("dp_warm_hits".into(), num(r.dp_warm_hits as f64));
            row.insert(
                "grid_points_cold".into(),
                num(r.grid_points_cold as f64),
            );
            row.insert(
                "grid_points_replan".into(),
                num(r.grid_points_replan as f64),
            );
            row.insert("total_share".into(), num(r.total_share as f64));
            row.insert("gpus".into(), num(r.gpus as f64));
            row.insert("group_cold_ms".into(), ms3(r.group_cold_ms));
            row.insert("group_replan_ms".into(), ms3(r.group_replan_ms));
            row.insert(
                "groups_replayed".into(),
                num(r.groups_replayed as f64),
            );
            row.insert(
                "fragments_regrouped".into(),
                num(r.fragments_regrouped as f64),
            );
            row.insert("covers".into(), Json::Bool(r.covers));
            row.insert("slo_safe".into(), Json::Bool(r.slo_safe));
            row.insert(
                "share_ratio".into(),
                num((r.share_ratio * 1e3).round() / 1e3),
            );
            row.insert("grouping_ok".into(), Json::Bool(true));
            row.insert("ctx_save_ms".into(), ms3(r.ctx_save_ms));
            row.insert("ctx_resave_ms".into(), ms3(r.ctx_resave_ms));
            row.insert(
                "ctx_resave_skipped".into(),
                Json::Bool(r.ctx_resave_skipped),
            );
            replans.push(Json::Obj(row));
        }
    }

    // `sharded` scenario: per-model planner shards vs the sequential
    // oracle.  Byte-identity is a hard bail at every size; the speedup
    // self-check fires at n >= 100k with >= 4 threads on machines that
    // actually have >= 4 cores.
    let cores = std::thread::available_parallelism()
        .map(std::num::NonZeroUsize::get)
        .unwrap_or(1);
    let mut sharded = Vec::new();
    println!(
        "\n{:>8} {:>8} {:>10} {:>10} {:>8} {:>7} {:>10} {:>10}",
        "n", "threads", "seq_ms", "par_ms", "speedup", "shards", "max_ms",
        "imbalance"
    );
    for &n in &shard_sizes {
        let r = sharded_plan_scenario(n, planner_threads, 0xB15C);
        if !r.identical {
            bail!(
                "sharded plan diverged from the sequential oracle at n={n} \
                 (threads={planner_threads})"
            );
        }
        if n >= 100_000
            && planner_threads >= 4
            && cores >= 4
            && r.par_ms >= r.seq_ms
        {
            bail!(
                "sharded planning not faster than sequential at n={n}: \
                 {:.1} ms vs {:.1} ms ({planner_threads} threads, \
                 {cores} cores)",
                r.par_ms,
                r.seq_ms
            );
        }
        println!(
            "{:>8} {:>8} {:>10} {:>10} {:>8} {:>7} {:>10} {:>10}",
            n,
            planner_threads,
            format!("{:.1}", r.seq_ms),
            format!("{:.1}", r.par_ms),
            format!("{:.2}x", r.speedup),
            r.planner_shards,
            format!("{:.1}", r.shard_max_ms),
            format!("{:.2}x", r.shard_imbalance),
        );
        let mut row = BTreeMap::new();
        row.insert("n_clients".into(), num(r.n_clients as f64));
        row.insert("threads".into(), num(r.threads as f64));
        row.insert("seq_ms".into(), ms3(r.seq_ms));
        row.insert("par_ms".into(), ms3(r.par_ms));
        row.insert("speedup".into(), num((r.speedup * 1e3).round() / 1e3));
        row.insert(
            "planner_shards".into(),
            num(r.planner_shards as f64),
        );
        row.insert("shard_max_ms".into(), ms3(r.shard_max_ms));
        row.insert(
            "shard_imbalance".into(),
            num((r.shard_imbalance * 1e3).round() / 1e3),
        );
        row.insert("identical".into(), Json::Bool(r.identical));
        row.insert("cores".into(), num(cores as f64));
        row.insert("total_share".into(), num(r.total_share as f64));
        row.insert("gpus".into(), num(r.gpus as f64));
        row.insert("shards_ok".into(), Json::Bool(true));
        sharded.push(Json::Obj(row));
    }

    // record the options the benchmark actually ran with, not literals
    let defaults = SchedulerOptions::default();
    let mut config = BTreeMap::new();
    config.insert("pool_size".into(), num(defaults.pool_size as f64));
    config.insert(
        "planner_threads".into(),
        num(planner_threads as f64),
    );
    config.insert("d_grid".into(), num(defaults.repartition.d_grid as f64));
    config.insert(
        "coarse_grid".into(),
        num(defaults.repartition.coarse_grid as f64),
    );
    config.insert(
        "adaptive_grid".into(),
        Json::Bool(defaults.repartition.adaptive_grid),
    );
    config.insert("group_size".into(), num(defaults.group.group_size as f64));
    config.insert("merge_threshold".into(), Json::Num(defaults.merge.threshold));
    config.insert("reps".into(), num(reps as f64));
    let mut doc = BTreeMap::new();
    doc.insert("bench".into(), Json::Str("scheduler".into()));
    doc.insert("schema_version".into(), num(4.0));
    doc.insert("config".into(), Json::Obj(config));
    doc.insert("runs".into(), Json::Arr(runs));
    doc.insert("replan".into(), Json::Arr(replans));
    doc.insert("sharded".into(), Json::Arr(sharded));
    let json = Json::Obj(doc);
    if let Some(dir) = out.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(&out, format!("{json}\n"))
        .with_context(|| format!("writing {}", out.display()))?;
    println!("\nwrote {}", out.display());
    Ok(())
}

/// `graft bench-serving`: drive the real serving data path (mock
/// executor, pacing off) with synthetic fleets under both executor
/// modes and emit `BENCH_serving.json` — the serving-path companion to
/// `BENCH_scheduler.json`.  Each size plans one mixed-model fleet and
/// serves the *same plan* thread-per-instance and pooled, so the two
/// rows differ only in the executor core.
fn cmd_bench_serving(cm: &CostModel, args: &Args) -> Result<()> {
    use graft::experiments::common::random_mixed_fragments;
    use graft::experiments::scale::{serve_synthetic_run, ServingBenchRun};
    use graft::obs::{counter_sum, counter_value};
    use graft::serving::ExecutorMode;
    use graft::util::Json;
    use std::collections::BTreeMap;

    let sizes: Vec<usize> = args
        .flags
        .get("sizes")
        .map(String::as_str)
        .unwrap_or("1000,5000,10000")
        .split(',')
        .map(|s| s.trim().parse().context("parsing --sizes"))
        .collect::<Result<_>>()?;
    let requests_flag: Option<usize> = args
        .flags
        .get("requests")
        .map(|s| s.parse())
        .transpose()
        .context("parsing --requests")?;
    let out = PathBuf::from(
        args.flags
            .get("out")
            .cloned()
            .unwrap_or_else(|| "BENCH_serving.json".into()),
    );

    let num = Json::Num;
    let ms3 = |v: f64| {
        Json::Num(if v.is_finite() { (v * 1e3).round() / 1e3 } else { -1.0 })
    };
    // the counter dump is read back out of the run's registry snapshot
    // under the canonical metric names — the same numbers `/metrics`
    // would have served during the run
    let counters_json = |snap: &[graft::obs::Metric]| {
        let mut o = BTreeMap::new();
        for name in [
            "graft_serving_served_total",
            "graft_serving_dropped_total",
            "graft_serving_batches_total",
            "graft_serving_batched_requests_total",
            "graft_serving_exec_panics_total",
            "graft_trace_requests_total",
        ] {
            o.insert(
                name.to_string(),
                num(counter_value(snap, name).unwrap_or(0) as f64),
            );
        }
        for name in ["graft_queue_pushed_total", "graft_queue_rejected_total"]
        {
            o.insert(name.to_string(), num(counter_sum(snap, name) as f64));
        }
        Json::Obj(o)
    };
    let point_json = |run: &ServingBenchRun| {
        let r = &run.point;
        let mut o = BTreeMap::new();
        o.insert("requests".into(), num(r.requests as f64));
        o.insert("wall_ms".into(), ms3(r.wall_ms));
        o.insert("throughput_rps".into(), ms3(r.throughput_rps));
        o.insert("p50_ms".into(), ms3(r.p50_ms));
        o.insert("p99_ms".into(), ms3(r.p99_ms));
        o.insert("threads".into(), num(r.threads as f64));
        o.insert("batches".into(), num(r.batches as f64));
        o.insert("served".into(), num(r.served as f64));
        o.insert("dropped".into(), num(r.dropped as f64));
        // rejected = balancer + closed-queue refusals; anything non-zero
        // means the run lost work items to a shutdown race
        o.insert("rejected".into(), num(r.rejected as f64));
        o.insert("counters".into(), counters_json(&run.snapshot));
        Json::Obj(o)
    };

    let mut runs = Vec::new();
    println!(
        "{:>8} {:>8} {:>10} | {:>14} {:>9} {:>8} | {:>14} {:>9} {:>8} {:>8}",
        "n",
        "reqs",
        "instances",
        "thr_rps(thrd)",
        "p99(ms)",
        "threads",
        "thr_rps(pool)",
        "p99(ms)",
        "threads",
        "speedup"
    );
    let no_trace = graft::obs::TraceOptions::default();
    let largest = sizes.iter().copied().max().unwrap_or(0);
    let mut overhead: Option<Json> = None;
    let mut attribution: Option<Json> = None;
    for &n in &sizes {
        let total_reqs = requests_flag.unwrap_or_else(|| (4 * n).max(8000));
        let specs = random_mixed_fragments(cm, n, 0x5E4D);
        let sched =
            Scheduler::new(cm.clone(), SchedulerOptions::default());
        let (plan, _) = sched.plan(&specs);
        let rt = serve_synthetic_run(
            cm, &plan, ExecutorMode::Threads, total_reqs, None, no_trace,
        );
        let rp = serve_synthetic_run(
            cm, &plan, ExecutorMode::Pool, total_reqs, None, no_trace,
        );
        if rt.point.requests < total_reqs || rp.point.requests < total_reqs {
            bail!(
                "lost responses at n={n}: threads {}/{total_reqs}, pool {}/{total_reqs}",
                rt.point.requests,
                rp.point.requests
            );
        }
        let speedup =
            rp.point.throughput_rps / rt.point.throughput_rps.max(1e-9);
        println!(
            "{:>8} {:>8} {:>10} | {:>14} {:>9} {:>8} | {:>14} {:>9} {:>8} {:>8}",
            n,
            total_reqs,
            rt.point.instances,
            format!("{:.0}", rt.point.throughput_rps),
            format!("{:.2}", rt.point.p99_ms),
            rt.point.threads,
            format!("{:.0}", rp.point.throughput_rps),
            format!("{:.2}", rp.point.p99_ms),
            rp.point.threads,
            format!("{speedup:.2}x"),
        );
        let mut row = BTreeMap::new();
        row.insert("n_clients".into(), num(n as f64));
        row.insert("requests".into(), num(total_reqs as f64));
        row.insert("instances".into(), num(rt.point.instances as f64));
        row.insert("stages".into(), num(plan.stages().count() as f64));
        row.insert("threads".into(), point_json(&rt));
        row.insert("pool".into(), point_json(&rp));
        row.insert(
            "pool_speedup".into(),
            num((speedup * 1e3).round() / 1e3),
        );
        runs.push(Json::Obj(row));

        // at the largest size: rerun the pool leg with sampled tracing
        // on and self-check that tracing stays out of the hot path
        if n == largest {
            let traced = serve_synthetic_run(
                cm,
                &plan,
                ExecutorMode::Pool,
                total_reqs,
                None,
                graft::obs::TraceOptions { sample_every: 8 },
            );
            let (off, on) = (rp.point.p99_ms, traced.point.p99_ms);
            // 5% relative + 0.5 ms absolute slack: sub-ms p99s jitter
            // more than 5% between identical runs
            let ok = !on.is_finite() || on <= off * 1.05 + 0.5;
            if !ok {
                bail!(
                    "tracing overhead self-check failed at n={n}: \
                     p99 {off:.3} ms off -> {on:.3} ms on (sample_every=8)"
                );
            }
            println!(
                "tracing overhead @ n={n}: p99 {off:.2} ms off -> {on:.2} ms \
                 on (sample_every=8), traced {} requests",
                counter_value(&traced.snapshot, "graft_trace_requests_total")
                    .unwrap_or(0),
            );
            let mut o = BTreeMap::new();
            o.insert("n_clients".into(), num(n as f64));
            o.insert("sample_every".into(), num(8.0));
            o.insert("p99_ms_trace_off".into(), ms3(off));
            o.insert("p99_ms_trace_on".into(), ms3(on));
            o.insert("trace_overhead_ok".into(), Json::Bool(ok));
            overhead = Some(Json::Obj(o));
            attribution = traced.attribution.as_ref().map(|a| a.to_json());
        }
    }

    let mut config = BTreeMap::new();
    config.insert("time_scale".into(), num(0.0));
    config.insert("drop_on_slo".into(), Json::Bool(false));
    config.insert("producers".into(), num(4.0));
    config.insert(
        "num_cpus".into(),
        num(std::thread::available_parallelism()
            .map(|c| c.get())
            .unwrap_or(4) as f64),
    );
    let mut doc = BTreeMap::new();
    doc.insert("bench".into(), Json::Str("serving".into()));
    // v3: registry-snapshot counter dumps, SLO-budget attribution and
    // the tracing-overhead self-check (observability PR);
    // v2: per-mode rejected counters (live-reconfig PR)
    doc.insert("schema_version".into(), num(3.0));
    doc.insert("config".into(), Json::Obj(config));
    doc.insert("runs".into(), Json::Arr(runs));
    if let Some(o) = overhead {
        doc.insert("trace_overhead".into(), o);
    }
    if let Some(a) = attribution {
        doc.insert("attribution".into(), a);
    }
    let json = Json::Obj(doc);
    if let Some(dir) = out.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(&out, format!("{json}\n"))
        .with_context(|| format!("writing {}", out.display()))?;
    println!("\nwrote {}", out.display());
    Ok(())
}

/// `graft bench-placement`: plan mixed-model fleets with the
/// placement-integrated scheduler and compare against the post-hoc FFD
/// oracle (`sim::cluster::pack` over the feedback-free plan), emitting
/// `BENCH_placement.json` with packed-GPU counts and fragmentation.
/// Self-checking: the run aborts if the integrated placement ever uses
/// more GPUs than the oracle or violates a per-GPU cap.
fn cmd_bench_placement(args: &Args) -> Result<()> {
    use graft::coordinator::placement::{stamped_usage, PlacementOptions};
    use graft::experiments::common::random_mixed_fragments;
    use graft::sim::pack;
    use graft::util::Json;
    use std::collections::BTreeMap;
    use std::time::Instant;

    let sizes: Vec<usize> = args
        .flags
        .get("sizes")
        .map(String::as_str)
        .unwrap_or("1000,5000,10000")
        .split(',')
        .map(|s| s.trim().parse().context("parsing --sizes"))
        .collect::<Result<_>>()?;
    let out = PathBuf::from(
        args.flags
            .get("out")
            .cloned()
            .unwrap_or_else(|| "BENCH_placement.json".into()),
    );

    let num = Json::Num;
    let ms3 = |v: f64| Json::Num((v * 1e3).round() / 1e3);
    let mut runs = Vec::new();
    println!(
        "{:>8} {:>10} {:>10} {:>10} {:>10} {:>8} {:>10} {:>12}",
        "n",
        "share_lb",
        "gpus_ffd",
        "gpus_int",
        "frag_int",
        "rounds",
        "place_ms",
        "total_share"
    );
    for &n in &sizes {
        let cm = CostModel::new(Config::embedded());
        let g = cm.config().gpu.clone();
        let specs = random_mixed_fragments(&cm, n, 0x9A7E);

        // oracle: feedback-free plan, FFD-packed after the fact
        let base = Scheduler::new(
            cm.clone(),
            SchedulerOptions {
                placement: PlacementOptions {
                    enabled: false,
                    ..Default::default()
                },
                ..Default::default()
            },
        );
        let (plan0, _) = base.plan(&specs);
        // `None` = the feedback-free plan is unpackable (an instance no
        // single GPU can host) — exactly the case the integrated
        // planner's feedback loop exists to rescue, so it skips the
        // ≤-oracle check rather than failing the bench
        let oracle = pack(&cm, &plan0, None);

        // integrated: placement + feedback inside Scheduler::plan
        let sched = Scheduler::new(cm.clone(), SchedulerOptions::default());
        let t0 = Instant::now();
        let (plan, stats) = sched.plan(&specs);
        let plan_ms = t0.elapsed().as_secs_f64() * 1e3;
        let gpus_int = plan
            .placed_gpus()
            .context("integrated planner left the plan unstamped")?;
        if let Some(o) = &oracle {
            if gpus_int > o.gpus {
                bail!(
                    "integrated placement regressed at n={n}: {gpus_int} \
                     GPUs vs post-hoc FFD {}",
                    o.gpus
                );
            }
        }
        let usage = stamped_usage(&cm, &plan)
            .context("stamped usage unavailable")?;
        // small epsilon: stamped_usage re-sums per-GPU memory in stage
        // order, not the FFD order place() validated, so a cap-exact
        // GPU can drift a few ULPs
        for (i, u) in usage.iter().enumerate() {
            if u.share > g.max_share || u.mem_mb > g.gpu_mem_mb + 1e-6 {
                bail!(
                    "cap violation at n={n} gpu={i}: share {} mem {:.0}",
                    u.share,
                    u.mem_mb
                );
            }
        }

        let lb = plan0.gpus_share_lower_bound(g.max_share);
        println!(
            "{:>8} {:>10} {:>10} {:>10} {:>10} {:>8} {:>10} {:>12}",
            n,
            lb,
            oracle
                .as_ref()
                .map_or("nan".to_string(), |o| o.gpus.to_string()),
            gpus_int,
            format!("{:.3}", stats.fragmentation),
            stats.placement_rounds,
            format!("{:.1}", stats.placement_ms),
            plan.total_share(),
        );
        // -1 marks an unpackable oracle in the JSON (same convention as
        // bench-serving's non-finite latencies)
        let mut row = BTreeMap::new();
        row.insert("n_clients".into(), num(n as f64));
        row.insert("share_lb_gpus".into(), num(lb as f64));
        row.insert(
            "gpus_ffd".into(),
            num(oracle.as_ref().map_or(-1.0, |o| o.gpus as f64)),
        );
        row.insert("gpus_integrated".into(), num(gpus_int as f64));
        row.insert(
            "fragmentation_ffd".into(),
            num(oracle.as_ref().map_or(-1.0, |o| {
                (o.fragmentation(g.max_share) * 1e4).round() / 1e4
            })),
        );
        row.insert(
            "fragmentation_integrated".into(),
            num((stats.fragmentation * 1e4).round() / 1e4),
        );
        row.insert(
            "feedback_rounds".into(),
            num(stats.placement_rounds as f64),
        );
        row.insert("placement_ms".into(), ms3(stats.placement_ms));
        row.insert("plan_ms".into(), ms3(plan_ms));
        row.insert(
            "total_share_ffd".into(),
            num(plan0.total_share() as f64),
        );
        row.insert(
            "total_share_integrated".into(),
            num(plan.total_share() as f64),
        );
        runs.push(Json::Obj(row));
    }

    let defaults = PlacementOptions::default();
    let cfg = Config::embedded();
    let mut config = BTreeMap::new();
    config.insert("frag_threshold".into(), Json::Num(defaults.frag_threshold));
    config.insert("max_rounds".into(), num(defaults.max_rounds as f64));
    config.insert("share_slack".into(), Json::Num(defaults.share_slack));
    config.insert("max_share".into(), num(cfg.gpu.max_share as f64));
    config.insert("gpu_mem_mb".into(), Json::Num(cfg.gpu.gpu_mem_mb));
    let mut doc = BTreeMap::new();
    doc.insert("bench".into(), Json::Str("placement".into()));
    doc.insert("schema_version".into(), num(1.0));
    doc.insert("config".into(), Json::Obj(config));
    doc.insert("runs".into(), Json::Arr(runs));
    let json = Json::Obj(doc);
    if let Some(dir) = out.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(&out, format!("{json}\n"))
        .with_context(|| format!("writing {}", out.display()))?;
    println!("\nwrote {}", out.display());
    Ok(())
}

/// `graft bench-transition`: measure live reconfiguration — serve a
/// planned fleet with the pooled executor, perturb k ∈ {1, 5, 20}% of
/// the clients' demand rates, re-plan incrementally, delta-place
/// against the deployed plan and hot-swap under live traffic — and
/// emit `BENCH_transition.json` (swap latency split into prepare /
/// switch / drain, migrated-instance counts delta-vs-full-repack,
/// requests dropped).
///
/// Self-checking, the run aborts unless:
///   * every submitted request got exactly one response and nothing
///     was dropped or rejected across the swap (zero-drop transition);
///   * per k, delta re-placement migrates no more instances than the
///     full-repack oracle and packs onto no more GPUs;
///   * per size, delta re-placement migrates *strictly fewer*
///     instances than the repack summed over k ∈ {1, 5, 20}% (per-k
///     strictness can degenerate at smoke sizes when FFD happens to
///     leave every kept instance in place, so strictness is enforced
///     on the aggregate).
fn cmd_bench_transition(args: &Args) -> Result<()> {
    use graft::experiments::scale::transition_scenario;
    use graft::util::Json;
    use std::collections::BTreeMap;

    let sizes: Vec<usize> = args
        .flags
        .get("sizes")
        .map(String::as_str)
        .unwrap_or("1000,5000,10000")
        .split(',')
        .map(|s| s.trim().parse().context("parsing --sizes"))
        .collect::<Result<_>>()?;
    let requests_flag: Option<usize> = args
        .flags
        .get("requests")
        .map(|s| s.parse())
        .transpose()
        .context("parsing --requests")?;
    let out = PathBuf::from(
        args.flags
            .get("out")
            .cloned()
            .unwrap_or_else(|| "BENCH_transition.json".into()),
    );

    let num = Json::Num;
    let ms3 = |v: f64| Json::Num((v * 1e3).round() / 1e3);
    let mut rows = Vec::new();
    println!(
        "{:>8} {:>5} {:>10} {:>9} {:>9} {:>9} {:>8} {:>10} {:>11} {:>9} {:>9}",
        "n",
        "k%",
        "responses",
        "swap_ms",
        "drain_ms",
        "kept",
        "restart",
        "migr_delta",
        "migr_repack",
        "gpus_dlt",
        "gpus_rpk"
    );
    for &n in &sizes {
        let total_reqs = requests_flag.unwrap_or_else(|| (2 * n).max(4000));
        let (mut agg_delta, mut agg_repack) = (0usize, 0usize);
        for &pct in &[1usize, 5, 20] {
            let r = transition_scenario(n, pct, total_reqs, 0x7245);
            if !r.plan_changed {
                bail!("perturbing {pct}% at n={n} left the plan unchanged");
            }
            if r.responses != r.requests {
                bail!(
                    "live swap lost responses at n={n} k={pct}%: \
                     {}/{}",
                    r.responses,
                    r.requests
                );
            }
            if r.dropped != 0 || r.rejected != 0 {
                bail!(
                    "live swap dropped work at n={n} k={pct}%: dropped {} \
                     rejected {}",
                    r.dropped,
                    r.rejected
                );
            }
            if r.migrated_delta > r.migrated_repack {
                bail!(
                    "delta re-placement migrated more than the repack at \
                     n={n} k={pct}%: {} vs {}",
                    r.migrated_delta,
                    r.migrated_repack
                );
            }
            if r.gpus_delta > r.gpus_repack {
                bail!(
                    "delta re-placement used more GPUs than the repack at \
                     n={n} k={pct}%: {} vs {}",
                    r.gpus_delta,
                    r.gpus_repack
                );
            }
            agg_delta += r.migrated_delta;
            agg_repack += r.migrated_repack;
            println!(
                "{:>8} {:>5} {:>10} {:>9} {:>9} {:>9} {:>8} {:>10} {:>11} {:>9} {:>9}",
                n,
                pct,
                format!("{}/{}", r.responses, r.requests),
                format!("{:.2}", r.swap_ms),
                format!("{:.2}", r.drain_ms),
                r.kept_instances,
                r.restarted_instances,
                r.migrated_delta,
                r.migrated_repack,
                r.gpus_delta,
                r.gpus_repack,
            );
            let mut row = BTreeMap::new();
            row.insert("n_clients".into(), num(r.n_clients as f64));
            row.insert("perturb_pct".into(), num(r.perturb_pct as f64));
            row.insert("requests".into(), num(r.requests as f64));
            row.insert("responses".into(), num(r.responses as f64));
            row.insert("dropped".into(), num(r.dropped as f64));
            row.insert("rejected".into(), num(r.rejected as f64));
            row.insert("swap_ms".into(), ms3(r.swap_ms));
            row.insert("prepare_ms".into(), ms3(r.prepare_ms));
            row.insert("switch_ms".into(), ms3(r.switch_ms));
            row.insert("drain_ms".into(), ms3(r.drain_ms));
            row.insert(
                "kept_instances".into(),
                num(r.kept_instances as f64),
            );
            row.insert(
                "restarted_instances".into(),
                num(r.restarted_instances as f64),
            );
            row.insert(
                "migrated_delta".into(),
                num(r.migrated_delta as f64),
            );
            row.insert(
                "migrated_repack".into(),
                num(r.migrated_repack as f64),
            );
            row.insert("gpus_delta".into(), num(r.gpus_delta as f64));
            row.insert("gpus_repack".into(), num(r.gpus_repack as f64));
            row.insert("fell_back".into(), Json::Bool(r.fell_back));
            rows.push(Json::Obj(row));
        }
        if agg_delta >= agg_repack {
            bail!(
                "delta re-placement failed to beat the full repack at n={n}: \
                 {agg_delta} vs {agg_repack} migrations over k∈{{1,5,20}}%"
            );
        }
    }

    let mut config = BTreeMap::new();
    config.insert("time_scale".into(), num(0.0));
    config.insert("drop_on_slo".into(), Json::Bool(false));
    config.insert("producers".into(), num(2.0));
    config.insert("perturb_rate_factor".into(), Json::Num(1.5));
    let mut doc = BTreeMap::new();
    doc.insert("bench".into(), Json::Str("transition".into()));
    doc.insert("schema_version".into(), num(1.0));
    doc.insert("config".into(), Json::Obj(config));
    doc.insert("transition".into(), Json::Arr(rows));
    let json = Json::Obj(doc);
    if let Some(dir) = out.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(&out, format!("{json}\n"))
        .with_context(|| format!("writing {}", out.display()))?;
    println!("\nwrote {}", out.display());
    Ok(())
}

/// `graft bench-faults`: failure-recovery bench — serve a planned
/// fleet with the pooled executor, fail one live GPU a third of the
/// way through the load (every co-located instance dies, its shards
/// close and reroute), let the replan controller detect it and
/// emergency-replan with the dead GPU excluded from placement, and
/// emit `BENCH_faults.json` (recovery latency, degraded-window drops,
/// request accounting).
///
/// Self-checking, the run aborts unless:
///   * the controller detected the failure and emergency-replanned;
///   * the failure actually killed instances (the victim GPU is drawn
///     from the deployed plan's stamps, so it always hosts some);
///   * every submitted request — including every request accepted
///     before the fault — got exactly one response (a result or an
///     explicit drop notice): nothing is ever silently lost;
///   * the emergency plan placed zero instances on the failed GPU.
///
/// Schema v2 adds the predictive-vs-reactive comparison (`predictive`
/// array + `predictive_ok`): the same seeded failure story runs twice —
/// once purely reactive, once with health-score-driven proactive
/// migration — and the run aborts unless the predictive leg strictly
/// reduces degraded-window drops at the largest size, vacated the
/// victim before death, and neither leg silently lost a response.
fn cmd_bench_faults(args: &Args) -> Result<()> {
    use graft::experiments::scale::{fault_compare_scenario, fault_scenario};
    use graft::util::Json;
    use std::collections::BTreeMap;

    let sizes: Vec<usize> = args
        .flags
        .get("sizes")
        .map(String::as_str)
        .unwrap_or("1000,5000,10000")
        .split(',')
        .map(|s| s.trim().parse().context("parsing --sizes"))
        .collect::<Result<_>>()?;
    let requests_flag: Option<usize> = args
        .flags
        .get("requests")
        .map(|s| s.parse())
        .transpose()
        .context("parsing --requests")?;
    let out = PathBuf::from(
        args.flags
            .get("out")
            .cloned()
            .unwrap_or_else(|| "BENCH_faults.json".into()),
    );

    let num = Json::Num;
    let ms3 = |v: f64| Json::Num((v * 1e3).round() / 1e3);
    let mut rows = Vec::new();
    println!(
        "{:>8} {:>10} {:>8} {:>7} {:>11} {:>9} {:>9} {:>9} {:>9} {:>9}",
        "n",
        "responses",
        "killed",
        "gpu",
        "recovery_ms",
        "swap_ms",
        "drain_ms",
        "degraded",
        "dropped",
        "rejected"
    );
    for &n in &sizes {
        let total_reqs = requests_flag.unwrap_or_else(|| (2 * n).max(4000));
        let r = fault_scenario(n, total_reqs, 0xFA17 + n as u64);
        if !r.emergency_fired {
            bail!(
                "controller missed the GPU failure at n={n}: no emergency \
                 replan fired"
            );
        }
        if r.killed_instances == 0 {
            bail!(
                "injected failure of GPU {} at n={n} killed no instances",
                r.failed_gpu
            );
        }
        if r.responses != r.requests {
            bail!(
                "failure run lost responses at n={n}: {}/{} — a request \
                 (accepted before or after the fault) vanished without a \
                 drop notice",
                r.responses,
                r.requests
            );
        }
        if r.new_plan_on_failed_gpu != 0 {
            bail!(
                "emergency replan placed {} instance(s) back on failed \
                 GPU {} at n={n}",
                r.new_plan_on_failed_gpu,
                r.failed_gpu
            );
        }
        println!(
            "{:>8} {:>10} {:>8} {:>7} {:>11} {:>9} {:>9} {:>9} {:>9} {:>9}",
            n,
            format!("{}/{}", r.responses, r.requests),
            r.killed_instances,
            r.failed_gpu,
            format!("{:.2}", r.recovery_ms),
            format!("{:.2}", r.swap_ms),
            format!("{:.2}", r.drain_ms),
            r.degraded_drops,
            r.dropped,
            r.rejected,
        );
        let mut row = BTreeMap::new();
        row.insert("n_clients".into(), num(r.n_clients as f64));
        row.insert("requests".into(), num(r.requests as f64));
        row.insert("responses".into(), num(r.responses as f64));
        row.insert(
            "pre_fault_submitted".into(),
            num(r.pre_fault_submitted as f64),
        );
        row.insert("failed_gpu".into(), num(r.failed_gpu as f64));
        row.insert(
            "killed_instances".into(),
            num(r.killed_instances as f64),
        );
        row.insert("dropped".into(), num(r.dropped as f64));
        row.insert("rejected".into(), num(r.rejected as f64));
        row.insert(
            "degraded_drops".into(),
            num(r.degraded_drops as f64),
        );
        row.insert("recovery_ms".into(), ms3(r.recovery_ms));
        row.insert("swap_ms".into(), ms3(r.swap_ms));
        row.insert("drain_ms".into(), ms3(r.drain_ms));
        row.insert(
            "new_plan_on_failed_gpu".into(),
            num(r.new_plan_on_failed_gpu as f64),
        );
        rows.push(Json::Obj(row));
    }

    // predictive-vs-reactive comparison: same seeded story, the only
    // difference is whether health warnings feed proactive migration
    let leg_json = |l: &graft::experiments::scale::FaultLegStats| {
        let mut o = BTreeMap::new();
        o.insert("requests".into(), num(l.requests as f64));
        o.insert("responses".into(), num(l.responses as f64));
        o.insert(
            "degraded_window_drops".into(),
            num(l.degraded_window_drops as f64),
        );
        o.insert("killed_at_death".into(), num(l.killed_at_death as f64));
        o.insert("emergency_fired".into(), Json::Bool(l.emergency_fired));
        o.insert("proactive_fired".into(), Json::Bool(l.proactive_fired));
        o.insert(
            "migrated_before_death".into(),
            num(l.migrated_before_death as f64),
        );
        o.insert(
            "new_plan_on_failed_gpu".into(),
            num(l.new_plan_on_failed_gpu as f64),
        );
        o.insert("dropped".into(), num(l.dropped as f64));
        o.insert("rejected".into(), num(l.rejected as f64));
        Json::Obj(o)
    };
    let strict_n = sizes.iter().copied().max().unwrap_or(0);
    let mut predictive_rows = Vec::new();
    let mut predictive_ok = true;
    println!(
        "\n{:>8} {:>7} {:>16} {:>16} {:>12} {:>12} {:>6}",
        "n",
        "victim",
        "reactive_drops",
        "predictive_drops",
        "killed_react",
        "killed_pred",
        "ok"
    );
    for &n in &sizes {
        let total_reqs = requests_flag.unwrap_or_else(|| (2 * n).max(4000));
        let c = fault_compare_scenario(n, total_reqs, 0x9E1F + n as u64);
        for (leg, l) in
            [("reactive", &c.reactive), ("predictive", &c.predictive)]
        {
            if l.responses != l.requests {
                bail!(
                    "{leg} leg lost responses at n={n}: {}/{} — a request \
                     vanished without a drop notice",
                    l.responses,
                    l.requests
                );
            }
        }
        let ok = c.predictive_ok();
        println!(
            "{:>8} {:>7} {:>16} {:>16} {:>12} {:>12} {:>6}",
            n,
            c.victim_gpu,
            c.reactive.degraded_window_drops,
            c.predictive.degraded_window_drops,
            c.reactive.killed_at_death,
            c.predictive.killed_at_death,
            ok,
        );
        if n == strict_n && !ok {
            bail!(
                "predictive leg failed to strictly beat the reactive one \
                 at n={n}: reactive degraded-window drops {} (killed {}), \
                 predictive {} (killed {}, proactive_fired={})",
                c.reactive.degraded_window_drops,
                c.reactive.killed_at_death,
                c.predictive.degraded_window_drops,
                c.predictive.killed_at_death,
                c.predictive.proactive_fired,
            );
        }
        // the gate is the largest size; smaller sizes are advisory
        // (tiny runs can see zero reactive drops, making strict
        // reduction meaningless there)
        if n == strict_n {
            predictive_ok &= ok;
        }
        let mut row = BTreeMap::new();
        row.insert("n_clients".into(), num(c.n_clients as f64));
        row.insert("victim_gpu".into(), num(c.victim_gpu as f64));
        row.insert("burst".into(), num(c.burst as f64));
        row.insert("reactive".into(), leg_json(&c.reactive));
        row.insert("predictive".into(), leg_json(&c.predictive));
        row.insert("predictive_ok".into(), Json::Bool(ok));
        predictive_rows.push(Json::Obj(row));
    }

    let mut config = BTreeMap::new();
    config.insert("time_scale".into(), num(0.0));
    config.insert("drop_on_slo".into(), Json::Bool(false));
    config.insert("producers".into(), num(2.0));
    config.insert("fault".into(), Json::Str("single_gpu_failure".into()));
    config.insert("fail_at_fraction".into(), Json::Num(1.0 / 3.0));
    config.insert("suspect_threshold".into(), num(0.6));
    let mut doc = BTreeMap::new();
    doc.insert("bench".into(), Json::Str("faults".into()));
    doc.insert("schema_version".into(), num(2.0));
    doc.insert("config".into(), Json::Obj(config));
    doc.insert("faults".into(), Json::Arr(rows));
    doc.insert("predictive".into(), Json::Arr(predictive_rows));
    doc.insert("predictive_ok".into(), Json::Bool(predictive_ok));
    let json = Json::Obj(doc);
    if let Some(dir) = out.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(&out, format!("{json}\n"))
        .with_context(|| format!("writing {}", out.display()))?;
    println!("\nwrote {}", out.display());
    Ok(())
}

fn cmd_serve(cm: &CostModel, args: &Args) -> Result<()> {
    let model = args.flags.get("model").map(String::as_str).unwrap_or("vgg");
    let n_clients: usize = args
        .flags
        .get("clients")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(4);
    let duration: f64 = args
        .flags
        .get("duration")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(10.0);
    let addr =
        args.flags.get("addr").cloned().unwrap_or("127.0.0.1:0".to_string());
    // every replan the controller runs (--reconfigure) plans on the
    // sharded path; >1 parallelises per-model shards, identical plans
    let planner_threads: usize = args
        .flags
        .get("planner-threads")
        .map(|s| s.parse())
        .transpose()
        .context("parsing --planner-threads")?
        .unwrap_or(1);
    // observability: --metrics-addr serves /metrics (+ .json) off the
    // unified registry; --trace-sample N traces every Nth request
    let metrics_addr = args.flags.get("metrics-addr").cloned();
    let trace_sample: u32 = args
        .flags
        .get("trace-sample")
        .map(|s| s.parse())
        .transpose()
        .context("parsing --trace-sample")?
        .unwrap_or(0);

    let mi = cm.model_index(model).context("unknown model")?;
    let engine = Arc::new(
        Engine::new(&default_artifacts_dir())
            .context("loading artifacts (run `make artifacts`)")?,
    );
    // plan from a snapshot restricted to compiled partition points
    let points = cm.config().models[mi].points();
    let clients: Vec<_> = experiments::common::fleet(
        cm,
        mi,
        experiments::common::Scale::SmallHomo,
        cm.config().slo_ratio_default,
        7,
    )
    .into_iter()
    .take(n_clients)
    .map(|c| c.with_candidates(points[..points.len() - 1].to_vec()))
    .collect();
    let specs = experiments::common::snapshot(cm, &clients, 0.0);
    let sched = Scheduler::new(
        cm.clone(),
        SchedulerOptions {
            repartition: RepartitionOptions {
                point_set: Some(points),
                constraints: AllocConstraints::default(),
                ..Default::default()
            },
            planner_threads,
            ..Default::default()
        },
    );
    let (plan, _) = sched.plan(&specs);
    println!(
        "serving {} clients of {model}: {} sets, {}% total share",
        specs.len(),
        plan.sets.len(),
        plan.total_share()
    );
    // the data path is always fronted by the live server; --reconfigure
    // additionally runs the replan controller, which watches observed
    // per-model arrival rates and hot-swaps the plan on drift without
    // dropping in-flight requests
    let reconfigure = args.flags.contains_key("reconfigure");
    let live = Arc::new(LiveServer::start(
        engine,
        cm,
        &plan,
        ServerOptions {
            trace: TraceOptions { sample_every: trace_sample },
            ..Default::default()
        },
    ));
    let front = TcpFront::start(&addr, live.clone())?;
    println!(
        "listening on {} for {duration}s{}",
        front.addr,
        if reconfigure { " (live reconfiguration on)" } else { "" }
    );
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let (ctrl, watcher) = if reconfigure {
        let sched = Arc::new(sched);
        let ctrl = Arc::new(ReplanController::new(
            sched,
            live.clone(),
            specs,
            ControllerOptions::default(),
        ));
        let watcher = ctrl.clone().run(stop.clone());
        (Some(ctrl), Some(watcher))
    } else {
        (None, None)
    };
    // unified metrics registry: the live front (current + retired
    // cores, swap counter) and the controller's avoid-sets register
    // here; the heartbeat line, the final summary and the /metrics
    // endpoint all render from the same snapshots
    let registry = Arc::new(MetricsRegistry::new());
    {
        let live = live.clone();
        registry.register("serving", move |out| live.collect_metrics(out));
    }
    if let Some(c) = &ctrl {
        let c = c.clone();
        registry.register("controller", move |out| {
            out.push(Metric::gauge(
                "graft_health_suspect_gpus",
                c.suspect_gpus().len() as f64,
            ));
            out.push(Metric::gauge(
                "graft_controller_dead_gpus",
                c.dead_gpus().len() as f64,
            ));
        });
    }
    let metrics_srv = match &metrics_addr {
        Some(a) => {
            let srv = MetricsServer::start(a, registry.clone())?;
            println!("metrics on http://{}/metrics (+ /metrics.json)", srv.addr());
            Some(srv)
        }
        None => None,
    };
    // periodic operator heartbeat, rendered from the registry snapshot
    let deadline = std::time::Instant::now()
        + std::time::Duration::from_secs_f64(duration);
    loop {
        let now = std::time::Instant::now();
        if now >= deadline {
            break;
        }
        std::thread::sleep(std::time::Duration::from_secs(2).min(deadline - now));
        println!("[serve] {}", render_stats_line(&registry.snapshot()));
    }
    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    if let Some(w) = watcher {
        let _ = w.join();
    }
    drop(ctrl);
    front.stop();
    println!("{}", render_stats_line(&registry.snapshot()));
    if trace_sample > 0 {
        let att = graft::obs::BudgetAttribution::from_obs(
            cm,
            &live.plan(),
            &live.server().obs(),
            live.server().time_scale(),
        );
        print!("{}", att.render_text());
    }
    if let Some(srv) = metrics_srv {
        srv.stop();
    }
    registry.unregister("serving");
    registry.unregister("controller");
    if let Ok(l) = Arc::try_unwrap(live) {
        l.shutdown();
    }
    Ok(())
}

/// `graft obs-report`: the observability round-trip without artifacts.
/// Default mode drives a synthetic traced serving run (mock executor),
/// prints the SLO-budget attribution and the metrics exposition, and —
/// with `--metrics-addr` — serves the run's snapshot over HTTP for
/// `--serve-for` seconds (the CI smoke curls it).  `--addr` instead
/// scrapes a live `graft serve --metrics-addr` endpoint.
fn cmd_obs_report(cm: &CostModel, args: &Args) -> Result<()> {
    use graft::experiments::common::random_mixed_fragments;
    use graft::obs::{prometheus_text, scrape, snapshot_json, TraceOptions};
    use graft::serving::ExecutorMode;

    let format =
        args.flags.get("format").map(String::as_str).unwrap_or("prom");
    if let Some(addr) = args.flags.get("addr") {
        // scrape mode: print a running endpoint's exposition verbatim
        let path =
            if format == "json" { "/metrics.json" } else { "/metrics" };
        print!("{}", scrape(addr, path)?);
        return Ok(());
    }

    let n: usize = args
        .flags
        .get("clients")
        .map(|s| s.parse())
        .transpose()
        .context("parsing --clients")?
        .unwrap_or(64);
    let requests: usize = args
        .flags
        .get("requests")
        .map(|s| s.parse())
        .transpose()
        .context("parsing --requests")?
        .unwrap_or(4000);
    let sample: u32 = args
        .flags
        .get("trace-sample")
        .map(|s| s.parse())
        .transpose()
        .context("parsing --trace-sample")?
        .unwrap_or(1);

    let specs = random_mixed_fragments(cm, n, 0x0B5);
    let sched = Scheduler::new(cm.clone(), SchedulerOptions::default());
    let (plan, stats) = sched.plan(&specs);
    let run = graft::experiments::scale::serve_synthetic_run(
        cm,
        &plan,
        ExecutorMode::Pool,
        requests,
        None,
        TraceOptions { sample_every: sample },
    );
    if let Some(att) = &run.attribution {
        print!("{}", att.render_text());
    }
    // the run's registry snapshot plus the planner's gauges, under the
    // same namespace the live endpoint serves
    let mut snap = run.snapshot.clone();
    stats.collect_metrics(&mut snap);
    snap.sort_by(|a, b| a.name.cmp(&b.name));
    let text = match format {
        "json" => format!("{}\n", snapshot_json(&snap)),
        _ => prometheus_text(&snap),
    };
    match args.flags.get("out") {
        Some(out) => {
            std::fs::write(out, &text)
                .with_context(|| format!("writing {out}"))?;
            println!("wrote {out}");
        }
        None => print!("{text}"),
    }
    if let Some(maddr) = args.flags.get("metrics-addr") {
        let secs: f64 = args
            .flags
            .get("serve-for")
            .map(|s| s.parse())
            .transpose()
            .context("parsing --serve-for")?
            .unwrap_or(5.0);
        let registry = Arc::new(MetricsRegistry::new());
        let frozen = snap.clone();
        registry
            .register("report", move |out| out.extend(frozen.iter().cloned()));
        let srv = MetricsServer::start(maddr, registry)?;
        println!(
            "metrics on http://{}/metrics (+ /metrics.json) for {secs}s",
            srv.addr()
        );
        std::thread::sleep(std::time::Duration::from_secs_f64(secs));
        srv.stop();
    }
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<()> {
    let seed: u64 = args
        .flags
        .get("seed")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(7);
    let len: usize = args
        .flags
        .get("len")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(60);
    let trace = BandwidthTrace::generate(
        seed,
        &TraceParams { len_s: len, ..Default::default() },
    );
    println!("t_s,mbps");
    for (i, b) in trace.mbps.iter().enumerate() {
        println!("{i},{b:.1}");
    }
    Ok(())
}
