//! L3 coordinator — the paper's contribution: the Graft scheduler
//! (merging §4.1, grouping §4.2, re-partitioning §4.3 / Algorithm 1),
//! the execution-plan types, and the baselines it is evaluated against.

pub mod baselines;
pub mod controller;
pub mod fragment;
pub mod grouping;
pub mod merging;
pub mod optimal;
pub mod placement;
pub mod plan;
pub mod repartition;
pub mod reuse;
pub mod scheduler;

pub use controller::{ControllerOptions, ReplanController, TickOutcome};
pub use fragment::{ClientId, FragmentSpec};
pub use placement::{
    place, place_constrained, place_delta, place_delta_constrained,
    DeltaPlacement, GpuUsage, Placement, PlacementConstraints,
    PlacementOptions,
};
pub use plan::{ExecutionPlan, MemberPlan, RealignedSet, StagePlan};
pub use scheduler::{ScheduleStats, Scheduler, SchedulerOptions};
