//! §4.3 — fragment re-partitioning (Algorithm 1).
//!
//! For a group of same-model fragments `⟨p_i, t_i, q_i⟩`, scan every
//! candidate re-partition point `p`: the fragments with `p_i ≤ p` form
//! `F_A` and are re-aligned — each executes an *alignment stage*
//! `[p_i..p]` on its own instances, then all share one batched *shared
//! stage* `[p..L]`; the rest (`F_B`) is re-aligned recursively.  For each
//! `p` the time-budget split between the two stages is searched on a
//! grid of `d_shared` values (the paper solves the equivalent allocation
//! LP with GUROBI; the split is one-dimensional because each member's
//! alignment budget is maximal at `t_i/2 − d_shared` — see below), with
//! the §4.3 worst-case-queueing rule `d_i + d_shared ≤ t_i / 2`.
//!
//! The recursion over `F_B` only ever visits suffixes of the fragments
//! sorted by partition point, so we implement it as a suffix DP — same
//! optimum, no recomputation.
//!
//! Two further *exact* prunings keep trigger-to-trigger replanning
//! cheap (they change time, never plans — property-tested):
//!
//! * **Warm-started DP** ([`realign_group_warm`]): the previous
//!   trigger's winning re-partition points are evaluated *first* at
//!   every DP state, seeding a near-optimal incumbent.  Choices are
//!   compared by `(cost, rank)` where the rank encodes the cold
//!   evaluation order (standalone fallback, then candidate points
//!   ascending), so evaluation order cannot change the winner — a
//!   stale or wrong hint only costs time.  Branches whose tail alone
//!   reaches the incumbent cost are skipped, and the incumbent's
//!   remaining headroom is pushed into the grid sweep as a share bound.
//! * **Adaptive d_shared grid** (`RepartitionOptions::adaptive_grid`):
//!   instead of fully costing all `d_grid` split points, a coarse
//!   subset (`coarse_grid` evenly spaced points) is costed first and
//!   every remaining point is screened by its shared-stage allocation
//!   alone — the member sweep (the expensive part) runs only for
//!   points that can still *strictly beat* the incumbent.  Skipped
//!   candidates provably cannot win or tie into the winner, so the
//!   search returns the same split as the exhaustive scan at the same
//!   `d_grid` resolution.

use std::sync::atomic::{AtomicU64, Ordering};

use super::fragment::FragmentSpec;
use super::plan::{ExecutionPlan, MemberPlan, RealignedSet, StagePlan};
use crate::profiler::{AllocConstraints, CostModel, FragmentId};

#[derive(Debug, Clone)]
pub struct RepartitionOptions {
    /// Grid resolution for the d_shared time-budget split search.
    pub d_grid: usize,
    /// First-phase samples of the adaptive d_shared search: evenly
    /// spaced over the grid (always including the full-budget point),
    /// they establish the incumbent that screens the remaining points.
    pub coarse_grid: usize,
    /// Adaptive (coarse sweep + bound-screened refinement) vs
    /// exhaustive d_shared search.  Both explore the same `d_grid`
    /// resolution and return identical sets (property-tested); the
    /// adaptive search only skips splits that provably cannot beat the
    /// incumbent.
    pub adaptive_grid: bool,
    pub constraints: AllocConstraints,
    /// Restrict candidate re-partition points (e.g. to the AOT-compiled
    /// point set on the real data path).  `None` = every layer (paper).
    pub point_set: Option<Vec<usize>>,
}

impl Default for RepartitionOptions {
    fn default() -> Self {
        Self {
            d_grid: 24,
            coarse_grid: 8,
            adaptive_grid: true,
            constraints: AllocConstraints::default(),
            point_set: None,
        }
    }
}

/// Search-effort counters of one (or many) re-partitioning passes.
/// Atomic because groups re-align on the parallel pool — and because
/// one instance is shared across all planner-shard workers of a
/// sharded trigger, each realigning its own groups concurrently; the
/// scheduler folds the totals into
/// [`crate::coordinator::ScheduleStats`].
#[derive(Debug, Default)]
pub struct RepartitionTelemetry {
    /// d_shared grid points whose member sweep ran (fully or until the
    /// cost bound aborted it).
    pub grid_points_evaluated: AtomicU64,
    /// Grid points dismissed by the shared-stage allocation alone
    /// (adaptive grid: one memoised query instead of a member sweep).
    pub grid_points_pruned: AtomicU64,
    /// DP states whose winning choice came from the previous trigger's
    /// hinted re-partition points.
    pub dp_warm_hits: AtomicU64,
}

/// Re-align one group (Algorithm 1).  Returns the realigned sets plus the
/// specs that are infeasible even standalone (dropped by the balancer).
pub fn realign_group(
    cm: &CostModel,
    specs: &[FragmentSpec],
    opts: &RepartitionOptions,
) -> ExecutionPlan {
    realign_group_warm(cm, specs, opts, None, None)
}

/// One suffix-DP state: the winning way to serve `work[i..]`.  `rank`
/// encodes the cold evaluation order (0 = standalone fallback, `1 + j`
/// = the `j`-th candidate point); choices are compared by `(cost,
/// rank)`, which reproduces the cold first-wins tie-breaking exactly
/// while making the result independent of evaluation order — the
/// property that lets warm hints go first without changing the plan.
struct Choice {
    cost: u32,
    rank: usize,
    next: usize,
    hinted: bool,
    set: RealignedSet,
}

/// Evaluate candidate point `p` (at cold-order `rank`) for DP state
/// `i`, replacing `best[i]` when it wins under `(cost, rank)`.
#[allow(clippy::too_many_arguments)]
fn consider_point(
    cm: &CostModel,
    work: &[FragmentSpec],
    opts: &RepartitionOptions,
    telemetry: Option<&RepartitionTelemetry>,
    best: &mut [Option<Choice>],
    i: usize,
    p: usize,
    rank: usize,
    from_hint: bool,
) {
    let n = work.len();
    // F_A = work[i..j] (all suffix members with p_k <= p)
    let j = i + work[i..].partition_point(|s| s.p <= p);
    if j == i {
        return;
    }
    let tc = if j == n {
        0
    } else {
        match &best[j] {
            Some(c) => c.cost,
            None => return,
        }
    };
    // a candidate costing >= the incumbent from its tail alone cannot
    // win or even tie (set share is positive) — skip the grid sweep
    if best[i].as_ref().is_some_and(|c| tc >= c.cost) {
        return;
    }
    // headroom left for the head set: share strictly above it loses;
    // share equal to it ties, which the rank comparison below resolves
    let bound = best[i].as_ref().map(|c| c.cost - tc);
    let Some(set) = realign_set(cm, &work[i..j], p, opts, bound, telemetry)
    else {
        return;
    };
    let cost = set.total_share() + tc;
    if best[i]
        .as_ref()
        .map_or(true, |c| (cost, rank) < (c.cost, c.rank))
    {
        best[i] = Some(Choice { cost, rank, next: j, hinted: from_hint, set });
    }
}

/// [`realign_group`] with cross-trigger warm-start state: `hint` is the
/// previous trigger's winning re-partition points for (approximately)
/// this group, `telemetry` collects search-effort counters.  Hints are
/// purely advisory — any hint (stale, foreign, empty) yields the same
/// plan as no hint, only faster or slower (property-tested); an
/// infeasible hinted point simply falls through to the cold sweep.
pub fn realign_group_warm(
    cm: &CostModel,
    specs: &[FragmentSpec],
    opts: &RepartitionOptions,
    hint: Option<&[usize]>,
    telemetry: Option<&RepartitionTelemetry>,
) -> ExecutionPlan {
    let mut plan = ExecutionPlan::default();
    if specs.is_empty() {
        return plan;
    }
    debug_assert!(
        specs.iter().all(|s| s.model == specs[0].model),
        "realign_group expects same-model fragments"
    );

    // Pre-filter: members infeasible even standalone can never be served.
    // Keep each feasible member's standalone set — it is the DP's
    // fallback candidate, so computing it once here avoids re-running the
    // allocation search per DP index.
    let mut pre: Vec<(FragmentSpec, RealignedSet)> = Vec::new();
    for s in specs {
        match standalone_set(cm, s, &opts.constraints) {
            Some(set) => pre.push((s.clone(), set)),
            None => plan.infeasible.push(s.clone()),
        }
    }
    if pre.is_empty() {
        return plan;
    }
    pre.sort_by(|a, b| {
        a.0.p.cmp(&b.0.p).then(a.0.budget_ms.total_cmp(&b.0.budget_ms))
    });
    let (work, standalone): (Vec<FragmentSpec>, Vec<RealignedSet>) =
        pre.into_iter().unzip();

    let layers = cm.config().models[work[0].model].layers;
    let points = candidate_points(opts, layers);
    // warm hints, intersected with the candidate set (an out-of-set
    // hint must never be evaluated — it could plant a point the cold
    // sweep would not consider) and carrying their cold-order ranks
    let hinted: Vec<(usize, usize)> = hint
        .map(|h| {
            let mut v: Vec<(usize, usize)> = h
                .iter()
                .filter_map(|p| {
                    points.binary_search(p).ok().map(|idx| (*p, idx + 1))
                })
                .collect();
            v.sort_unstable();
            v.dedup();
            v
        })
        .unwrap_or_default();

    // Suffix DP: best[i] = min-(cost, rank) realignment of work[i..].
    // Each state stores only its cost/rank, the set serving the head
    // block and the index where the tail resumes; the winning plan is
    // reconstructed once by backtracking.  (The seed kept a full
    // Vec<RealignedSet> per state, cloning O(n²) sets per group.)
    let n = work.len();
    let mut best: Vec<Option<Choice>> = (0..n).map(|_| None).collect();
    for i in (0..n).rev() {
        // Fallback: the head fragment standalone (always feasible
        // here), rank 0 — the cold order's first candidate.
        let tc_next = if i + 1 == n {
            Some(0)
        } else {
            best[i + 1].as_ref().map(|c| c.cost)
        };
        if let Some(tc) = tc_next {
            let set = standalone[i].clone();
            let cost = set.total_share() + tc;
            best[i] =
                Some(Choice { cost, rank: 0, next: i + 1, hinted: false, set });
        }
        // previous trigger's points first: they seed a near-optimal
        // incumbent so the full sweep below prunes almost everything
        for &(p, rank) in hinted.iter().filter(|&&(p, _)| p >= work[i].p) {
            consider_point(
                cm, &work, opts, telemetry, &mut best, i, p, rank, true,
            );
        }
        for (idx, &p) in points.iter().enumerate() {
            if p < work[i].p
                || hinted.binary_search_by_key(&p, |&(hp, _)| hp).is_ok()
            {
                continue;
            }
            consider_point(
                cm, &work, opts, telemetry, &mut best, i, p, idx + 1, false,
            );
        }
    }
    // Backtrack the winning chain of sets (head-first, as the seed did).
    let mut i = 0;
    let mut warm_hits = 0u64;
    while i < n {
        let c = best[i].take().expect("standalone fallback always feasible");
        if c.hinted {
            warm_hits += 1;
        }
        i = c.next;
        plan.sets.push(c.set);
    }
    if let Some(t) = telemetry {
        t.dp_warm_hits.fetch_add(warm_hits, Ordering::Relaxed);
    }
    plan
}

/// Provision one fragment standalone: point = its own p, budget t/2.
pub fn standalone_set(
    cm: &CostModel,
    spec: &FragmentSpec,
    cons: &AllocConstraints,
) -> Option<RealignedSet> {
    let layers = cm.config().models[spec.model].layers;
    let frag = FragmentId::new(spec.model, spec.p, layers);
    let budget = spec.budget_ms / 2.0;
    let alloc = cm.min_alloc(frag, budget, spec.rate_rps, *cons)?;
    Some(RealignedSet {
        model: spec.model,
        point: spec.p,
        members: vec![MemberPlan { spec: spec.clone(), align: None }],
        shared: StagePlan {
            frag,
            alloc,
            budget_ms: budget,
            demand_rps: spec.rate_rps,
            gpus: Vec::new(),
        },
    })
}

/// Best provisioning of `members` re-aligned at point `p` over the
/// d_shared grid.  Every member must have `p_i <= p`; `p < layers`.
///
/// Two passes: a costing sweep over the grid that touches only cached
/// `min_alloc` results (no spec clones, no plan construction), then one
/// materialisation of the winning split.  The seed built a full
/// `RealignedSet` — cloning every member spec — per grid point.
///
/// `bound` is the DP incumbent's remaining share headroom: a split
/// whose (partial) cost *strictly exceeds* it can neither win nor tie
/// into the DP winner, so its member sweep is cut short.  When every
/// split lands above the bound the function returns `None`, which the
/// DP treats exactly like an over-bound candidate — so bound pruning
/// never changes the chosen plan.  With `adaptive_grid`, the sweep
/// visits `coarse_grid` evenly spaced splits first and screens the
/// rest by their shared-stage allocation alone; ties remain exact
/// because the winner is the `(cost, k)` minimum regardless of visit
/// order (the exhaustive ascending scan's first-wins rule, made
/// order-free).
fn realign_set(
    cm: &CostModel,
    members: &[FragmentSpec],
    p: usize,
    opts: &RepartitionOptions,
    bound: Option<u32>,
    telemetry: Option<&RepartitionTelemetry>,
) -> Option<RealignedSet> {
    let model = members[0].model;
    let layers = cm.config().models[model].layers;
    let shared_frag = FragmentId::new(model, p, layers);
    let total_rate: f64 = members.iter().map(|m| m.rate_rps).sum();
    let t_min = members
        .iter()
        .map(|m| m.budget_ms)
        .fold(f64::INFINITY, f64::min);

    let g = opts.d_grid.max(2);
    let d_shared_at = |k: usize| t_min / 2.0 * k as f64 / g as f64;

    // Visit order: coarse samples first (adaptive), else ascending.
    let ks: Vec<usize> = if opts.adaptive_grid {
        let coarse = opts.coarse_grid.clamp(2, g);
        let mut mark = vec![false; g + 1];
        let mut order = Vec::with_capacity(g);
        for c in 1..=coarse {
            let k = (c * g).div_ceil(coarse);
            if !mark[k] {
                mark[k] = true;
                order.push(k);
            }
        }
        for k in 1..=g {
            if !mark[k] {
                order.push(k);
            }
        }
        order
    } else {
        (1..=g).collect()
    };

    // Pass 1: the cheapest feasible grid point, ties to the smallest k.
    let mut best_k: Option<(u32, usize)> = None; // (cost, k)
    let mut evaluated = 0u64;
    let mut pruned = 0u64;
    'grid: for k in ks {
        // strictly-greater abort threshold: the DP bound and the best
        // split seen so far (only the adaptive search prunes on it; the
        // exhaustive reference costs every split in full)
        let cap = if opts.adaptive_grid {
            match (bound, best_k.map(|(c, _)| c)) {
                (Some(b), Some(c)) => Some(b.min(c)),
                (Some(b), None) => Some(b),
                (None, c) => c,
            }
        } else {
            None
        };
        let d_shared = d_shared_at(k);
        let Some(shared_alloc) =
            cm.min_alloc(shared_frag, d_shared, total_rate, opts.constraints)
        else {
            continue; // too tight for the shared stage; larger k may fit
        };
        let mut cost = shared_alloc.total_share();
        if cap.is_some_and(|c| cost > c) {
            pruned += 1; // dismissed on the shared allocation alone
            continue;
        }
        evaluated += 1;
        for m in members {
            if m.p == p {
                continue;
            }
            let d_i = m.budget_ms / 2.0 - d_shared;
            let align_frag = FragmentId::new(model, m.p, p);
            match cm.min_alloc(align_frag, d_i, m.rate_rps, opts.constraints)
            {
                Some(alloc) => {
                    cost += alloc.total_share();
                    if cap.is_some_and(|c| cost > c) {
                        continue 'grid; // cannot win or tie any more
                    }
                }
                None => continue 'grid,
            }
        }
        if best_k.map_or(true, |(bc, bk)| (cost, k) < (bc, bk)) {
            best_k = Some((cost, k));
        }
    }
    if let Some(t) = telemetry {
        t.grid_points_evaluated.fetch_add(evaluated, Ordering::Relaxed);
        t.grid_points_pruned.fetch_add(pruned, Ordering::Relaxed);
    }
    let (_, k) = best_k?;

    // Pass 2: materialise the winning split (allocation queries repeat
    // the pass-1 keys, so they are cache hits).
    let d_shared = d_shared_at(k);
    let shared_alloc =
        cm.min_alloc(shared_frag, d_shared, total_rate, opts.constraints)?;
    let mut member_plans = Vec::with_capacity(members.len());
    for m in members {
        if m.p == p {
            member_plans.push(MemberPlan { spec: m.clone(), align: None });
            continue;
        }
        let d_i = m.budget_ms / 2.0 - d_shared;
        let align_frag = FragmentId::new(model, m.p, p);
        let alloc =
            cm.min_alloc(align_frag, d_i, m.rate_rps, opts.constraints)?;
        member_plans.push(MemberPlan {
            spec: m.clone(),
            align: Some(StagePlan {
                frag: align_frag,
                alloc,
                budget_ms: d_i,
                demand_rps: m.rate_rps,
                gpus: Vec::new(),
            }),
        });
    }
    Some(RealignedSet {
        model,
        point: p,
        members: member_plans,
        shared: StagePlan {
            frag: shared_frag,
            alloc: shared_alloc,
            budget_ms: d_shared,
            demand_rps: total_rate,
            gpus: Vec::new(),
        },
    })
}

/// Candidate re-partition points, sorted and deduplicated, clamped to
/// `p < layers` — a point at `layers` would leave an empty shared
/// fragment, so the DP can now scan the list as-is instead of
/// re-filtering it at every state.  Sorted order is also what lets the
/// warm-hint intersection binary-search.
fn candidate_points(opts: &RepartitionOptions, layers: usize) -> Vec<usize> {
    match &opts.point_set {
        Some(ps) => {
            let mut v: Vec<usize> =
                ps.iter().copied().filter(|&p| p < layers).collect();
            v.sort_unstable();
            v.dedup();
            v
        }
        None => (0..layers).collect(),
    }
}

/// Resource consumption without re-partitioning: every spec standalone
/// (the Fig 11 comparator).
pub fn no_realign_plan(
    cm: &CostModel,
    specs: &[FragmentSpec],
    cons: &AllocConstraints,
) -> ExecutionPlan {
    let mut plan = ExecutionPlan::default();
    for s in specs {
        match standalone_set(cm, s, cons) {
            Some(set) => plan.sets.push(set),
            None => plan.infeasible.push(s.clone()),
        }
    }
    plan
}

/// SLO-safety check used by tests/proptests: every member's end-to-end
/// server time (alignment latency + shared latency, each doubled for
/// worst-case queueing) fits its budget.
pub fn plan_is_slo_safe(plan: &ExecutionPlan) -> bool {
    plan.sets.iter().all(|set| {
        set.members.iter().all(|m| {
            let align_ms =
                m.align.as_ref().map_or(0.0, |a| a.alloc.latency_ms);
            let shared_ms = set.shared.alloc.latency_ms;
            2.0 * (align_ms + shared_ms) <= m.spec.budget_ms + 1e-6
        })
    })
}

/// Throughput-safety: every stage's allocation covers its demand.
pub fn plan_covers_demand(plan: &ExecutionPlan) -> bool {
    plan.stages()
        .all(|s| s.alloc.throughput_rps >= s.demand_rps - 1e-6)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::coordinator::fragment::ClientId;

    fn cm() -> CostModel {
        CostModel::new(Config::embedded())
    }

    fn spec(i: u32, model: usize, p: usize, t: f64, q: f64) -> FragmentSpec {
        FragmentSpec::single(ClientId(i), model, p, t, q)
    }

    fn inc_group(cm: &CostModel) -> Vec<FragmentSpec> {
        let m = cm.model_index("inc").unwrap();
        vec![
            spec(0, m, 2, 90.0, 30.0),
            spec(1, m, 3, 95.0, 30.0),
            spec(2, m, 4, 100.0, 30.0),
            spec(3, m, 4, 85.0, 30.0),
            spec(4, m, 6, 110.0, 30.0),
        ]
    }

    #[test]
    fn realign_beats_no_realign() {
        let cm = cm();
        let specs = inc_group(&cm);
        let opts = RepartitionOptions::default();
        let with = realign_group(&cm, &specs, &opts);
        let without =
            no_realign_plan(&cm, &specs, &AllocConstraints::default());
        assert!(with.infeasible.is_empty());
        assert!(
            with.total_share() <= without.total_share(),
            "realign {} > standalone {}",
            with.total_share(),
            without.total_share()
        );
    }

    #[test]
    fn plans_are_slo_safe_and_cover_demand() {
        let cm = cm();
        let specs = inc_group(&cm);
        let plan = realign_group(&cm, &specs, &RepartitionOptions::default());
        assert!(plan_is_slo_safe(&plan), "{plan:?}");
        assert!(plan_covers_demand(&plan));
        // all clients are served exactly once
        let mut clients: Vec<u32> = plan
            .sets
            .iter()
            .flat_map(|s| s.members.iter())
            .flat_map(|m| m.spec.clients.iter().map(|c| c.0))
            .collect();
        clients.sort_unstable();
        assert_eq!(clients, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn repartition_point_covers_members() {
        let cm = cm();
        let specs = inc_group(&cm);
        let plan = realign_group(&cm, &specs, &RepartitionOptions::default());
        for set in &plan.sets {
            for m in &set.members {
                assert!(m.spec.p <= set.point);
                match &m.align {
                    Some(a) => {
                        assert_eq!(a.frag.start, m.spec.p);
                        assert_eq!(a.frag.end, set.point);
                    }
                    None => assert_eq!(m.spec.p, set.point),
                }
            }
            assert_eq!(set.shared.frag.start, set.point);
        }
    }

    #[test]
    fn shared_stage_batches_aggregate_rate() {
        let cm = cm();
        let specs = inc_group(&cm);
        let plan = realign_group(&cm, &specs, &RepartitionOptions::default());
        // at least one set should aggregate several members (that's the
        // whole point of re-alignment for this homogeneous-ish group)
        assert!(
            plan.sets.iter().any(|s| s.members.len() > 1),
            "no batching across members: {plan:?}"
        );
        for set in &plan.sets {
            assert!(
                (set.shared.demand_rps - set.total_rate()).abs() < 1e-9
            );
        }
    }

    #[test]
    fn infeasible_budget_is_reported() {
        let cm = cm();
        let m = cm.model_index("vit").unwrap();
        let bad = spec(0, m, 1, 0.01, 1.0); // sub-ms budget: hopeless
        let plan = realign_group(&cm, &[bad.clone()], &RepartitionOptions::default());
        assert!(plan.sets.is_empty());
        assert_eq!(plan.infeasible, vec![bad]);
    }

    #[test]
    fn point_set_restriction_respected() {
        let cm = cm();
        let specs = inc_group(&cm);
        let opts = RepartitionOptions {
            point_set: Some(vec![4, 6, 8, 17]),
            ..Default::default()
        };
        let plan = realign_group(&cm, &specs, &opts);
        for set in &plan.sets {
            // points are either from the set or a member's own p
            // (standalone fallback)
            assert!(
                [4usize, 6, 8, 17].contains(&set.point)
                    || set.members.len() == 1
                        && set.members[0].spec.p == set.point,
                "unexpected point {}",
                set.point
            );
        }
    }

    #[test]
    fn heterogeneous_budgets_respected() {
        // one very tight member must not drag others into infeasibility
        let cm = cm();
        let m = cm.model_index("inc").unwrap();
        let specs = vec![
            spec(0, m, 2, 30.0, 30.0), // tight
            spec(1, m, 2, 140.0, 30.0),
        ];
        let plan = realign_group(&cm, &specs, &RepartitionOptions::default());
        assert!(plan.infeasible.is_empty());
        assert!(plan_is_slo_safe(&plan));
    }

    #[test]
    fn single_fragment_gets_standalone_plan() {
        let cm = cm();
        let m = cm.model_index("vgg").unwrap();
        let plan = realign_group(
            &cm,
            &[spec(0, m, 2, 60.0, 30.0)],
            &RepartitionOptions::default(),
        );
        assert_eq!(plan.sets.len(), 1);
        assert_eq!(plan.sets[0].members.len(), 1);
    }

    #[test]
    fn candidate_points_dedups_and_clamps() {
        // duplicate / out-of-range point_set entries must not survive
        // into the DP scan; the open default range excludes `layers`
        let opts = RepartitionOptions {
            point_set: Some(vec![8, 4, 17, 4, 6, 17, 99, 8]),
            ..Default::default()
        };
        assert_eq!(candidate_points(&opts, 17), vec![4, 6, 8]);
        assert_eq!(candidate_points(&opts, 5), vec![4]);
        let all = candidate_points(&RepartitionOptions::default(), 17);
        assert_eq!(all.len(), 17);
        assert_eq!(*all.last().unwrap(), 16);
    }

    #[test]
    fn adaptive_grid_matches_exhaustive() {
        let cm = cm();
        let specs = inc_group(&cm);
        for d_grid in [4usize, 8, 24, 48] {
            let adaptive = RepartitionOptions {
                d_grid,
                adaptive_grid: true,
                ..Default::default()
            };
            let exhaustive = RepartitionOptions {
                d_grid,
                adaptive_grid: false,
                ..Default::default()
            };
            assert_eq!(
                realign_group(&cm, &specs, &adaptive),
                realign_group(&cm, &specs, &exhaustive),
                "d_grid={d_grid}"
            );
        }
    }

    #[test]
    fn warm_hints_never_change_the_plan() {
        let cm = cm();
        let specs = inc_group(&cm);
        let opts = RepartitionOptions::default();
        let cold = realign_group(&cm, &specs, &opts);
        // its own winning points, a stale/bogus set, and an empty hint
        // must all replay byte-identically
        let own = cold.realign_points();
        for hint in [own, vec![0, 3, 99, 16, 3], Vec::new()] {
            let warm = realign_group_warm(
                &cm,
                &specs,
                &opts,
                Some(&hint),
                None,
            );
            assert_eq!(warm, cold, "hint {hint:?}");
        }
    }

    #[test]
    fn telemetry_counts_search_effort() {
        let cm = cm();
        let specs = inc_group(&cm);
        let opts = RepartitionOptions::default();
        let cold_t = RepartitionTelemetry::default();
        let cold =
            realign_group_warm(&cm, &specs, &opts, None, Some(&cold_t));
        let cold_eval = cold_t
            .grid_points_evaluated
            .load(std::sync::atomic::Ordering::Relaxed);
        assert!(cold_eval > 0);
        // warm-started with the winning points: strictly less costing
        // work, same plan, and the winning choices count as warm hits
        let own = cold.realign_points();
        let warm_t = RepartitionTelemetry::default();
        let warm =
            realign_group_warm(&cm, &specs, &opts, Some(&own), Some(&warm_t));
        assert_eq!(warm, cold);
        let warm_eval = warm_t
            .grid_points_evaluated
            .load(std::sync::atomic::Ordering::Relaxed);
        assert!(warm_eval > 0);
        // the adaptive screen dismissed at least some splits on the
        // shared allocation alone in one of the two runs
        let pruned = cold_t
            .grid_points_pruned
            .load(std::sync::atomic::Ordering::Relaxed)
            + warm_t
                .grid_points_pruned
                .load(std::sync::atomic::Ordering::Relaxed);
        let _ = pruned; // config-dependent; counted, not asserted
    }
}
