//! §4.3 — fragment re-partitioning (Algorithm 1).
//!
//! For a group of same-model fragments `⟨p_i, t_i, q_i⟩`, scan every
//! candidate re-partition point `p`: the fragments with `p_i ≤ p` form
//! `F_A` and are re-aligned — each executes an *alignment stage*
//! `[p_i..p]` on its own instances, then all share one batched *shared
//! stage* `[p..L]`; the rest (`F_B`) is re-aligned recursively.  For each
//! `p` the time-budget split between the two stages is searched on a
//! grid of `d_shared` values (the paper solves the equivalent allocation
//! LP with GUROBI; the split is one-dimensional because each member's
//! alignment budget is maximal at `t_i/2 − d_shared` — see below), with
//! the §4.3 worst-case-queueing rule `d_i + d_shared ≤ t_i / 2`.
//!
//! The recursion over `F_B` only ever visits suffixes of the fragments
//! sorted by partition point, so we implement it as a suffix DP — same
//! optimum, no recomputation.

use super::fragment::FragmentSpec;
use super::plan::{ExecutionPlan, MemberPlan, RealignedSet, StagePlan};
use crate::profiler::{AllocConstraints, CostModel, FragmentId};

#[derive(Debug, Clone)]
pub struct RepartitionOptions {
    /// Grid resolution for the d_shared time-budget split search.
    pub d_grid: usize,
    pub constraints: AllocConstraints,
    /// Restrict candidate re-partition points (e.g. to the AOT-compiled
    /// point set on the real data path).  `None` = every layer (paper).
    pub point_set: Option<Vec<usize>>,
}

impl Default for RepartitionOptions {
    fn default() -> Self {
        Self {
            d_grid: 24,
            constraints: AllocConstraints::default(),
            point_set: None,
        }
    }
}

/// Re-align one group (Algorithm 1).  Returns the realigned sets plus the
/// specs that are infeasible even standalone (dropped by the balancer).
pub fn realign_group(
    cm: &CostModel,
    specs: &[FragmentSpec],
    opts: &RepartitionOptions,
) -> ExecutionPlan {
    let mut plan = ExecutionPlan::default();
    if specs.is_empty() {
        return plan;
    }
    debug_assert!(
        specs.iter().all(|s| s.model == specs[0].model),
        "realign_group expects same-model fragments"
    );

    // Pre-filter: members infeasible even standalone can never be served.
    // Keep each feasible member's standalone set — it is the DP's
    // fallback candidate, so computing it once here avoids re-running the
    // allocation search per DP index.
    let mut pre: Vec<(FragmentSpec, RealignedSet)> = Vec::new();
    for s in specs {
        match standalone_set(cm, s, &opts.constraints) {
            Some(set) => pre.push((s.clone(), set)),
            None => plan.infeasible.push(s.clone()),
        }
    }
    if pre.is_empty() {
        return plan;
    }
    pre.sort_by(|a, b| {
        a.0.p.cmp(&b.0.p).then(a.0.budget_ms.total_cmp(&b.0.budget_ms))
    });
    let (work, standalone): (Vec<FragmentSpec>, Vec<RealignedSet>) =
        pre.into_iter().unzip();

    let layers = cm.config().models[work[0].model].layers;
    let points = candidate_points(opts, layers);

    // Suffix DP: best[i] = min-cost realignment of work[i..].  Each state
    // stores only its cost, the set serving the head block and the index
    // where the tail resumes; the winning plan is reconstructed once by
    // backtracking.  (The seed kept a full Vec<RealignedSet> per state,
    // cloning O(n²) sets per group.)
    struct Choice {
        cost: u32,
        next: usize,
        set: RealignedSet,
    }
    let n = work.len();
    let mut best: Vec<Option<Choice>> = (0..n).map(|_| None).collect();
    let tail_cost = |best: &[Option<Choice>], j: usize| -> Option<u32> {
        if j == n {
            Some(0)
        } else {
            best[j].as_ref().map(|c| c.cost)
        }
    };
    for i in (0..n).rev() {
        // Fallback: the head fragment standalone (always feasible here).
        if let Some(tc) = tail_cost(&best, i + 1) {
            let set = standalone[i].clone();
            let cost = set.total_share() + tc;
            if best[i].as_ref().map_or(true, |c| cost < c.cost) {
                best[i] = Some(Choice { cost, next: i + 1, set });
            }
        }
        for &p in points.iter().filter(|&&p| p >= work[i].p && p < layers) {
            // F_A = work[i..j] (all suffix members with p_k <= p)
            let j = i + work[i..].partition_point(|s| s.p <= p);
            if j == i {
                continue;
            }
            let Some(tc) = tail_cost(&best, j) else {
                continue;
            };
            // a candidate costing >= the incumbent from its tail alone
            // cannot win (set share is positive) — skip the grid sweep
            if best[i].as_ref().is_some_and(|c| tc >= c.cost) {
                continue;
            }
            let Some(set) = realign_set(cm, &work[i..j], p, opts) else {
                continue;
            };
            let cost = set.total_share() + tc;
            if best[i].as_ref().map_or(true, |c| cost < c.cost) {
                best[i] = Some(Choice { cost, next: j, set });
            }
        }
    }
    // Backtrack the winning chain of sets (head-first, as the seed did).
    let mut i = 0;
    while i < n {
        let c = best[i].take().expect("standalone fallback always feasible");
        i = c.next;
        plan.sets.push(c.set);
    }
    plan
}

/// Provision one fragment standalone: point = its own p, budget t/2.
pub fn standalone_set(
    cm: &CostModel,
    spec: &FragmentSpec,
    cons: &AllocConstraints,
) -> Option<RealignedSet> {
    let layers = cm.config().models[spec.model].layers;
    let frag = FragmentId::new(spec.model, spec.p, layers);
    let budget = spec.budget_ms / 2.0;
    let alloc = cm.min_alloc(frag, budget, spec.rate_rps, *cons)?;
    Some(RealignedSet {
        model: spec.model,
        point: spec.p,
        members: vec![MemberPlan { spec: spec.clone(), align: None }],
        shared: StagePlan {
            frag,
            alloc,
            budget_ms: budget,
            demand_rps: spec.rate_rps,
            gpus: Vec::new(),
        },
    })
}

/// Best provisioning of `members` re-aligned at point `p` over the
/// d_shared grid.  Every member must have `p_i <= p`; `p < layers`.
///
/// Two passes: a costing sweep over the grid that touches only cached
/// `min_alloc` results (no spec clones, no plan construction), then one
/// materialisation of the winning split.  The seed built a full
/// `RealignedSet` — cloning every member spec — per grid point.
fn realign_set(
    cm: &CostModel,
    members: &[FragmentSpec],
    p: usize,
    opts: &RepartitionOptions,
) -> Option<RealignedSet> {
    let model = members[0].model;
    let layers = cm.config().models[model].layers;
    let shared_frag = FragmentId::new(model, p, layers);
    let total_rate: f64 = members.iter().map(|m| m.rate_rps).sum();
    let t_min = members
        .iter()
        .map(|m| m.budget_ms)
        .fold(f64::INFINITY, f64::min);

    let g = opts.d_grid.max(2);
    let d_shared_at = |k: usize| t_min / 2.0 * k as f64 / g as f64;

    // Pass 1: find the cheapest feasible grid point (first wins ties,
    // matching the seed's strict-improvement replacement order).
    let mut best_k: Option<(usize, u32)> = None;
    'grid: for k in 1..=g {
        let d_shared = d_shared_at(k);
        let Some(shared_alloc) =
            cm.min_alloc(shared_frag, d_shared, total_rate, opts.constraints)
        else {
            continue; // too tight for the shared stage; larger k may fit
        };
        let mut cost = shared_alloc.total_share();
        for m in members {
            if m.p == p {
                continue;
            }
            let d_i = m.budget_ms / 2.0 - d_shared;
            let align_frag = FragmentId::new(model, m.p, p);
            match cm.min_alloc(align_frag, d_i, m.rate_rps, opts.constraints)
            {
                Some(alloc) => cost += alloc.total_share(),
                None => continue 'grid,
            }
        }
        if best_k.map_or(true, |(_, c)| cost < c) {
            best_k = Some((k, cost));
        }
    }
    let (k, _) = best_k?;

    // Pass 2: materialise the winning split (allocation queries repeat
    // the pass-1 keys, so they are cache hits).
    let d_shared = d_shared_at(k);
    let shared_alloc =
        cm.min_alloc(shared_frag, d_shared, total_rate, opts.constraints)?;
    let mut member_plans = Vec::with_capacity(members.len());
    for m in members {
        if m.p == p {
            member_plans.push(MemberPlan { spec: m.clone(), align: None });
            continue;
        }
        let d_i = m.budget_ms / 2.0 - d_shared;
        let align_frag = FragmentId::new(model, m.p, p);
        let alloc =
            cm.min_alloc(align_frag, d_i, m.rate_rps, opts.constraints)?;
        member_plans.push(MemberPlan {
            spec: m.clone(),
            align: Some(StagePlan {
                frag: align_frag,
                alloc,
                budget_ms: d_i,
                demand_rps: m.rate_rps,
                gpus: Vec::new(),
            }),
        });
    }
    Some(RealignedSet {
        model,
        point: p,
        members: member_plans,
        shared: StagePlan {
            frag: shared_frag,
            alloc: shared_alloc,
            budget_ms: d_shared,
            demand_rps: total_rate,
            gpus: Vec::new(),
        },
    })
}

fn candidate_points(opts: &RepartitionOptions, layers: usize) -> Vec<usize> {
    match &opts.point_set {
        Some(ps) => {
            let mut v: Vec<usize> =
                ps.iter().copied().filter(|&p| p <= layers).collect();
            v.sort_unstable();
            v.dedup();
            v
        }
        None => (0..=layers).collect(),
    }
}

/// Resource consumption without re-partitioning: every spec standalone
/// (the Fig 11 comparator).
pub fn no_realign_plan(
    cm: &CostModel,
    specs: &[FragmentSpec],
    cons: &AllocConstraints,
) -> ExecutionPlan {
    let mut plan = ExecutionPlan::default();
    for s in specs {
        match standalone_set(cm, s, cons) {
            Some(set) => plan.sets.push(set),
            None => plan.infeasible.push(s.clone()),
        }
    }
    plan
}

/// SLO-safety check used by tests/proptests: every member's end-to-end
/// server time (alignment latency + shared latency, each doubled for
/// worst-case queueing) fits its budget.
pub fn plan_is_slo_safe(plan: &ExecutionPlan) -> bool {
    plan.sets.iter().all(|set| {
        set.members.iter().all(|m| {
            let align_ms =
                m.align.as_ref().map_or(0.0, |a| a.alloc.latency_ms);
            let shared_ms = set.shared.alloc.latency_ms;
            2.0 * (align_ms + shared_ms) <= m.spec.budget_ms + 1e-6
        })
    })
}

/// Throughput-safety: every stage's allocation covers its demand.
pub fn plan_covers_demand(plan: &ExecutionPlan) -> bool {
    plan.stages()
        .all(|s| s.alloc.throughput_rps >= s.demand_rps - 1e-6)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::coordinator::fragment::ClientId;

    fn cm() -> CostModel {
        CostModel::new(Config::embedded())
    }

    fn spec(i: u32, model: usize, p: usize, t: f64, q: f64) -> FragmentSpec {
        FragmentSpec::single(ClientId(i), model, p, t, q)
    }

    fn inc_group(cm: &CostModel) -> Vec<FragmentSpec> {
        let m = cm.model_index("inc").unwrap();
        vec![
            spec(0, m, 2, 90.0, 30.0),
            spec(1, m, 3, 95.0, 30.0),
            spec(2, m, 4, 100.0, 30.0),
            spec(3, m, 4, 85.0, 30.0),
            spec(4, m, 6, 110.0, 30.0),
        ]
    }

    #[test]
    fn realign_beats_no_realign() {
        let cm = cm();
        let specs = inc_group(&cm);
        let opts = RepartitionOptions::default();
        let with = realign_group(&cm, &specs, &opts);
        let without =
            no_realign_plan(&cm, &specs, &AllocConstraints::default());
        assert!(with.infeasible.is_empty());
        assert!(
            with.total_share() <= without.total_share(),
            "realign {} > standalone {}",
            with.total_share(),
            without.total_share()
        );
    }

    #[test]
    fn plans_are_slo_safe_and_cover_demand() {
        let cm = cm();
        let specs = inc_group(&cm);
        let plan = realign_group(&cm, &specs, &RepartitionOptions::default());
        assert!(plan_is_slo_safe(&plan), "{plan:?}");
        assert!(plan_covers_demand(&plan));
        // all clients are served exactly once
        let mut clients: Vec<u32> = plan
            .sets
            .iter()
            .flat_map(|s| s.members.iter())
            .flat_map(|m| m.spec.clients.iter().map(|c| c.0))
            .collect();
        clients.sort_unstable();
        assert_eq!(clients, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn repartition_point_covers_members() {
        let cm = cm();
        let specs = inc_group(&cm);
        let plan = realign_group(&cm, &specs, &RepartitionOptions::default());
        for set in &plan.sets {
            for m in &set.members {
                assert!(m.spec.p <= set.point);
                match &m.align {
                    Some(a) => {
                        assert_eq!(a.frag.start, m.spec.p);
                        assert_eq!(a.frag.end, set.point);
                    }
                    None => assert_eq!(m.spec.p, set.point),
                }
            }
            assert_eq!(set.shared.frag.start, set.point);
        }
    }

    #[test]
    fn shared_stage_batches_aggregate_rate() {
        let cm = cm();
        let specs = inc_group(&cm);
        let plan = realign_group(&cm, &specs, &RepartitionOptions::default());
        // at least one set should aggregate several members (that's the
        // whole point of re-alignment for this homogeneous-ish group)
        assert!(
            plan.sets.iter().any(|s| s.members.len() > 1),
            "no batching across members: {plan:?}"
        );
        for set in &plan.sets {
            assert!(
                (set.shared.demand_rps - set.total_rate()).abs() < 1e-9
            );
        }
    }

    #[test]
    fn infeasible_budget_is_reported() {
        let cm = cm();
        let m = cm.model_index("vit").unwrap();
        let bad = spec(0, m, 1, 0.01, 1.0); // sub-ms budget: hopeless
        let plan = realign_group(&cm, &[bad.clone()], &RepartitionOptions::default());
        assert!(plan.sets.is_empty());
        assert_eq!(plan.infeasible, vec![bad]);
    }

    #[test]
    fn point_set_restriction_respected() {
        let cm = cm();
        let specs = inc_group(&cm);
        let opts = RepartitionOptions {
            point_set: Some(vec![4, 6, 8, 17]),
            ..Default::default()
        };
        let plan = realign_group(&cm, &specs, &opts);
        for set in &plan.sets {
            // points are either from the set or a member's own p
            // (standalone fallback)
            assert!(
                [4usize, 6, 8, 17].contains(&set.point)
                    || set.members.len() == 1
                        && set.members[0].spec.p == set.point,
                "unexpected point {}",
                set.point
            );
        }
    }

    #[test]
    fn heterogeneous_budgets_respected() {
        // one very tight member must not drag others into infeasibility
        let cm = cm();
        let m = cm.model_index("inc").unwrap();
        let specs = vec![
            spec(0, m, 2, 30.0, 30.0), // tight
            spec(1, m, 2, 140.0, 30.0),
        ];
        let plan = realign_group(&cm, &specs, &RepartitionOptions::default());
        assert!(plan.infeasible.is_empty());
        assert!(plan_is_slo_safe(&plan));
    }

    #[test]
    fn single_fragment_gets_standalone_plan() {
        let cm = cm();
        let m = cm.model_index("vgg").unwrap();
        let plan = realign_group(
            &cm,
            &[spec(0, m, 2, 60.0, 30.0)],
            &RepartitionOptions::default(),
        );
        assert_eq!(plan.sets.len(), 1);
        assert_eq!(plan.sets[0].members.len(), 1);
    }
}
