//! The paper's baselines (§5.1):
//!
//! * **GSLICE** — fine-grained MPS shares (like Graft) but *no
//!   re-alignment*: every client's fragment is provisioned separately.
//! * **GSLICE⁺** — GSLICE plus the best merging strategy: all uniform
//!   fragments (same partition point + budget) merged before
//!   provisioning, enabling batching within a uniform class.
//! * **Static / Static⁺** — provision once from each client's *average*
//!   bandwidth (no dynamic re-planning); ⁺ merges uniform fragments.
//!   Static's resource number is what the average-bandwidth fragments
//!   cost; its SLO behaviour under a varying trace is evaluated by the
//!   latency simulator.
//!
//! None of these re-partition; that is exactly Graft's delta.

use super::fragment::FragmentSpec;
use super::merging::{merge_fragments, MergeOptions};
use super::plan::ExecutionPlan;
use super::repartition::no_realign_plan;
use crate::hybrid::{choose_partition, BandwidthTrace, DeviceKind};
use crate::profiler::{AllocConstraints, CostModel};

/// GSLICE: per-fragment fine-grained allocation, no merging, no realign.
pub fn gslice(
    cm: &CostModel,
    specs: &[FragmentSpec],
    cons: &AllocConstraints,
) -> ExecutionPlan {
    no_realign_plan(cm, specs, cons)
}

/// GSLICE⁺: merge all uniform fragments, then per-fragment allocation.
pub fn gslice_plus(
    cm: &CostModel,
    specs: &[FragmentSpec],
    cons: &AllocConstraints,
) -> ExecutionPlan {
    let merged = merge_fragments(
        cm,
        specs,
        &MergeOptions { constraints: *cons, ..MergeOptions::merge_all() },
    );
    no_realign_plan(cm, &merged, cons)
}

/// Inputs for the Static baselines: the client's device/model plus its
/// bandwidth trace (Static provisions for the trace *mean*).
#[derive(Debug, Clone)]
pub struct StaticClient {
    pub spec_seed: FragmentSpec, // carries client id / model / rate
    pub device: DeviceKind,
    pub trace: BandwidthTrace,
    pub slo_ratio: f64,
}

/// Compute the average-bandwidth fragment specs the Static baselines
/// provision for.
pub fn static_specs(
    cm: &CostModel,
    clients: &[StaticClient],
    candidates: Option<&[usize]>,
) -> Vec<FragmentSpec> {
    let mut out = Vec::new();
    for c in clients {
        let m = &cm.config().models[c.spec_seed.model];
        let slo = c.device.slo_ms(m, c.slo_ratio);
        if let Some(part) = choose_partition(
            cm,
            c.spec_seed.model,
            c.device,
            c.trace.mean(),
            slo,
            candidates,
        )
        .partition()
        {
            let mut s = c.spec_seed.clone();
            s.p = part.p;
            s.budget_ms = part.server_budget_ms;
            out.push(s);
        }
        // infeasible at mean bandwidth -> the static system simply cannot
        // serve this client; it contributes no provisioning.
    }
    out
}

/// Static: average-bandwidth provisioning, no merging.
pub fn static_alloc(
    cm: &CostModel,
    clients: &[StaticClient],
    cons: &AllocConstraints,
    candidates: Option<&[usize]>,
) -> ExecutionPlan {
    no_realign_plan(cm, &static_specs(cm, clients, candidates), cons)
}

/// Static⁺: average-bandwidth provisioning with full uniform merging.
pub fn static_plus(
    cm: &CostModel,
    clients: &[StaticClient],
    cons: &AllocConstraints,
    candidates: Option<&[usize]>,
) -> ExecutionPlan {
    let specs = static_specs(cm, clients, candidates);
    let merged = merge_fragments(
        cm,
        &specs,
        &MergeOptions { constraints: *cons, ..MergeOptions::merge_all() },
    );
    no_realign_plan(cm, &merged, cons)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::coordinator::fragment::ClientId;
    use crate::coordinator::scheduler::{Scheduler, SchedulerOptions};
    use crate::hybrid::TraceParams;

    fn cm() -> CostModel {
        CostModel::new(Config::embedded())
    }

    fn uniform_specs(cm: &CostModel, n: u32) -> Vec<FragmentSpec> {
        let inc = cm.model_index("inc").unwrap();
        (0..n)
            .map(|i| FragmentSpec::single(ClientId(i), inc, 3, 100.0, 30.0))
            .collect()
    }

    #[test]
    fn gslice_plus_never_worse_than_gslice() {
        let cm = cm();
        let specs = uniform_specs(&cm, 10);
        let cons = AllocConstraints::default();
        let g = gslice(&cm, &specs, &cons);
        let gp = gslice_plus(&cm, &specs, &cons);
        assert!(gp.total_share() <= g.total_share());
        assert!(gp.total_share() < g.total_share(), "merging should help");
    }

    #[test]
    fn graft_never_worse_than_gslice_plus() {
        let cm = cm();
        let inc = cm.model_index("inc").unwrap();
        // mildly heterogeneous fleet
        let specs: Vec<FragmentSpec> = (0..10)
            .map(|i| {
                FragmentSpec::single(
                    ClientId(i),
                    inc,
                    2 + (i as usize % 3),
                    90.0 + 5.0 * (i % 4) as f64,
                    30.0,
                )
            })
            .collect();
        let cons = AllocConstraints::default();
        let gp = gslice_plus(&cm, &specs, &cons);
        let (graft, _) = Scheduler::new(cm.clone(), SchedulerOptions::default())
            .plan(&specs);
        assert!(
            graft.total_share() <= gp.total_share(),
            "graft {} > gslice+ {}",
            graft.total_share(),
            gp.total_share()
        );
    }

    #[test]
    fn static_uses_mean_bandwidth() {
        let cm = cm();
        let inc = cm.model_index("inc").unwrap();
        let clients: Vec<StaticClient> = (0..4)
            .map(|i| StaticClient {
                spec_seed: FragmentSpec::single(ClientId(i), inc, 0, 0.0, 30.0),
                device: DeviceKind::Nano,
                trace: BandwidthTrace::generate(i as u64, &TraceParams::default()),
                slo_ratio: 0.95,
            })
            .collect();
        let specs = static_specs(&cm, &clients, None);
        assert_eq!(specs.len(), 4);
        for s in &specs {
            assert!(s.budget_ms > 0.0);
        }
        let plan = static_alloc(&cm, &clients, &AllocConstraints::default(), None);
        assert!(plan.total_share() > 0);
        let plus = static_plus(&cm, &clients, &AllocConstraints::default(), None);
        assert!(plus.total_share() <= plan.total_share());
    }
}
