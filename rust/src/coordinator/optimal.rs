//! The "Optimal" baseline (§5.1): exhaustive grouping + exact
//! re-alignment per group.  Enumerates *every* partition of the fragment
//! set into groups of size ≤ `group_size` (e.g. 252 feasible groupings
//! for 10 fragments at size 5 — §5.9), re-aligns each group with a finer
//! d_shared grid, and keeps the global minimum.  Exponential — only
//! usable at small scale, which is exactly how the paper uses it.

use super::fragment::FragmentSpec;
use super::plan::ExecutionPlan;
use super::repartition::{realign_group, RepartitionOptions};
use crate::profiler::CostModel;

/// Practical input-size cap (partitions grow super-exponentially).
pub const MAX_OPTIMAL_N: usize = 12;

/// Enumerate all partitions of `n` items into blocks of size ≤ `cap`.
fn partitions(n: usize, cap: usize) -> Vec<Vec<Vec<usize>>> {
    fn rec(
        remaining: &[usize],
        cap: usize,
        current: &mut Vec<Vec<usize>>,
        out: &mut Vec<Vec<Vec<usize>>>,
    ) {
        match remaining.split_first() {
            None => out.push(current.clone()),
            Some((&first, rest)) => {
                // put `first` into each existing block (canonical order
                // avoids duplicates: first always goes with smaller ids)
                for i in 0..current.len() {
                    if current[i].len() < cap {
                        current[i].push(first);
                        rec(rest, cap, current, out);
                        current[i].pop();
                    }
                }
                // or open a new block
                current.push(vec![first]);
                rec(rest, cap, current, out);
                current.pop();
            }
        }
    }
    let items: Vec<usize> = (0..n).collect();
    let mut out = Vec::new();
    rec(&items, cap, &mut Vec::new(), &mut out);
    out
}

/// Exhaustively optimal plan over all groupings (same model only).
/// Also enumerates the merging pre-step (none / threshold / merge-all),
/// since merging expresses full-fragment sharing that suffix
/// re-alignment alone cannot.
pub fn optimal_plan(
    cm: &CostModel,
    specs: &[FragmentSpec],
    group_size: usize,
    opts: &RepartitionOptions,
) -> ExecutionPlan {
    use super::merging::{merge_fragments, MergeOptions};
    let variants = [
        specs.to_vec(),
        merge_fragments(cm, specs, &MergeOptions::merge_all()),
        merge_fragments(
            cm,
            specs,
            &MergeOptions {
                constraints: opts.constraints,
                ..Default::default()
            },
        ),
    ];
    let mut best: Option<ExecutionPlan> = None;
    for v in variants {
        let plan = optimal_plan_unmerged(cm, &v, group_size, opts);
        let better = match &best {
            None => true,
            Some(b) => {
                (plan.infeasible.len(), plan.total_share())
                    < (b.infeasible.len(), b.total_share())
            }
        };
        if better {
            best = Some(plan);
        }
    }
    best.unwrap_or_default()
}

fn optimal_plan_unmerged(
    cm: &CostModel,
    specs: &[FragmentSpec],
    group_size: usize,
    opts: &RepartitionOptions,
) -> ExecutionPlan {
    assert!(
        specs.len() <= MAX_OPTIMAL_N,
        "optimal baseline capped at {MAX_OPTIMAL_N} fragments"
    );
    if specs.is_empty() {
        return ExecutionPlan::default();
    }
    // finer allocation grid than the fast path
    let fine = RepartitionOptions { d_grid: opts.d_grid.max(48), ..opts.clone() };

    let mut best: Option<ExecutionPlan> = None;
    for grouping in partitions(specs.len(), group_size) {
        let mut plan = ExecutionPlan::default();
        for block in &grouping {
            let group: Vec<FragmentSpec> =
                block.iter().map(|&i| specs[i].clone()).collect();
            plan.merge_with(realign_group(cm, &group, &fine));
        }
        let better = match &best {
            None => true,
            Some(b) => {
                // prefer fewer dropped clients, then fewer share points
                (plan.infeasible.len(), plan.total_share())
                    < (b.infeasible.len(), b.total_share())
            }
        };
        if better {
            best = Some(plan);
        }
    }
    best.unwrap()
}

/// Optimal over a mixed-model demand set: split per model, cap each.
pub fn optimal_plan_multi(
    cm: &CostModel,
    specs: &[FragmentSpec],
    group_size: usize,
    opts: &RepartitionOptions,
) -> ExecutionPlan {
    let n_models = cm.config().models.len();
    let mut plan = ExecutionPlan::default();
    for model in 0..n_models {
        let ms: Vec<FragmentSpec> =
            specs.iter().filter(|s| s.model == model).cloned().collect();
        if !ms.is_empty() {
            plan.merge_with(optimal_plan(cm, &ms, group_size, opts));
        }
    }
    plan
}

/// Number of groupings the optimal search enumerates (§5.9 reports 252
/// for 10 fragments — that is C(10,5)/... with the paper's constraints;
/// exposed for the overhead experiment).
pub fn grouping_count(n: usize, cap: usize) -> usize {
    partitions(n, cap).len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::coordinator::fragment::ClientId;
    use crate::coordinator::scheduler::{Scheduler, SchedulerOptions};
    use crate::profiler::CostModel;

    fn cm() -> CostModel {
        CostModel::new(Config::embedded())
    }

    #[test]
    fn partition_counts_match_bell_like_numbers() {
        // unrestricted cap == Bell numbers: 1, 1, 2, 5, 15, 52
        for (n, bell) in [(0, 1), (1, 1), (2, 2), (3, 5), (4, 15), (5, 52)] {
            assert_eq!(partitions(n, n.max(1)).len(), bell, "n={n}");
        }
        // cap 2 over 4 items: pairs+singletons = 10 partitions
        assert_eq!(partitions(4, 2).len(), 10);
    }

    #[test]
    fn partitions_are_valid() {
        for p in partitions(5, 3) {
            let mut all: Vec<usize> = p.concat();
            all.sort_unstable();
            assert_eq!(all, vec![0, 1, 2, 3, 4]);
            assert!(p.iter().all(|b| b.len() <= 3 && !b.is_empty()));
        }
    }

    #[test]
    fn optimal_never_worse_than_graft() {
        let cm = cm();
        let inc = cm.model_index("inc").unwrap();
        let specs: Vec<FragmentSpec> = (0..6)
            .map(|i| {
                FragmentSpec::single(
                    ClientId(i),
                    inc,
                    2 + (i as usize % 3),
                    90.0 + 7.0 * (i % 3) as f64,
                    30.0,
                )
            })
            .collect();
        let opt = optimal_plan(&cm, &specs, 5, &RepartitionOptions::default());
        let (graft, _) =
            Scheduler::new(cm.clone(), SchedulerOptions::default()).plan(&specs);
        assert!(
            opt.total_share() <= graft.total_share(),
            "optimal {} > graft {}",
            opt.total_share(),
            graft.total_share()
        );
        // paper: Graft is close to Optimal (within a few %; we allow 25%
        // slack in this tiny synthetic case to keep the test robust)
        assert!(
            (graft.total_share() as f64)
                <= (opt.total_share() as f64) * 1.25
        );
    }

    #[test]
    #[should_panic(expected = "capped")]
    fn optimal_rejects_large_inputs() {
        let cm = cm();
        let specs: Vec<FragmentSpec> = (0..20)
            .map(|i| FragmentSpec::single(ClientId(i), 0, 2, 90.0, 30.0))
            .collect();
        optimal_plan(&cm, &specs, 5, &RepartitionOptions::default());
    }
}
