//! §4.1 — DNN fragment merging.
//!
//! Uniform fragments (same partition point, same time budget) are merged
//! incrementally until the *resource margin* `(q_a - q_d)/q_d` of the
//! merged fragment drops to the merging threshold.  Merging exploits the
//! discreteness of batch/share/instances (Fig 4): an instance provisioned
//! for one client can usually absorb several more for free.  A threshold
//! of 0 ("Uniform" merging) merges every uniform fragment; Graft's
//! Uniform⁺ stops early to leave slack for grouping/re-partitioning
//! (paper §5.5 shows why that wins for low-margin models like ResNet).
//!
//! **Incremental (dirty-class) merging.**  The sorted demand set
//! segments into *uniform classes*: maximal runs with one `(model, p)`
//! whose consecutive budgets gap by at most the uniformity tolerance.
//! The merge accumulator's budget only ever tightens downward, so a
//! budget gap wider than the tolerance can never close — specs in
//! different classes cannot merge, classes merge independently, and
//! their outputs concatenate to exactly `merge_fragments`' result.
//! [`merge_fragments_incremental`] exploits this under trigger-based
//! re-planning: classes whose membership is unchanged since the
//! previous trigger (verified by full spec equality, so hash
//! collisions cannot splice a wrong result) reuse their cached merge
//! output; only dirty classes re-run the margin scan.

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};

use super::fragment::FragmentSpec;
use super::reuse::{group_signature, hash_constraints};
use crate::profiler::{AllocConstraints, CostModel, FragmentId};

/// Strategy knobs for the merging step.
#[derive(Debug, Clone, Copy)]
pub struct MergeOptions {
    /// Stop merging into a fragment once its margin ≤ this threshold
    /// (paper default 0.2). `f64::NEG_INFINITY` ≙ merge-all ("Uniform").
    pub threshold: f64,
    /// Budgets within this tolerance count as uniform (ms).
    pub budget_tol_ms: f64,
    pub constraints: AllocConstraints,
}

impl Default for MergeOptions {
    fn default() -> Self {
        Self {
            threshold: 0.2,
            budget_tol_ms: 1.0,
            constraints: AllocConstraints::default(),
        }
    }
}

impl MergeOptions {
    /// The paper's "Uniform" strategy: merge all uniform fragments.
    pub fn merge_all() -> Self {
        Self { threshold: f64::NEG_INFINITY, ..Default::default() }
    }

    /// "No-merging" strategy.
    pub fn none() -> Self {
        Self { threshold: f64::INFINITY, ..Default::default() }
    }
}

/// Resource margin of a spec under its min-resource allocation (the §4.1
/// metric): how much spare throughput the discrete allocation yields.
pub fn resource_margin(
    cm: &CostModel,
    spec: &FragmentSpec,
    cons: AllocConstraints,
) -> Option<f64> {
    let layers = cm.config().models[spec.model].layers;
    let frag = FragmentId::new(spec.model, spec.p, layers);
    // §4.3: worst-case queueing halves the usable budget.
    cm.min_alloc(frag, spec.budget_ms / 2.0, spec.rate_rps, cons)
        .map(|a| a.margin(spec.rate_rps))
}

/// Merge fragments per §4.1.  Fragments of different models are never
/// merged.  Returns the merged specs (order: by model, point, budget).
pub fn merge_fragments(
    cm: &CostModel,
    specs: &[FragmentSpec],
    opts: &MergeOptions,
) -> Vec<FragmentSpec> {
    if opts.threshold.is_infinite() && opts.threshold > 0.0 {
        let mut out = specs.to_vec();
        sort_specs(&mut out);
        return out;
    }
    // "mergesort" the fragments into uniform classes (model, p, budget)
    let mut sorted = specs.to_vec();
    sort_specs(&mut sorted);
    let mut out: Vec<FragmentSpec> = Vec::new();
    merge_scan(cm, sorted, opts, &mut out);
    out
}

/// The linear §4.1 scan over one sorted sequence (the whole demand set,
/// or one uniform class — the scan state resets exactly at class
/// boundaries, so per-class scans concatenate to the global scan).
/// Takes owned specs so the from-scratch path moves them instead of
/// cloning.
fn merge_scan(
    cm: &CostModel,
    sorted: impl IntoIterator<Item = FragmentSpec>,
    opts: &MergeOptions,
    out: &mut Vec<FragmentSpec>,
) {
    let mut current: Option<FragmentSpec> = None;
    for spec in sorted {
        match current.take() {
            None => current = Some(spec),
            Some(mut acc) => {
                if acc.uniform_with(&spec, opts.budget_tol_ms)
                    && resource_margin(cm, &acc, opts.constraints)
                        .is_some_and(|m| m > opts.threshold)
                {
                    // margin still above threshold: absorb this one
                    acc.merge(&spec);
                    current = Some(acc);
                } else {
                    out.push(acc);
                    current = Some(spec);
                }
            }
        }
    }
    out.extend(current);
}

fn sort_specs(specs: &mut [FragmentSpec]) {
    specs.sort_by(|a, b| {
        (a.model, a.p)
            .cmp(&(b.model, b.p))
            .then(a.budget_ms.total_cmp(&b.budget_ms))
            .then(a.rate_rps.total_cmp(&b.rate_rps))
    });
}

/// Segment a sorted demand set into independent uniform classes:
/// maximal runs with one `(model, p)` whose *consecutive* budgets gap
/// by at most `tol_ms`.  An accumulator's budget is the minimum of its
/// members (≤ every budget seen so far in the run), so a gap > tol
/// between neighbours guarantees the global scan pushes its
/// accumulator there — classes never interact.
fn class_ranges(sorted: &[FragmentSpec], tol_ms: f64) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut start = 0;
    for i in 1..=sorted.len() {
        let split = i == sorted.len() || {
            let (a, b) = (&sorted[i - 1], &sorted[i]);
            a.model != b.model
                || a.p != b.p
                || (b.budget_ms - a.budget_ms).abs() > tol_ms
        };
        if split {
            if i > start {
                out.push((start, i));
            }
            start = i;
        }
    }
    out
}

/// One cached uniform class: the exact sorted member specs (hash
/// collisions are resolved by full equality, so a stale entry can never
/// splice a wrong result) and its merge output.
struct MergeClassEntry {
    specs: Vec<FragmentSpec>,
    merged: Vec<FragmentSpec>,
    generation: u64,
}

/// Generational cache of per-class merge results, owned by the
/// scheduler's replan context.  Every incremental merge pass opens a
/// new generation and refreshes the entries it hits; when the entry
/// count exceeds the capacity, eviction drops only entries not touched
/// within the last trigger — the live working set always survives.
#[derive(Default)]
pub struct MergeCache {
    map: HashMap<u64, Vec<MergeClassEntry>>,
    entries: usize,
    generation: u64,
}

const MERGE_CACHE_CAPACITY: usize = 1 << 16;

impl MergeCache {
    /// Drop everything (e.g. after mutating merge options — the options
    /// are folded into every class signature, so this is belt-and-
    /// braces, not correctness).
    pub fn clear(&mut self) {
        self.map.clear();
        self.entries = 0;
    }

    fn begin_trigger(&mut self) {
        self.generation += 1;
        let gen = self.generation;
        if self.entries > MERGE_CACHE_CAPACITY {
            for bucket in self.map.values_mut() {
                bucket.retain(|e| e.generation + 1 >= gen);
            }
            self.map.retain(|_, b| !b.is_empty());
            self.entries = self.map.values().map(Vec::len).sum();
        }
    }

    /// Cached class entries (for persistence bookkeeping/tests).
    pub fn len(&self) -> usize {
        self.entries
    }

    pub fn is_empty(&self) -> bool {
        self.entries == 0
    }

    /// JSON form for replan-context persistence
    /// ([`crate::coordinator::Scheduler::save_replan_context`]): every
    /// cached class with its signature, exact member specs and merge
    /// output.  Generations are not persisted — a reloaded cache starts
    /// a fresh generation clock, which only affects eviction order,
    /// never correctness (entries are always verified by full spec
    /// equality on lookup).
    pub fn to_json(&self) -> crate::util::Json {
        use crate::util::Json;
        let mut classes = Vec::new();
        for (sig, bucket) in &self.map {
            for e in bucket {
                let mut o = std::collections::BTreeMap::new();
                o.insert("sig".into(), Json::Str(format!("{sig:016x}")));
                o.insert(
                    "specs".into(),
                    Json::Arr(e.specs.iter().map(|s| s.to_json()).collect()),
                );
                o.insert(
                    "merged".into(),
                    Json::Arr(e.merged.iter().map(|s| s.to_json()).collect()),
                );
                classes.push(Json::Obj(o));
            }
        }
        Json::Arr(classes)
    }

    /// Inverse of [`Self::to_json`].
    pub fn from_json(v: &crate::util::Json) -> anyhow::Result<MergeCache> {
        let mut cache = MergeCache::default();
        for entry in v.as_arr()? {
            let sig = u64::from_str_radix(entry.get("sig")?.as_str()?, 16)?;
            let parse = |key: &str| -> anyhow::Result<Vec<FragmentSpec>> {
                entry
                    .get(key)?
                    .as_arr()?
                    .iter()
                    .map(FragmentSpec::from_json)
                    .collect()
            };
            cache.map.entry(sig).or_default().push(MergeClassEntry {
                specs: parse("specs")?,
                merged: parse("merged")?,
                generation: 0,
            });
            cache.entries += 1;
        }
        Ok(cache)
    }

    /// Partition the cached classes into per-model caches.  A uniform
    /// class never spans models ([`class_ranges`] splits on the model
    /// key), so this is an exact re-keying — used by the scheduler to
    /// route a persisted (globally keyed) cache to its per-model
    /// planner shards.
    pub fn split_by_model(
        self,
    ) -> std::collections::HashMap<usize, MergeCache> {
        let mut out: std::collections::HashMap<usize, MergeCache> =
            std::collections::HashMap::new();
        for (sig, bucket) in self.map {
            for e in bucket {
                let model = e.specs.first().map_or(0, |s| s.model);
                let shard = out.entry(model).or_default();
                shard.map.entry(sig).or_default().push(e);
                shard.entries += 1;
            }
        }
        out
    }
}

/// Outcome of one incremental merge pass.
pub struct MergeOutcome {
    /// Identical to `merge_fragments` on the same demand
    /// (property-tested).
    pub merged: Vec<FragmentSpec>,
    /// Uniform classes the demand set segmented into.
    pub classes: usize,
    /// Classes whose membership changed since the previous trigger
    /// (recomputed; the rest spliced their cached output).
    pub classes_remerged: usize,
}

fn merge_signature(opts: &MergeOptions) -> u64 {
    let mut h = DefaultHasher::new();
    opts.threshold.to_bits().hash(&mut h);
    opts.budget_tol_ms.to_bits().hash(&mut h);
    hash_constraints(&mut h, &opts.constraints);
    h.finish()
}

/// [`merge_fragments`], incrementally: diff the demand set against the
/// previous trigger by uniform class and re-run the margin scan only
/// for classes whose membership changed, splicing cached results for
/// the clean ones.  Output is exactly `merge_fragments`' (the class
/// segmentation argument above; property-tested).
pub fn merge_fragments_incremental(
    cm: &CostModel,
    specs: &[FragmentSpec],
    opts: &MergeOptions,
    cache: &mut MergeCache,
) -> MergeOutcome {
    let mut sorted = specs.to_vec();
    sort_specs(&mut sorted);
    if opts.threshold.is_infinite() && opts.threshold > 0.0 {
        // "no merging": the sorted demand passes through untouched
        let classes = class_ranges(&sorted, opts.budget_tol_ms).len();
        return MergeOutcome { merged: sorted, classes, classes_remerged: 0 };
    }
    cache.begin_trigger();
    let gen = cache.generation;
    let opts_sig = merge_signature(opts);
    let ranges = class_ranges(&sorted, opts.budget_tol_ms);
    let classes = ranges.len();
    let mut merged = Vec::new();
    let mut remerged = 0usize;
    for (a, b) in ranges {
        let class = &sorted[a..b];
        // the exact spec-level hash shared with the scheduler's group
        // cache (`reuse::group_signature`), under the merge options
        let sig = group_signature(class, opts_sig);
        if let Some(e) = cache
            .map
            .get_mut(&sig)
            .and_then(|bucket| bucket.iter_mut().find(|e| e.specs == class))
        {
            e.generation = gen;
            merged.extend(e.merged.iter().cloned());
            continue;
        }
        remerged += 1;
        let mut out = Vec::new();
        merge_scan(cm, class.iter().cloned(), opts, &mut out);
        merged.extend(out.iter().cloned());
        cache.map.entry(sig).or_default().push(MergeClassEntry {
            specs: class.to_vec(),
            merged: out,
            generation: gen,
        });
        cache.entries += 1;
    }
    MergeOutcome { merged, classes, classes_remerged: remerged }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::coordinator::fragment::ClientId;

    fn cm() -> CostModel {
        CostModel::new(Config::embedded())
    }

    fn specs(n: usize, model: usize, p: usize, t: f64, q: f64) -> Vec<FragmentSpec> {
        (0..n)
            .map(|i| FragmentSpec::single(ClientId(i as u32), model, p, t, q))
            .collect()
    }

    #[test]
    fn no_merging_keeps_everything() {
        let cm = cm();
        let s = specs(10, 0, 4, 80.0, 30.0);
        let out = merge_fragments(&cm, &s, &MergeOptions::none());
        assert_eq!(out.len(), 10);
    }

    #[test]
    fn merge_all_collapses_uniform_class() {
        let cm = cm();
        let s = specs(10, 0, 4, 80.0, 30.0);
        let out = merge_fragments(&cm, &s, &MergeOptions::merge_all());
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].rate_rps, 300.0);
        assert_eq!(out[0].clients.len(), 10);
    }

    #[test]
    fn threshold_merging_is_between() {
        let cm = cm();
        let s = specs(20, 0, 4, 80.0, 30.0);
        let none = merge_fragments(&cm, &s, &MergeOptions::none()).len();
        let all = merge_fragments(&cm, &s, &MergeOptions::merge_all()).len();
        let thr = merge_fragments(
            &cm,
            &s,
            &MergeOptions { threshold: 0.2, ..Default::default() },
        )
        .len();
        assert!(all <= thr && thr <= none, "{all} <= {thr} <= {none}");
    }

    #[test]
    fn different_points_never_merge() {
        let cm = cm();
        let mut s = specs(3, 0, 4, 80.0, 30.0);
        s.extend(specs(3, 0, 5, 80.0, 30.0));
        let out = merge_fragments(&cm, &s, &MergeOptions::merge_all());
        assert_eq!(out.len(), 2);
    }

    #[test]
    fn different_models_never_merge() {
        let cm = cm();
        let mut s = specs(3, 0, 4, 80.0, 30.0);
        s.extend(specs(3, 1, 4, 80.0, 30.0));
        let out = merge_fragments(&cm, &s, &MergeOptions::merge_all());
        assert_eq!(out.len(), 2);
    }

    #[test]
    fn merged_rate_and_clients_conserved() {
        let cm = cm();
        let s = specs(12, 0, 4, 80.0, 30.0);
        let out = merge_fragments(
            &cm,
            &s,
            &MergeOptions { threshold: 0.2, ..Default::default() },
        );
        let rate: f64 = out.iter().map(|f| f.rate_rps).sum();
        let clients: usize = out.iter().map(|f| f.clients.len()).sum();
        assert_eq!(rate, 360.0);
        assert_eq!(clients, 12);
    }

    #[test]
    fn class_ranges_split_on_model_point_and_budget_gap() {
        let mut s = vec![
            FragmentSpec::single(ClientId(0), 0, 4, 80.0, 30.0),
            FragmentSpec::single(ClientId(1), 0, 4, 80.6, 30.0),
            FragmentSpec::single(ClientId(2), 0, 4, 83.0, 30.0), // gap > 1
            FragmentSpec::single(ClientId(3), 0, 5, 83.0, 30.0), // new p
            FragmentSpec::single(ClientId(4), 1, 5, 83.0, 30.0), // new model
        ];
        sort_specs(&mut s);
        assert_eq!(
            class_ranges(&s, 1.0),
            vec![(0, 2), (2, 3), (3, 4), (4, 5)]
        );
        assert!(class_ranges(&[], 1.0).is_empty());
        // chained runs stay one class even when the ends gap > tol
        let mut chain = vec![
            FragmentSpec::single(ClientId(0), 0, 4, 80.0, 30.0),
            FragmentSpec::single(ClientId(1), 0, 4, 80.9, 30.0),
            FragmentSpec::single(ClientId(2), 0, 4, 81.8, 30.0),
        ];
        sort_specs(&mut chain);
        assert_eq!(class_ranges(&chain, 1.0), vec![(0, 3)]);
    }

    #[test]
    fn incremental_merge_equals_scratch_and_reuses_clean_classes() {
        let cm = cm();
        let mut s = specs(10, 0, 4, 80.0, 30.0);
        s.extend(specs(6, 1, 3, 60.0, 10.0));
        let opts = MergeOptions::default();
        let mut cache = MergeCache::default();
        let first = merge_fragments_incremental(&cm, &s, &opts, &mut cache);
        assert_eq!(first.merged, merge_fragments(&cm, &s, &opts));
        assert_eq!(first.classes_remerged, first.classes);
        assert!(first.classes >= 2);
        // unchanged demand: everything splices from the cache
        let replay = merge_fragments_incremental(&cm, &s, &opts, &mut cache);
        assert_eq!(replay.merged, first.merged);
        assert_eq!(replay.classes_remerged, 0);
        // dirty one class: only it re-merges
        s[0].budget_ms = 80.4;
        let third = merge_fragments_incremental(&cm, &s, &opts, &mut cache);
        assert_eq!(third.merged, merge_fragments(&cm, &s, &opts));
        assert!(third.classes_remerged >= 1);
        assert!(third.classes_remerged < third.classes);
    }

    #[test]
    fn incremental_merge_none_threshold_passes_through() {
        let cm = cm();
        let s = specs(5, 0, 4, 80.0, 30.0);
        let mut cache = MergeCache::default();
        let out = merge_fragments_incremental(
            &cm,
            &s,
            &MergeOptions::none(),
            &mut cache,
        );
        assert_eq!(out.merged.len(), 5);
        assert_eq!(out.classes_remerged, 0);
        assert_eq!(out.merged, merge_fragments(&cm, &s, &MergeOptions::none()));
    }

    #[test]
    fn margin_decreases_with_rate() {
        let cm = cm();
        let lo = FragmentSpec::single(ClientId(0), 0, 4, 80.0, 10.0);
        let hi = FragmentSpec::single(ClientId(0), 0, 4, 80.0, 200.0);
        let ml = resource_margin(&cm, &lo, AllocConstraints::default()).unwrap();
        let mh = resource_margin(&cm, &hi, AllocConstraints::default()).unwrap();
        assert!(ml > mh, "{ml} > {mh}");
        assert!(ml >= 0.0 && mh >= 0.0);
    }
}
