//! §4.1 — DNN fragment merging.
//!
//! Uniform fragments (same partition point, same time budget) are merged
//! incrementally until the *resource margin* `(q_a - q_d)/q_d` of the
//! merged fragment drops to the merging threshold.  Merging exploits the
//! discreteness of batch/share/instances (Fig 4): an instance provisioned
//! for one client can usually absorb several more for free.  A threshold
//! of 0 ("Uniform" merging) merges every uniform fragment; Graft's
//! Uniform⁺ stops early to leave slack for grouping/re-partitioning
//! (paper §5.5 shows why that wins for low-margin models like ResNet).

use super::fragment::FragmentSpec;
use crate::profiler::{AllocConstraints, CostModel, FragmentId};

/// Strategy knobs for the merging step.
#[derive(Debug, Clone, Copy)]
pub struct MergeOptions {
    /// Stop merging into a fragment once its margin ≤ this threshold
    /// (paper default 0.2). `f64::NEG_INFINITY` ≙ merge-all ("Uniform").
    pub threshold: f64,
    /// Budgets within this tolerance count as uniform (ms).
    pub budget_tol_ms: f64,
    pub constraints: AllocConstraints,
}

impl Default for MergeOptions {
    fn default() -> Self {
        Self {
            threshold: 0.2,
            budget_tol_ms: 1.0,
            constraints: AllocConstraints::default(),
        }
    }
}

impl MergeOptions {
    /// The paper's "Uniform" strategy: merge all uniform fragments.
    pub fn merge_all() -> Self {
        Self { threshold: f64::NEG_INFINITY, ..Default::default() }
    }

    /// "No-merging" strategy.
    pub fn none() -> Self {
        Self { threshold: f64::INFINITY, ..Default::default() }
    }
}

/// Resource margin of a spec under its min-resource allocation (the §4.1
/// metric): how much spare throughput the discrete allocation yields.
pub fn resource_margin(
    cm: &CostModel,
    spec: &FragmentSpec,
    cons: AllocConstraints,
) -> Option<f64> {
    let layers = cm.config().models[spec.model].layers;
    let frag = FragmentId::new(spec.model, spec.p, layers);
    // §4.3: worst-case queueing halves the usable budget.
    cm.min_alloc(frag, spec.budget_ms / 2.0, spec.rate_rps, cons)
        .map(|a| a.margin(spec.rate_rps))
}

/// Merge fragments per §4.1.  Fragments of different models are never
/// merged.  Returns the merged specs (order: by model, point, budget).
pub fn merge_fragments(
    cm: &CostModel,
    specs: &[FragmentSpec],
    opts: &MergeOptions,
) -> Vec<FragmentSpec> {
    if opts.threshold.is_infinite() && opts.threshold > 0.0 {
        let mut out = specs.to_vec();
        sort_specs(&mut out);
        return out;
    }
    // "mergesort" the fragments into uniform classes (model, p, budget)
    let mut sorted = specs.to_vec();
    sort_specs(&mut sorted);

    let mut out: Vec<FragmentSpec> = Vec::new();
    let mut current: Option<FragmentSpec> = None;
    for spec in sorted {
        match current.take() {
            None => current = Some(spec),
            Some(mut acc) => {
                if acc.uniform_with(&spec, opts.budget_tol_ms)
                    && resource_margin(cm, &acc, opts.constraints)
                        .is_some_and(|m| m > opts.threshold)
                {
                    // margin still above threshold: absorb this one
                    acc.merge(&spec);
                    current = Some(acc);
                } else {
                    out.push(acc);
                    current = Some(spec);
                }
            }
        }
    }
    out.extend(current);
    out
}

fn sort_specs(specs: &mut [FragmentSpec]) {
    specs.sort_by(|a, b| {
        (a.model, a.p)
            .cmp(&(b.model, b.p))
            .then(a.budget_ms.total_cmp(&b.budget_ms))
            .then(a.rate_rps.total_cmp(&b.rate_rps))
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::coordinator::fragment::ClientId;

    fn cm() -> CostModel {
        CostModel::new(Config::embedded())
    }

    fn specs(n: usize, model: usize, p: usize, t: f64, q: f64) -> Vec<FragmentSpec> {
        (0..n)
            .map(|i| FragmentSpec::single(ClientId(i as u32), model, p, t, q))
            .collect()
    }

    #[test]
    fn no_merging_keeps_everything() {
        let cm = cm();
        let s = specs(10, 0, 4, 80.0, 30.0);
        let out = merge_fragments(&cm, &s, &MergeOptions::none());
        assert_eq!(out.len(), 10);
    }

    #[test]
    fn merge_all_collapses_uniform_class() {
        let cm = cm();
        let s = specs(10, 0, 4, 80.0, 30.0);
        let out = merge_fragments(&cm, &s, &MergeOptions::merge_all());
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].rate_rps, 300.0);
        assert_eq!(out[0].clients.len(), 10);
    }

    #[test]
    fn threshold_merging_is_between() {
        let cm = cm();
        let s = specs(20, 0, 4, 80.0, 30.0);
        let none = merge_fragments(&cm, &s, &MergeOptions::none()).len();
        let all = merge_fragments(&cm, &s, &MergeOptions::merge_all()).len();
        let thr = merge_fragments(
            &cm,
            &s,
            &MergeOptions { threshold: 0.2, ..Default::default() },
        )
        .len();
        assert!(all <= thr && thr <= none, "{all} <= {thr} <= {none}");
    }

    #[test]
    fn different_points_never_merge() {
        let cm = cm();
        let mut s = specs(3, 0, 4, 80.0, 30.0);
        s.extend(specs(3, 0, 5, 80.0, 30.0));
        let out = merge_fragments(&cm, &s, &MergeOptions::merge_all());
        assert_eq!(out.len(), 2);
    }

    #[test]
    fn different_models_never_merge() {
        let cm = cm();
        let mut s = specs(3, 0, 4, 80.0, 30.0);
        s.extend(specs(3, 1, 4, 80.0, 30.0));
        let out = merge_fragments(&cm, &s, &MergeOptions::merge_all());
        assert_eq!(out.len(), 2);
    }

    #[test]
    fn merged_rate_and_clients_conserved() {
        let cm = cm();
        let s = specs(12, 0, 4, 80.0, 30.0);
        let out = merge_fragments(
            &cm,
            &s,
            &MergeOptions { threshold: 0.2, ..Default::default() },
        );
        let rate: f64 = out.iter().map(|f| f.rate_rps).sum();
        let clients: usize = out.iter().map(|f| f.clients.len()).sum();
        assert_eq!(rate, 360.0);
        assert_eq!(clients, 12);
    }

    #[test]
    fn margin_decreases_with_rate() {
        let cm = cm();
        let lo = FragmentSpec::single(ClientId(0), 0, 4, 80.0, 10.0);
        let hi = FragmentSpec::single(ClientId(0), 0, 4, 80.0, 200.0);
        let ml = resource_margin(&cm, &lo, AllocConstraints::default()).unwrap();
        let mh = resource_margin(&cm, &hi, AllocConstraints::default()).unwrap();
        assert!(ml > mh, "{ml} > {mh}");
        assert!(ml >= 0.0 && mh >= 0.0);
    }
}
