//! The replan controller — the monitor → re-plan → redeploy loop of
//! Fig 6, bridging the delta-aware planner and the live serving core.
//!
//! The controller owns the current demand specs and watches the
//! *observed* per-model arrival counts of the live server (the
//! balancer's routed-submit counters,
//! [`crate::serving::Server::model_arrivals`] —
//! inter-stage forwards are excluded, so pipeline depth cannot inflate
//! the estimate).  Each [`ReplanController::tick`]:
//!
//! 1. diffs the arrival counters against the previous baseline into
//!    observed per-model rates over the window;
//! 2. compares them with the *planned* rates (the demand specs the
//!    deployed plan was built from) — the max relative drift decides;
//! 3. on drift ≥ the threshold, scales the drifted models' demand
//!    rates to the observation, re-plans on the shared (incremental,
//!    PR-4 delta-aware) [`Scheduler`], re-places with the
//!    migration-minimizing delta placement
//!    ([`crate::coordinator::placement::place_delta`]) and applies the
//!    new plan through the live transition engine
//!    ([`LiveServer::reconfigure`]) — in-flight requests finish on the
//!    old shards while the new ones open.
//!
//! `tick` is synchronous and deterministic given the counters, so the
//! tests drive it directly; [`ReplanController::run`] wraps it in a
//! background watcher thread for `graft serve --reconfigure`.

use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::fragment::FragmentSpec;
use super::placement::{
    place_constrained, place_delta_constrained, stamp, PlacementConstraints,
};
use super::scheduler::Scheduler;
use crate::runtime::transition::{diff_plans, LiveServer, TransitionReport};
use crate::serving::GpuDegradation;
use crate::util::lock::lock_recover;

#[derive(Debug, Clone)]
pub struct ControllerOptions {
    /// Relative per-model drift `|observed − planned| / planned` that
    /// fires a replan.
    pub drift_threshold: f64,
    /// Arrivals a window must contain before its rate estimate is
    /// trusted (windows keep accumulating until then).
    pub min_requests: u64,
    /// Watcher thread poll interval ([`ReplanController::run`]).
    pub interval: Duration,
    /// Clamp on the per-model demand rescale factor per trigger, so a
    /// measurement artifact cannot blow the demand model up (or to 0).
    pub rate_clamp: (f64, f64),
    /// Observed RPS above which traffic on a model whose *planned* rate
    /// is zero counts as threshold-exceeding drift.  Zero-rate models
    /// have no meaningful relative drift (`|o − p| / p` divides by 0),
    /// and were previously skipped outright — so a surge on a
    /// newly-popular model could never trigger a replan.  The observed
    /// rate is distributed across the model's demand specs directly
    /// (no rescale factor exists), so `rate_clamp` does not apply.
    pub unplanned_rate_floor: f64,
    /// Persist the scheduler's replan context here after every replan
    /// ([`Scheduler::save_replan_context`]), so a restarted scheduler
    /// warm-starts its first live replan.  The save is dirty-flagged:
    /// a replan that changed no persisted state (the steady-state loop)
    /// skips the atomic rewrite entirely, so pointing this at disk
    /// costs no I/O per tick unless the plan actually moved.  Replans
    /// themselves run on the scheduler's sharded planner — set
    /// `SchedulerOptions::planner_threads` > 1 to parallelise the
    /// per-model shards with byte-identical plans.
    pub context_path: Option<PathBuf>,
    /// Predictive failure avoidance: a GPU whose health score
    /// ([`crate::serving::HealthRegistry::gpu_scores`]) reaches this
    /// threshold joins the *soft* avoid-set — the next replan migrates
    /// its instances to healthy GPUs (a [`TickOutcome::ProactiveMigration`]
    /// fires immediately when it hosts any).  Suspects stay suspect
    /// until explicitly recovered
    /// ([`crate::serving::HealthRegistry::mark_gpu_recovered`]) —
    /// hysteresis, so a vacated GPU (whose score freezes without
    /// beats) cannot flap back in.  `None` disables the predictive
    /// path (the reactive baseline).
    pub suspect_threshold: Option<f64>,
    /// Correlated-failure domains (rack/host groups): when any member
    /// GPU fails, the emergency replan excludes the *whole* domain —
    /// hardware that shares a failure domain with dead hardware is
    /// assumed next.
    pub failure_domains: Vec<Vec<u32>>,
    /// Observed-latency drift: when a model's traced e2e p99 exceeds
    /// its planned wall-clock envelope (§4.3 window + execution, scaled
    /// by the core's pacing `time_scale`) by this factor, the model's
    /// demand rate is scaled up and a replan fires — arrival counters
    /// can look stable while queueing delay quietly eats the budget
    /// (burstier arrivals, slower hardware), and this is the signal
    /// that catches it.  Requires request tracing to be on
    /// ([`crate::serving::ServerOptions::trace`]); with tracing off or
    /// pacing off the check is inert.  `None` disables.
    pub latency_drift_factor: Option<f64>,
    /// Traced requests a model needs before its e2e p99 is trusted by
    /// the latency-drift check.
    pub latency_min_samples: u64,
}

impl Default for ControllerOptions {
    fn default() -> Self {
        Self {
            drift_threshold: 0.25,
            min_requests: 50,
            interval: Duration::from_secs(1),
            rate_clamp: (0.2, 5.0),
            unplanned_rate_floor: 1.0,
            context_path: None,
            suspect_threshold: Some(0.6),
            failure_domains: Vec::new(),
            latency_drift_factor: Some(1.5),
            latency_min_samples: 50,
        }
    }
}

/// What one controller tick did.
#[derive(Debug)]
pub enum TickOutcome {
    /// First observation (or post-swap counter reset): baseline stored.
    Baseline,
    /// The window has too few arrivals to trust; it keeps accumulating.
    TooFewRequests { arrivals: u64 },
    /// Every model within the drift threshold.
    Stable { max_drift: f64 },
    /// Drift fired but the replanner produced a configuration-identical
    /// plan (discreteness absorbed the rate move) — nothing to deploy.
    PlanUnchanged { max_drift: f64 },
    /// Re-planned and hot-swapped.
    Replanned {
        max_drift: f64,
        scaled_models: usize,
        report: TransitionReport,
    },
    /// The live core reported GPU failures: re-planned immediately with
    /// the dead GPUs excluded from placement and hot-swapped the
    /// surviving capacity in (bypasses the drift/min-requests gates).
    /// `domain_excluded` lists still-alive GPUs pre-emptively excluded
    /// because they share a configured failure domain with a dead one.
    EmergencyReplanned {
        failed_gpus: Vec<u32>,
        domain_excluded: Vec<u32>,
        report: TransitionReport,
    },
    /// Previously dead/degraded/suspect GPUs were marked recovered:
    /// re-planned with a full repack so the restored capacity is
    /// actually reused (a delta placement would pin everything in
    /// place and never migrate back).
    RecoveryReplanned {
        recovered_gpus: Vec<u32>,
        report: TransitionReport,
    },
    /// The live core reported partial-GPU degradations: re-planned with
    /// the affected GPUs' residual capacities folded into placement,
    /// shedding only the instances that no longer fit.
    DegradeRebalanced {
        degraded_gpus: Vec<u32>,
        report: TransitionReport,
    },
    /// Predictive path: health scores crossed the suspect threshold on
    /// GPUs still hosting instances — migrated them off *before* a
    /// failure, while the hardware can still drain cleanly.
    ProactiveMigration {
        suspect_gpus: Vec<u32>,
        migrated_instances: usize,
        report: TransitionReport,
    },
    /// Observed-latency drift: a model's traced e2e p99 blew past its
    /// planned wall-clock envelope while arrival counters looked fine —
    /// its demand was scaled up and the plan re-fit.
    LatencyReplanned {
        model: String,
        e2e_p99_ms: f64,
        envelope_ms: f64,
        report: TransitionReport,
    },
}

struct CtrlState {
    demands: Vec<FragmentSpec>,
    /// Arrival counters + wall clock of the window start, and the swap
    /// generation they were read under (a swap resets the counters).
    baseline: Option<(HashMap<String, u64>, Instant)>,
    swap_gen: u64,
    /// GPUs reported failed by any core so far.  Accumulated across
    /// swaps (each new core starts a fresh
    /// [`crate::serving::HealthRegistry`]) and excluded from every
    /// subsequent placement — a replanned fleet never lands back on
    /// hardware that already failed.  Shrinks only through the explicit
    /// recovery path ([`crate::serving::HealthRegistry::mark_gpu_recovered`]).
    dead_gpus: BTreeSet<u32>,
    /// Soft avoid-set: GPUs whose health score crossed
    /// [`ControllerOptions::suspect_threshold`].  Placement treats them
    /// as last-resort bins (prefer-not, never exclude) — capacity is
    /// never sacrificed on a hunch.
    suspect_gpus: BTreeSet<u32>,
    /// Suspects a proactive migration has already been attempted for,
    /// so a frozen above-threshold score doesn't re-fire every tick.
    handled_suspects: BTreeSet<u32>,
    /// Partial-GPU degradations seen so far: placement offers only the
    /// residual capacity of these GPUs.
    degraded: BTreeMap<u32, GpuDegradation>,
    /// Models the latency-drift path already acted on against the
    /// current core's histograms, so an unchanged plan (or still-warm
    /// histogram) doesn't re-fire every tick.  A swap installs a fresh
    /// core with fresh histograms and clears this.
    latency_handled: BTreeSet<usize>,
}

pub struct ReplanController {
    sched: Arc<Scheduler>,
    live: Arc<LiveServer>,
    pub opts: ControllerOptions,
    state: Mutex<CtrlState>,
}

impl ReplanController {
    pub fn new(
        sched: Arc<Scheduler>,
        live: Arc<LiveServer>,
        demands: Vec<FragmentSpec>,
        opts: ControllerOptions,
    ) -> Self {
        Self {
            sched,
            live,
            opts,
            state: Mutex::new(CtrlState {
                demands,
                baseline: None,
                swap_gen: 0,
                dead_gpus: BTreeSet::new(),
                suspect_gpus: BTreeSet::new(),
                handled_suspects: BTreeSet::new(),
                degraded: BTreeMap::new(),
                latency_handled: BTreeSet::new(),
            }),
        }
    }

    /// The demand specs the deployed plan was built from.
    pub fn demands(&self) -> Vec<FragmentSpec> {
        lock_recover(&self.state).demands.clone()
    }

    /// GPUs the controller has seen fail so far (excluded from every
    /// placement it produces).
    pub fn dead_gpus(&self) -> Vec<u32> {
        lock_recover(&self.state).dead_gpus.iter().copied().collect()
    }

    /// The current soft avoid-set (suspect but not dead GPUs).
    pub fn suspect_gpus(&self) -> Vec<u32> {
        lock_recover(&self.state)
            .suspect_gpus
            .iter()
            .copied()
            .collect()
    }

    /// Partially-degraded GPUs and their cumulative capacity losses.
    pub fn degraded_gpus(&self) -> Vec<(u32, GpuDegradation)> {
        lock_recover(&self.state)
            .degraded
            .iter()
            .map(|(g, d)| (*g, *d))
            .collect()
    }

    /// The full placement constraint set implied by the controller's
    /// accumulated failure knowledge: dead GPUs are hard-avoided,
    /// suspects are soft-avoided, degradations cap residual capacity.
    fn constraints(&self, st: &CtrlState) -> PlacementConstraints {
        let mut cons = PlacementConstraints {
            hard_avoid: st.dead_gpus.iter().copied().collect(),
            ..Default::default()
        };
        cons.soft_avoid = st
            .suspect_gpus
            .iter()
            .filter(|g| !st.dead_gpus.contains(g))
            .copied()
            .collect();
        for (gpu, d) in &st.degraded {
            if st.dead_gpus.contains(gpu) {
                continue;
            }
            cons.share_loss.insert(*gpu, d.share_loss);
            cons.mem_loss_mb.insert(*gpu, d.mem_loss_mb);
        }
        cons
    }

    /// Re-plan with the accumulated failure constraints applied,
    /// re-place and hot-swap.  Shared by the drift path and the
    /// failure/recovery/degrade/suspect paths.  `rebalance` picks the
    /// placement strategy: `false` → migration-minimizing delta
    /// against the deployed plan; `true` → full constrained repack
    /// (used after a recovery, where the delta would pin every
    /// instance in place and never reuse the restored GPU).
    fn replan_and_swap(
        &self,
        st: &mut CtrlState,
        demands: Vec<FragmentSpec>,
        mut new_plan: crate::coordinator::plan::ExecutionPlan,
        rebalance: bool,
    ) -> TransitionReport {
        let cm = self.sched.cost_model();
        let cons = self.constraints(st);
        // re-placement under the constraint set (falls back to the
        // scheduler's own FFD stamps on failure — only reachable with
        // an empty constraint set, where the stamps are equivalent)
        if rebalance {
            if let Ok(p) = place_constrained(cm, &new_plan, None, &cons) {
                stamp(&mut new_plan, &p);
            }
        } else {
            let old_plan = self.live.plan();
            if let Ok(d) =
                place_delta_constrained(cm, &old_plan, &new_plan, None, &cons)
            {
                stamp(&mut new_plan, &d.placement);
            }
        }
        let report = self.live.reconfigure(&new_plan);
        st.demands = demands;
        st.swap_gen = self.live.swap_count();
        st.baseline = None; // fresh counters next tick
        st.latency_handled.clear(); // fresh core, fresh histograms
        if let Some(path) = &self.opts.context_path {
            let _ = self.sched.save_replan_context(path);
        }
        report
    }

    /// One monitor → (maybe) re-plan → (maybe) redeploy step.
    pub fn tick(&self) -> TickOutcome {
        let mut st = lock_recover(&self.state);
        let server = self.live.server();

        // failure detection first: a GPU loss bypasses the drift and
        // min-requests gates — surviving capacity must be rebalanced
        // now, not after the window fills
        let failed = server.health().take_unacked_gpu_failures();
        if !failed.is_empty() {
            st.dead_gpus.extend(failed.iter().copied());
            // correlated-failure domains: hardware sharing a domain
            // with a dead GPU is excluded wholesale before it fails too
            let mut domain_excluded: Vec<u32> = Vec::new();
            for domain in &self.opts.failure_domains {
                if domain.iter().any(|g| failed.contains(g)) {
                    for &g in domain {
                        if st.dead_gpus.insert(g) {
                            domain_excluded.push(g);
                        }
                    }
                }
            }
            domain_excluded.sort_unstable();
            // hard-dead supersedes every softer mark
            let dead = st.dead_gpus.clone();
            st.suspect_gpus.retain(|g| !dead.contains(g));
            st.handled_suspects.retain(|g| !dead.contains(g));
            st.degraded.retain(|g, _| !dead.contains(g));
            let demands = st.demands.clone();
            let (new_plan, _stats) = self.sched.plan(&demands);
            let report = self.replan_and_swap(&mut st, demands, new_plan, false);
            // the swap installed a fresh core whose registry starts
            // clean; close the epoch so `degraded()` reads false
            self.live.server().health().note_recovery();
            return TickOutcome::EmergencyReplanned {
                failed_gpus: failed,
                domain_excluded,
                report,
            };
        }

        // recovery: GPUs explicitly marked healthy again are lifted out
        // of every avoid/degrade set, and a *full repack* replan pulls
        // capacity back onto them (a delta placement would pin the
        // deployed plan and never migrate back)
        let recovered: Vec<u32> = server
            .health()
            .take_unacked_gpu_recoveries()
            .into_iter()
            .filter(|g| {
                st.dead_gpus.contains(g)
                    || st.degraded.contains_key(g)
                    || st.suspect_gpus.contains(g)
            })
            .collect();
        if !recovered.is_empty() {
            for g in &recovered {
                st.dead_gpus.remove(g);
                st.degraded.remove(g);
                st.suspect_gpus.remove(g);
                st.handled_suspects.remove(g);
            }
            let demands = st.demands.clone();
            let (new_plan, _stats) = self.sched.plan(&demands);
            let report = self.replan_and_swap(&mut st, demands, new_plan, true);
            return TickOutcome::RecoveryReplanned {
                recovered_gpus: recovered,
                report,
            };
        }

        // partial degradation: fold the reported residual capacities
        // into placement and shed only what no longer fits
        let degrades: Vec<(u32, GpuDegradation)> = server
            .health()
            .take_unacked_degrades()
            .into_iter()
            .filter(|(g, _)| !st.dead_gpus.contains(g))
            .collect();
        if !degrades.is_empty() {
            for (g, d) in &degrades {
                st.degraded.insert(*g, *d);
            }
            let demands = st.demands.clone();
            let (new_plan, _stats) = self.sched.plan(&demands);
            let report = self.replan_and_swap(&mut st, demands, new_plan, false);
            // the degrade bumped the failure epoch; the swap routed
            // around the lost capacity, so close the epoch
            self.live.server().health().note_recovery();
            return TickOutcome::DegradeRebalanced {
                degraded_gpus: degrades.iter().map(|(g, _)| *g).collect(),
                report,
            };
        }

        // predictive avoidance: fold health scores into the soft
        // avoid-set, and migrate off newly-suspect GPUs that still
        // host instances — before the hardware actually fails
        if let Some(threshold) = self.opts.suspect_threshold {
            for (gpu, score) in server.gpu_health_scores() {
                if score >= threshold && !st.dead_gpus.contains(&gpu) {
                    st.suspect_gpus.insert(gpu);
                }
            }
            let pending: Vec<u32> = st
                .suspect_gpus
                .difference(&st.handled_suspects)
                .copied()
                .collect();
            if !pending.is_empty() {
                st.handled_suspects.extend(pending.iter().copied());
                let hosted: usize = self
                    .live
                    .plan()
                    .stages()
                    .map(|s| {
                        s.gpus.iter().filter(|g| pending.contains(*g)).count()
                    })
                    .sum();
                if hosted > 0 {
                    let demands = st.demands.clone();
                    let (new_plan, _stats) = self.sched.plan(&demands);
                    let report =
                        self.replan_and_swap(&mut st, demands, new_plan, false);
                    return TickOutcome::ProactiveMigration {
                        suspect_gpus: pending,
                        migrated_instances: hosted,
                        report,
                    };
                }
            }
        }

        // observed-latency drift: the tracing pipeline's per-model e2e
        // p99 against the deployed plan's wall-clock envelope.  Arrival
        // counters miss the case where the *rate* is on plan but the
        // latency is not (burstier arrivals, slower-than-modeled
        // hardware); the registry's observed latencies are the second
        // drift signal.  Only meaningful under pacing (time_scale > 0),
        // where the modeled envelope has a wall-clock interpretation.
        if let Some(factor) = self.opts.latency_drift_factor {
            let ts = server.time_scale();
            if ts > 0.0 {
                let obs = server.obs();
                let plan = self.live.plan();
                // planned wall-clock envelope per model: worst member
                // path, one batch window of formation + the execution
                let mut env: BTreeMap<usize, f64> = BTreeMap::new();
                for set in &plan.sets {
                    let shared = set.shared.alloc.latency_ms;
                    let worst_align = set
                        .members
                        .iter()
                        .filter_map(|m| m.align.as_ref())
                        .map(|a| a.alloc.latency_ms)
                        .fold(0.0, f64::max);
                    let e = env.entry(set.model).or_insert(0.0);
                    *e = e.max(2.0 * (worst_align + shared));
                }
                // worst offender by p99/envelope ratio
                let mut hit: Option<(usize, f64, f64)> = None;
                for (mi, _, lat) in obs.models() {
                    let mi = mi as usize;
                    if st.latency_handled.contains(&mi)
                        || lat.e2e.count() < self.opts.latency_min_samples
                    {
                        continue;
                    }
                    let Some(&env_ms) = env.get(&mi) else { continue };
                    let wall = env_ms * ts;
                    let p99 = lat.e2e.percentile(99.0);
                    if wall <= 0.0 || !p99.is_finite() || p99 <= wall * factor
                    {
                        continue;
                    }
                    let better = match &hit {
                        Some((_, hp, hw)) => p99 / wall > hp / hw,
                        None => true,
                    };
                    if better {
                        hit = Some((mi, p99, wall));
                    }
                }
                if let Some((mi, p99, wall)) = hit {
                    st.latency_handled.insert(mi);
                    // scale the model's demand by the envelope excess,
                    // clamped like the arrival-drift rescale (never
                    // below 1: observed latency can only argue for
                    // *more* capacity)
                    let (lo, hi) = self.opts.rate_clamp;
                    let f = (p99 / (wall * factor)).clamp(lo.max(1.0), hi);
                    let mut demands = st.demands.clone();
                    for s in demands.iter_mut().filter(|s| s.model == mi) {
                        s.rate_rps *= f;
                    }
                    let (new_plan, _stats) = self.sched.plan(&demands);
                    let old_plan = self.live.plan();
                    let t = diff_plans(&old_plan, &new_plan);
                    if t.updated_sets + t.added_sets + t.removed_sets > 0 {
                        let model = self.sched.cost_model().config().models
                            [mi]
                            .name
                            .clone();
                        let report =
                            self.replan_and_swap(&mut st, demands, new_plan, false);
                        return TickOutcome::LatencyReplanned {
                            model,
                            e2e_p99_ms: p99,
                            envelope_ms: wall,
                            report,
                        };
                    }
                    // discreteness absorbed the scale-up: keep the
                    // updated demand model so the next arrival-drift
                    // replan bakes the latency signal in anyway
                    st.demands = demands;
                }
            }
        }

        let gen = self.live.swap_count();
        let now = Instant::now();
        let arrivals = server.model_arrivals();

        let Some((base, t0)) = st
            .baseline
            .as_ref()
            .filter(|_| st.swap_gen == gen)
            .cloned()
        else {
            // first tick, or a swap reset the counters mid-window
            st.baseline = Some((arrivals, now));
            st.swap_gen = gen;
            return TickOutcome::Baseline;
        };

        let dt_s = now.duration_since(t0).as_secs_f64().max(1e-9);
        let mut window_total = 0u64;
        let mut observed: HashMap<&str, f64> = HashMap::new();
        for (model, &count) in &arrivals {
            let delta = count.saturating_sub(*base.get(model).unwrap_or(&0));
            window_total += delta;
            observed.insert(model.as_str(), delta as f64 / dt_s);
        }
        if window_total < self.opts.min_requests {
            // keep the window open until the estimate means something
            return TickOutcome::TooFewRequests { arrivals: window_total };
        }

        // planned per-model rates from the current demand model
        let cm = self.sched.cost_model();
        let mut planned: HashMap<&str, f64> = HashMap::new();
        for s in &st.demands {
            *planned
                .entry(cm.config().models[s.model].name.as_str())
                .or_insert(0.0) += s.rate_rps;
        }
        let mut max_drift = 0.0f64;
        let mut factors: HashMap<usize, f64> = HashMap::new();
        // models with zero planned but real observed rate: (model idx,
        // observed RPS) — handled by direct rate assignment, not factors
        let mut surges: HashMap<usize, f64> = HashMap::new();
        for (mi, m) in cm.config().models.iter().enumerate() {
            let p = *planned.get(m.name.as_str()).unwrap_or(&0.0);
            let o = *observed.get(m.name.as_str()).unwrap_or(&0.0);
            if p <= 0.0 {
                // no planned traffic.  A model with demand specs (just
                // zero-rated) that is now seeing real arrivals is
                // unplanned drift — above the floor it must fire like
                // any threshold-exceeding model.  Models with no specs
                // at all are skipped: the controller can only rescale
                // demand it knows about, not invent clients.
                if planned.contains_key(m.name.as_str())
                    && o > self.opts.unplanned_rate_floor
                {
                    let floor = self.opts.unplanned_rate_floor.max(1e-9);
                    max_drift =
                        max_drift.max((o / floor).max(self.opts.drift_threshold));
                    surges.insert(mi, o);
                }
                continue;
            }
            let drift = (o - p).abs() / p;
            max_drift = max_drift.max(drift);
            if drift >= self.opts.drift_threshold {
                let (lo, hi) = self.opts.rate_clamp;
                factors.insert(mi, (o / p).clamp(lo, hi));
            }
        }
        // window consumed either way: re-baseline on the fresh counters
        st.baseline = Some((arrivals, now));
        st.swap_gen = gen;
        if factors.is_empty() && surges.is_empty() {
            return TickOutcome::Stable { max_drift };
        }

        // drift: rescale the drifted models' demand and re-plan
        // incrementally on the shared scheduler
        let mut demands = st.demands.clone();
        for s in &mut demands {
            if let Some(f) = factors.get(&s.model) {
                s.rate_rps *= f;
            }
        }
        // surged models: split the observed rate evenly across the
        // model's demand specs (they were all zero-rated; max keeps any
        // spec that already carried rate intact)
        for (&mi, &o) in &surges {
            let k = demands.iter().filter(|s| s.model == mi).count();
            if k == 0 {
                continue;
            }
            let share = o / k as f64;
            for s in demands.iter_mut().filter(|s| s.model == mi) {
                s.rate_rps = s.rate_rps.max(share);
            }
        }
        let (new_plan, _stats) = self.sched.plan(&demands);
        let old_plan = self.live.plan();
        let t = diff_plans(&old_plan, &new_plan);
        if t.updated_sets + t.added_sets + t.removed_sets == 0 {
            st.demands = demands;
            return TickOutcome::PlanUnchanged { max_drift };
        }
        let report = self.replan_and_swap(&mut st, demands, new_plan, false);
        TickOutcome::Replanned {
            max_drift,
            scaled_models: factors.len() + surges.len(),
            report,
        }
    }

    /// Background watcher: tick every `opts.interval` until `stop` is
    /// set.  Returns the watcher thread handle.
    pub fn run(self: Arc<Self>, stop: Arc<AtomicBool>) -> JoinHandle<()> {
        let interval = self.opts.interval;
        std::thread::Builder::new()
            .name("graft-replan-ctrl".into())
            .spawn(move || {
                while !stop.load(Ordering::SeqCst) {
                    let outcome = self.tick();
                    if let TickOutcome::EmergencyReplanned {
                        failed_gpus,
                        domain_excluded,
                        report,
                    } = &outcome
                    {
                        eprintln!(
                            "[controller] EMERGENCY: gpu(s) {:?} failed \
                             (domain-excluded {:?}) -> replanned around them, \
                             swap {:.1} ms (drain {:.1} ms)",
                            failed_gpus,
                            domain_excluded,
                            report.total_ms,
                            report.drain_ms,
                        );
                    }
                    if let TickOutcome::ProactiveMigration {
                        suspect_gpus,
                        migrated_instances,
                        report,
                    } = &outcome
                    {
                        eprintln!(
                            "[controller] PREDICTIVE: gpu(s) {:?} suspect -> \
                             migrated {} instance(s) off pre-failure, swap \
                             {:.1} ms",
                            suspect_gpus, migrated_instances, report.total_ms,
                        );
                    }
                    if let TickOutcome::RecoveryReplanned {
                        recovered_gpus,
                        report,
                    } = &outcome
                    {
                        eprintln!(
                            "[controller] RECOVERY: gpu(s) {:?} healthy again \
                             -> repacked onto restored capacity, swap {:.1} ms",
                            recovered_gpus, report.total_ms,
                        );
                    }
                    if let TickOutcome::DegradeRebalanced {
                        degraded_gpus,
                        report,
                    } = &outcome
                    {
                        eprintln!(
                            "[controller] DEGRADE: gpu(s) {:?} lost partial \
                             capacity -> rebalanced onto residuals, swap \
                             {:.1} ms",
                            degraded_gpus, report.total_ms,
                        );
                    }
                    if let TickOutcome::LatencyReplanned {
                        model,
                        e2e_p99_ms,
                        envelope_ms,
                        report,
                    } = &outcome
                    {
                        eprintln!(
                            "[controller] LATENCY: model {} e2e p99 {:.1} ms \
                             over its {:.1} ms envelope -> scaled demand and \
                             replanned, swap {:.1} ms",
                            model, e2e_p99_ms, envelope_ms, report.total_ms,
                        );
                    }
                    if let TickOutcome::Replanned {
                        max_drift, report, ..
                    } = &outcome
                    {
                        eprintln!(
                            "[controller] drift {:.0}% -> replanned: {} kept / \
                             {} updated / {} added / {} removed sets, swap \
                             {:.1} ms (drain {:.1} ms), old core rejected {}",
                            max_drift * 100.0,
                            report.transition.kept_sets,
                            report.transition.updated_sets,
                            report.transition.added_sets,
                            report.transition.removed_sets,
                            report.total_ms,
                            report.drain_ms,
                            report.old_rejected,
                        );
                    }
                    // sleep in small steps so stop is honored promptly
                    let deadline = Instant::now() + interval;
                    while !stop.load(Ordering::SeqCst)
                        && Instant::now() < deadline
                    {
                        std::thread::sleep(Duration::from_millis(20));
                    }
                }
            })
            .expect("spawn replan controller")
    }
}
