//! Execution plans — the scheduler's output (paper §3).
//!
//! A plan materialises the re-alignment decisions: for every re-aligned
//! set, the re-partition point, each member's alignment-stage instance
//! allocation (layers `p_i+1..=p'`), and the shared-stage allocation
//! (layers `p'+1..=L`) that batches all members' requests together.

use super::fragment::FragmentSpec;
use crate::profiler::{Alloc, CostModel, FragmentId};

/// One provisioned stage: a fragment with its resource allocation and the
/// time budget it was sized for.
#[derive(Debug, Clone, PartialEq)]
pub struct StagePlan {
    pub frag: FragmentId,
    pub alloc: Alloc,
    pub budget_ms: f64,
    /// Demand this stage was sized for (RPS).
    pub demand_rps: f64,
    /// Per-instance GPU assignment, one entry per `alloc.instances`,
    /// stamped by the planner's placement pass
    /// ([`crate::coordinator::placement`]).  Empty until placed.
    pub gpus: Vec<u32>,
}

impl StagePlan {
    pub fn total_share(&self) -> u32 {
        self.alloc.total_share()
    }

    /// Whether every instance of this stage has a GPU assignment.
    pub fn is_placed(&self) -> bool {
        self.gpus.len() == self.alloc.instances as usize
    }

    /// GPU hosting instance `inst`, if placed.
    pub fn gpu_of(&self, inst: usize) -> Option<u32> {
        self.gpus.get(inst).copied()
    }
}

/// A member of a re-aligned set: its original spec plus the alignment
/// stage (absent when the member's partition point equals the
/// re-partition point).
#[derive(Debug, Clone, PartialEq)]
pub struct MemberPlan {
    pub spec: FragmentSpec,
    pub align: Option<StagePlan>,
}

/// A set of fragments re-aligned at one re-partition point sharing one
/// batched suffix instance group.
#[derive(Debug, Clone, PartialEq)]
pub struct RealignedSet {
    pub model: usize,
    /// The re-partition point `p'` (§4.3).
    pub point: usize,
    pub members: Vec<MemberPlan>,
    pub shared: StagePlan,
}

impl RealignedSet {
    pub fn total_share(&self) -> u32 {
        self.shared.total_share()
            + self
                .members
                .iter()
                .filter_map(|m| m.align.as_ref())
                .map(StagePlan::total_share)
                .sum::<u32>()
    }

    pub fn total_rate(&self) -> f64 {
        self.members.iter().map(|m| m.spec.rate_rps).sum()
    }
}

/// The full execution plan.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ExecutionPlan {
    pub sets: Vec<RealignedSet>,
    /// Fragments the scheduler could not provision within their SLO
    /// (these requests would be dropped by the load balancer).
    pub infeasible: Vec<FragmentSpec>,
}

impl ExecutionPlan {
    /// Total GPU consumption (share percentage points; 100 == one GPU).
    pub fn total_share(&self) -> u32 {
        self.sets.iter().map(RealignedSet::total_share).sum()
    }

    /// GPUs this plan needs, memory-aware and placement-backed: the
    /// stamped placement when present, otherwise a fresh first-fit-
    /// decreasing placement under the configured share + memory caps.
    /// `None` when some instance cannot fit any single GPU.
    pub fn gpus(&self, cm: &CostModel) -> Option<usize> {
        if let Some(n) = self.placed_gpus() {
            return Some(n);
        }
        super::placement::place(cm, self, None).ok().map(|p| p.gpus())
    }

    /// Share-only lower bound on the GPU count: `⌈total_share /
    /// max_share⌉`.  Ignores memory and per-GPU packing, so any real
    /// placement uses at least this many GPUs — kept as the documented
    /// reference bound the placement tests compare against.
    pub fn gpus_share_lower_bound(&self, max_share: u32) -> u32 {
        self.total_share().div_ceil(max_share)
    }

    /// GPU count of the stamped placement: `Some(max gpu id + 1)` when
    /// every stage is fully placed (an empty plan is trivially placed on
    /// zero GPUs), `None` otherwise.
    pub fn placed_gpus(&self) -> Option<usize> {
        let mut max_gpu: Option<u32> = None;
        for s in self.stages() {
            if !s.is_placed() {
                return None;
            }
            max_gpu = max_gpu.max(s.gpus.iter().copied().max());
        }
        Some(max_gpu.map_or(0, |g| g as usize + 1))
    }

    /// The distinct re-partition points of this plan's sets, sorted.
    /// These are the warm-start hints the scheduler persists across
    /// triggers to seed the next suffix-DP run
    /// ([`crate::coordinator::repartition::realign_group_warm`]).
    pub fn realign_points(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.sets.iter().map(|s| s.point).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// All stages in the plan (alignment + shared).
    pub fn stages(&self) -> impl Iterator<Item = &StagePlan> {
        self.sets.iter().flat_map(|s| {
            s.members
                .iter()
                .filter_map(|m| m.align.as_ref())
                .chain(std::iter::once(&s.shared))
        })
    }

    /// Mutable stage iteration in the same order as [`Self::stages`]
    /// (the placement pass stamps assignments through this).
    pub fn stages_mut(&mut self) -> impl Iterator<Item = &mut StagePlan> {
        self.sets.iter_mut().flat_map(|s| {
            s.members
                .iter_mut()
                .filter_map(|m| m.align.as_mut())
                .chain(std::iter::once(&mut s.shared))
        })
    }

    pub fn merge_with(&mut self, mut other: ExecutionPlan) {
        self.sets.append(&mut other.sets);
        self.infeasible.append(&mut other.infeasible);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::fragment::ClientId;
    use crate::profiler::Alloc;

    fn stage(share: u32, inst: u32) -> StagePlan {
        StagePlan {
            frag: FragmentId::new(0, 2, 17),
            alloc: Alloc {
                batch: 4,
                share,
                instances: inst,
                latency_ms: 10.0,
                throughput_rps: 100.0,
            },
            budget_ms: 10.0,
            demand_rps: 60.0,
            gpus: Vec::new(),
        }
    }

    fn member(p: usize, align: Option<StagePlan>) -> MemberPlan {
        MemberPlan {
            spec: FragmentSpec::single(ClientId(0), 0, p, 50.0, 30.0),
            align,
        }
    }

    #[test]
    fn share_accounting() {
        let set = RealignedSet {
            model: 0,
            point: 2,
            members: vec![member(1, Some(stage(10, 2))), member(2, None)],
            shared: stage(25, 1),
        };
        assert_eq!(set.total_share(), 10 * 2 + 25);
        assert_eq!(set.total_rate(), 60.0);
        let plan = ExecutionPlan { sets: vec![set], infeasible: vec![] };
        assert_eq!(plan.total_share(), 45);
        assert_eq!(plan.gpus_share_lower_bound(100), 1);
        assert_eq!(plan.stages().count(), 2);
    }

    #[test]
    fn share_lower_bound_rounds_up() {
        let set = RealignedSet {
            model: 0,
            point: 2,
            members: vec![member(2, None)],
            shared: stage(34, 4),
        };
        let plan = ExecutionPlan { sets: vec![set], infeasible: vec![] };
        assert_eq!(plan.total_share(), 136);
        assert_eq!(plan.gpus_share_lower_bound(100), 2);
    }

    #[test]
    fn realign_points_sorted_and_deduped() {
        let mk = |point| RealignedSet {
            model: 0,
            point,
            members: vec![member(point, None)],
            shared: stage(10, 1),
        };
        let plan = ExecutionPlan {
            sets: vec![mk(7), mk(2), mk(7), mk(4)],
            infeasible: vec![],
        };
        assert_eq!(plan.realign_points(), vec![2, 4, 7]);
        assert!(ExecutionPlan::default().realign_points().is_empty());
    }

    #[test]
    fn placed_gpus_requires_full_stamping() {
        let mut set = RealignedSet {
            model: 0,
            point: 2,
            members: vec![member(1, Some(stage(10, 2))), member(2, None)],
            shared: stage(25, 1),
        };
        let unplaced = ExecutionPlan {
            sets: vec![set.clone()],
            infeasible: vec![],
        };
        assert_eq!(unplaced.placed_gpus(), None);
        set.members[0].align.as_mut().unwrap().gpus = vec![0, 1];
        set.shared.gpus = vec![2];
        let placed = ExecutionPlan { sets: vec![set], infeasible: vec![] };
        assert_eq!(placed.placed_gpus(), Some(3));
        assert_eq!(ExecutionPlan::default().placed_gpus(), Some(0));
    }
}
